"""Training-time detection evaluation (NumPy twin of rust/src/eval).

Used by `aot.py --validate` to sanity-check the detector before export
(the authoritative evaluation lives in Rust where the serving stack is);
the two implementations agree on the metric definition: greedy matching
at IoU 0.5, 101-point interpolated AP, classes absent from GT excluded.
"""

from __future__ import annotations

from typing import List, Sequence

import numpy as np


def iou(a: np.ndarray, b: np.ndarray) -> float:
    ix0 = max(a[0], b[0])
    iy0 = max(a[1], b[1])
    ix1 = min(a[2], b[2])
    iy1 = min(a[3], b[3])
    inter = max(0.0, ix1 - ix0) * max(0.0, iy1 - iy0)
    area_a = max(0.0, a[2] - a[0]) * max(0.0, a[3] - a[1])
    area_b = max(0.0, b[2] - b[0]) * max(0.0, b[3] - b[1])
    union = area_a + area_b - inter
    return inter / union if union > 0 else 0.0


def nms(boxes: np.ndarray, iou_thresh: float = 0.45, topk: int = 50) -> np.ndarray:
    """boxes: (n, 6) x0,y0,x1,y1,score,class — greedy per-class NMS."""
    if boxes.size == 0:
        return boxes.reshape(0, 6)
    order = np.argsort(-boxes[:, 4])
    keep: List[np.ndarray] = []
    for i in order:
        b = boxes[i]
        if any(k[5] == b[5] and iou(k, b) > iou_thresh for k in keep):
            continue
        keep.append(b)
        if len(keep) >= topk:
            break
    return np.stack(keep) if keep else boxes[:0]


def average_precision(
    dets: Sequence[np.ndarray], gts: Sequence[np.ndarray], cls: int, thresh: float
):
    """dets/gts: per-image arrays (n,6)/(m,5). Returns AP or None."""
    records = []  # (score, img, box)
    total_gt = 0
    for i, (d, g) in enumerate(zip(dets, gts)):
        total_gt += int((g[:, 4] == cls).sum()) if g.size else 0
        if d.size:
            for row in d[d[:, 5] == cls]:
                records.append((float(row[4]), i, row))
    if total_gt == 0:
        return None
    records.sort(key=lambda r: -r[0])
    matched = [np.zeros(len(g), bool) for g in gts]
    tp = np.zeros(len(records), bool)
    for di, (_s, img, box) in enumerate(records):
        g = gts[img]
        best, best_iou = -1, thresh
        for gi in range(len(g)):
            if g[gi, 4] != cls or matched[img][gi]:
                continue
            v = iou(box, g[gi])
            if v >= best_iou:
                best_iou, best = v, gi
        if best >= 0:
            matched[img][best] = True
            tp[di] = True
    cum_tp = np.cumsum(tp)
    recall = cum_tp / total_gt
    precision = cum_tp / np.arange(1, len(records) + 1)
    ap = 0.0
    for r in np.linspace(0, 1, 101):
        mask = recall >= r
        ap += precision[mask].max() if mask.any() else 0.0
    return ap / 101.0


def mean_ap(
    dets: Sequence[np.ndarray],
    gts: Sequence[np.ndarray],
    num_classes: int,
    thresh: float = 0.5,
) -> float:
    aps = [average_precision(dets, gts, c, thresh) for c in range(num_classes)]
    aps = [a for a in aps if a is not None]
    return float(np.mean(aps)) if aps else 0.0


def evaluate_detector(det_params, images: int = 64, seed: int = 0xE7A1) -> float:
    """mAP@0.5 of the detector over a ShapeWorld split (same split family
    as the Rust eval set when seed = 0xE7A1)."""
    import jax
    import jax.numpy as jnp

    from . import dataset as D
    from . import detector as det

    fwd = jax.jit(lambda i: det.forward(det_params, i)[0])
    dets, gts = [], []
    for start in range(0, images, 32):
        cnt = min(32, images - start)
        imgs, boxes = D.batch(seed, start, cnt)
        heads = fwd(jnp.asarray(imgs))
        decoded = np.asarray(det.decode_head(heads))
        for i in range(cnt):
            d = decoded[i]
            d = d[d[:, 4] >= 0.05]
            dets.append(nms(d))
            gts.append(boxes[i])
    return mean_ap(dets, gts, det.NUM_CLASSES)
