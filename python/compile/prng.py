"""SplitMix64 — the cross-language deterministic PRNG used by ShapeWorld.

This module is the *specification*: the Rust twin (`rust/src/util/prng.rs`)
implements the exact same algorithm, and `artifacts/golden/prng.json`
(emitted by aot.py) pins the first outputs of several seeds so both sides
are checked against the same golden values.

Algorithm (Steele, Lea, Flood — "Fast Splittable Pseudorandom Number
Generators", OOPSLA'14), 64-bit state, all arithmetic mod 2^64:

    state += 0x9E3779B97F4A7C15
    z  = state
    z  = (z ^ (z >> 30)) * 0xBF58476D1CE4E5B9
    z  = (z ^ (z >> 27)) * 0x94D049BB133111EB
    out = z ^ (z >> 31)

Derived draws (must match Rust bit-for-bit):
  * ``next_u64``   — raw output.
  * ``next_f32``   — ``(next_u64 >> 40) / 2**24`` as f32 in [0, 1).
  * ``next_range(lo, hi)`` — ``lo + next_u64 % (hi - lo)`` (hi exclusive).
    Modulo bias is irrelevant here and keeping the naive form makes the
    cross-language contract trivial.
"""

from __future__ import annotations

MASK64 = (1 << 64) - 1
GAMMA = 0x9E3779B97F4A7C15
MIX1 = 0xBF58476D1CE4E5B9
MIX2 = 0x94D049BB133111EB


class SplitMix64:
    """Deterministic 64-bit PRNG; see module docstring for the contract."""

    __slots__ = ("state",)

    def __init__(self, seed: int) -> None:
        self.state = seed & MASK64

    def next_u64(self) -> int:
        self.state = (self.state + GAMMA) & MASK64
        z = self.state
        z = ((z ^ (z >> 30)) * MIX1) & MASK64
        z = ((z ^ (z >> 27)) * MIX2) & MASK64
        return z ^ (z >> 31)

    def next_f32(self) -> float:
        """Uniform f32 in [0, 1) with 24 bits of precision."""
        import numpy as np

        return float(np.float32(self.next_u64() >> 40) / np.float32(1 << 24))

    def next_range(self, lo: int, hi: int) -> int:
        """Uniform integer in [lo, hi). Requires hi > lo."""
        assert hi > lo, "next_range needs a non-empty range"
        return lo + self.next_u64() % (hi - lo)

    def fork(self) -> "SplitMix64":
        """Derive an independent stream (used for per-image streams)."""
        return SplitMix64(self.next_u64())
