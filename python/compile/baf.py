"""The Back-and-Forth (BaF) predictor (paper §3.3, Fig. 2).

Backward half: inverse BN of the C received channels, then a small
trainable deconvolution network — four 3x3 conv layers with PReLU
activations (identity on the last), the first preceded by a 2x nearest
upsample to bridge the stride-2 resolution gap — producing X-tilde, an
estimate of *all* Q input channels of the split layer.

Forward half: the split layer's own frozen pre-trained conv + BN applied
to X-tilde, producing Z-tilde — estimates of all P BN-output channels.
At export time the forward half runs through the L1 Pallas conv_bn kernel
so it lowers into the same HLO artifact.

Only the deconv-net (and its PReLU slopes) is trainable; the base
detector is never retrained — the paper's central deployment claim.
"""

from __future__ import annotations

from typing import Dict, Sequence

import jax
import jax.numpy as jnp

from . import detector
from . import layers as L
from .kernels import conv_bn as kconv

# Deconv-net widths (paper: 4 conv layers; ours sized for Q=32 outputs).
HIDDEN = (48, 48, 32)


def init(key, c: int) -> Dict:
    """Initialize a BaF deconv-net taking C input channels."""
    k1, k2, k3, k4 = jax.random.split(key, 4)
    q = detector.Q_CHANNELS
    return {
        "c1": L.conv_init(k1, 3, 3, c, HIDDEN[0]),
        "p1": L.prelu_init(HIDDEN[0]),
        "c2": L.conv_init(k2, 3, 3, HIDDEN[0], HIDDEN[1]),
        "p2": L.prelu_init(HIDDEN[1]),
        "c3": L.conv_init(k3, 3, 3, HIDDEN[1], HIDDEN[2]),
        "p3": L.prelu_init(HIDDEN[2]),
        "c4": L.conv_init(k4, 3, 3, HIDDEN[2], q),  # identity activation
    }


def backward_predict(
    baf_params: Dict, z_hat_c: jnp.ndarray, split_bn: Dict, sel: Sequence[int]
) -> jnp.ndarray:
    """z-hat_C (N,16,16,C) -> X-tilde (N,32,32,Q): the backward half.

    ``sel`` are the (static) indices of the transmitted channels; the
    inverse BN uses the split layer's per-channel parameters restricted to
    that subset.
    """
    sel = jnp.asarray(sel, jnp.int32)
    sub_bn = {k: split_bn[k][sel] for k in ("gamma", "beta", "mean", "var")}
    u = L.bn_inverse(z_hat_c, sub_bn)
    h = L.upsample2x(u)
    h = L.prelu(L.conv2d(h, baf_params["c1"]["w"]), baf_params["p1"])
    h = L.prelu(L.conv2d(h, baf_params["c2"]["w"]), baf_params["p2"])
    h = L.prelu(L.conv2d(h, baf_params["c3"]["w"]), baf_params["p3"])
    return L.conv2d(h, baf_params["c4"]["w"])  # identity activation


def forward_predict(
    det_params: Dict, x_tilde: jnp.ndarray, use_pallas: bool = False
) -> jnp.ndarray:
    """X-tilde -> Z-tilde via the frozen split-layer conv + BN."""
    p = det_params[detector.SPLIT]
    bn = p["bn"]
    if use_pallas:
        return kconv.conv3x3s2_bn(
            x_tilde, p["conv"]["w"], bn["gamma"], bn["beta"], bn["mean"], bn["var"]
        )
    u = L.conv2d(x_tilde, p["conv"]["w"], 2)
    return L.bn_apply(u, bn)


def predict(
    baf_params: Dict,
    det_params: Dict,
    z_hat_c: jnp.ndarray,
    sel: Sequence[int],
    use_pallas: bool = False,
) -> jnp.ndarray:
    """Full BaF prediction: decoded subset -> Z-tilde (all P channels)."""
    bn = det_params[detector.SPLIT]["bn"]
    x_tilde = backward_predict(baf_params, z_hat_c, bn, sel)
    return forward_predict(det_params, x_tilde, use_pallas=use_pallas)


def charbonnier(a: jnp.ndarray, b: jnp.ndarray, eps: float = 1e-3) -> jnp.ndarray:
    """Eq. 7 loss: sum of sqrt((a-b)^2 + eps^2) over all elements."""
    d = a - b
    return jnp.sum(jnp.sqrt(d * d + eps * eps))
