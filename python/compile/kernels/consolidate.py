"""Pallas kernel: Eq. 6 consolidation of BaF-predicted channels.

For each transmitted channel p < C the cloud holds two candidate values
per element: the BaF prediction z-tilde and the decoded bin index q. The
paper's case split (keep z-tilde when it re-quantizes to the same bin,
else snap to the nearest boundary of the decoded bin) is algebraically a
clip of z-tilde to the decoded bin's interval

    [m + (q - 1/2) * step,  m + (q + 1/2) * step],
    step = (M - m) / (2^n - 1)

which is what the kernel computes — one fused VPU pass per channel, no
separate re-quantization of z-tilde. Grid: one program per channel with a
(1, H, W) block, same schedule as the quantize kernel.

Always interpret=True (see quantize.py).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(zt_ref, q_ref, mm_ref, out_ref, *, levels: float):
    zt = zt_ref[...]
    q = q_ref[...].astype(jnp.float32)
    m = mm_ref[0, 0]
    mx = mm_ref[0, 1]
    span = mx - m
    step = jnp.where(span > 0, span, 1.0) / levels
    lo = m + (q - 0.5) * step
    hi = m + (q + 0.5) * step
    out = jnp.clip(zt, lo, hi)
    out_ref[...] = jnp.where(span > 0, out, m)


@functools.partial(jax.jit, static_argnames=("n",))
def consolidate(
    z_tilde: jnp.ndarray, q: jnp.ndarray, minmax: jnp.ndarray, n: int
) -> jnp.ndarray:
    """Consolidate (C,H,W) BaF predictions against decoded bins.

    Matches ref.consolidate_ref elementwise.
    """
    c, h, w = z_tilde.shape
    levels = float(2**n - 1)
    return pl.pallas_call(
        functools.partial(_kernel, levels=levels),
        grid=(c,),
        in_specs=[
            pl.BlockSpec((1, h, w), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, h, w), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, 2), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((1, h, w), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((c, h, w), jnp.float32),
        interpret=True,
    )(z_tilde, q, minmax)
