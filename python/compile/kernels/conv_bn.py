"""Pallas kernel: the split layer's 3x3 stride-2 conv + folded BN.

This is the compute hot-spot of the whole pipeline: it runs once on the
edge (frontend, producing Z) and once per request in the cloud (the
*forward* half of BaF prediction, §3.3, turning the deconv-net output
X-tilde into Z-tilde with the frozen pre-trained weights).

TPU mapping (§Hardware-Adaptation): instead of a CUDA threadblock per
output tile, the kernel is written as 9 shifted MXU matmuls — for each of
the 3x3 taps (ki,kj) the stride-2 slice of the padded input, shaped
(Ho*Wo, Cin), is multiplied into w[ki,kj] of shape (Cin, Cout) and
accumulated. BN is folded into the matmul epilogue as a per-Cout scale and
shift computed from (gamma, beta, mean, var), so the kernel writes the BN
output directly — this is exactly the conv+BN fusion the serving path
needs, and it keeps the accumulator in VMEM for the whole channel block.

Grid: one program per batch element (the 33x33x32 padded input plus the
16x16x64 accumulator are a few hundred KiB — comfortably VMEM-resident).

Always interpret=True (see quantize.py).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BN_EPS = 1e-5


def _kernel(x_ref, w_ref, scale_ref, shift_ref, o_ref, *, ho: int, wo: int):
    x = x_ref[0]  # (Hp, Wp, Cin) padded input
    w = w_ref[...]  # (3, 3, Cin, Cout)
    cout = w.shape[3]
    acc = jnp.zeros((ho, wo, cout), jnp.float32)
    for ki in range(3):
        for kj in range(3):
            # stride-2 slice of the padded input for this tap:
            # rows ki, ki+2, ..., ki+2*(ho-1)
            tap = jax.lax.slice(
                x,
                (ki, kj, 0),
                (ki + 2 * (ho - 1) + 1, kj + 2 * (wo - 1) + 1, x.shape[2]),
                (2, 2, 1),
            )  # (ho, wo, cin)
            acc += jnp.dot(
                tap, w[ki, kj], preferred_element_type=jnp.float32
            )  # (ho, wo, cout)
    # BN folded as epilogue: scale/shift precomputed outside the kernel.
    o_ref[0] = acc * scale_ref[...] + shift_ref[...]


@jax.jit
def conv3x3s2_bn(
    x: jnp.ndarray,
    w: jnp.ndarray,
    gamma: jnp.ndarray,
    beta: jnp.ndarray,
    mean: jnp.ndarray,
    var: jnp.ndarray,
) -> jnp.ndarray:
    """SAME 3x3 stride-2 conv + inference BN. x: (N,H,W,Cin), w: HWIO.

    H and W must be even (true everywhere in this network). Matches
    ref.conv_bn_ref(x, w, ..., stride=2).
    """
    n, h, wdt, cin = x.shape
    cout = w.shape[3]
    ho, wo = h // 2, wdt // 2
    # SAME for even extents with k=3, s=2: pad 0 before, 1 after.
    xp = jnp.pad(x, ((0, 0), (0, 1), (0, 1), (0, 0)))
    hp, wp = h + 1, wdt + 1
    inv = jax.lax.rsqrt(var + BN_EPS)
    scale = gamma * inv  # (Cout,)
    shift = beta - mean * scale  # (Cout,)
    return pl.pallas_call(
        functools.partial(_kernel, ho=ho, wo=wo),
        grid=(n,),
        in_specs=[
            pl.BlockSpec((1, hp, wp, cin), lambda i: (i, 0, 0, 0)),
            pl.BlockSpec((3, 3, cin, cout), lambda i: (0, 0, 0, 0)),
            pl.BlockSpec((cout,), lambda i: (0,)),
            pl.BlockSpec((cout,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((1, ho, wo, cout), lambda i: (i, 0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((n, ho, wo, cout), jnp.float32),
        interpret=True,
    )(xp, w, scale, shift)
