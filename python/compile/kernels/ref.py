"""Pure-jnp oracles for every Pallas kernel (the correctness contract).

Each function here is the *definition* of what the corresponding kernel in
this package must compute; pytest (python/tests/test_kernels.py) asserts
allclose between kernel and oracle over hypothesis-generated shapes/values,
and aot.py embeds golden vectors for the Rust side to re-check.

Layout note: quantization/consolidation oracles operate channel-major
(C, H, W) — one quantizer per channel (Eq. 4) — matching both the Pallas
grid (one program per channel) and the Rust hot-path layout.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

F16_SAFE_MIN = -65504.0
F16_SAFE_MAX = 65504.0


def minmax_f16(z: jnp.ndarray):
    """Per-channel min/max of (C,H,W), rounded to f16 precision (§3.2).

    The paper transmits m_p and M_p as 16-bit floats (C*32 bits of side
    info); rounding happens *before* quantization so encoder and decoder
    use bit-identical quantizer parameters.
    """
    m = jnp.min(z, axis=(1, 2))
    mx = jnp.max(z, axis=(1, 2))
    m = jnp.clip(m, F16_SAFE_MIN, F16_SAFE_MAX).astype(jnp.float16).astype(jnp.float32)
    mx = (
        jnp.clip(mx, F16_SAFE_MIN, F16_SAFE_MAX)
        .astype(jnp.float16)
        .astype(jnp.float32)
    )
    # f16 rounding may move m above the true min (and M below the true max);
    # quantization clips, so this only costs at most half a bin at the edges,
    # exactly as in the paper's pipeline.
    return m, mx


def quantize_ref(z: jnp.ndarray, n: int):
    """Eq. 4: per-channel n-bit uniform scalar quantization of (C,H,W).

    Returns (q int32 in [0, 2^n-1], minmax (C,2) f32 holding f16-rounded
    m_p, M_p). Constant channels (M == m) quantize to all-zeros.
    """
    m, mx = minmax_f16(z)
    span = mx - m
    safe = jnp.where(span > 0, span, 1.0)
    levels = float(2**n - 1)
    q = jnp.round((z - m[:, None, None]) / safe[:, None, None] * levels)
    q = jnp.clip(q, 0.0, levels).astype(jnp.int32)
    q = jnp.where(span[:, None, None] > 0, q, 0)
    return q, jnp.stack([m, mx], axis=-1)


def dequantize_ref(q: jnp.ndarray, minmax: jnp.ndarray, n: int) -> jnp.ndarray:
    """Eq. 5: inverse quantization back to f32 (C,H,W)."""
    m = minmax[:, 0][:, None, None]
    mx = minmax[:, 1][:, None, None]
    levels = float(2**n - 1)
    return q.astype(jnp.float32) / levels * (mx - m) + m


def consolidate_ref(
    z_tilde: jnp.ndarray, q: jnp.ndarray, minmax: jnp.ndarray, n: int
) -> jnp.ndarray:
    """Eq. 6: consolidation of BaF-predicted transmitted channels.

    For each element of the C transmitted channels we have the decoded bin
    index q and the BaF prediction z_tilde. If z_tilde falls in bin q it is
    kept; otherwise it is clamped to the nearest boundary of bin q — i.e.
    the closest value consistent with what the encoder transmitted. Bin k
    covers [m + (k-1/2)*step, m + (k+1/2)*step] with
    step = (M-m)/(2^n - 1), so the whole case split in Eq. 6 is a clip.
    Constant channels (M == m) are pinned to m.
    """
    m = minmax[:, 0][:, None, None]
    mx = minmax[:, 1][:, None, None]
    levels = float(2**n - 1)
    span = mx - m
    step = jnp.where(span > 0, span, 1.0) / levels
    qf = q.astype(jnp.float32)
    lo = m + (qf - 0.5) * step
    hi = m + (qf + 0.5) * step
    out = jnp.clip(z_tilde, lo, hi)
    return jnp.where(span > 0, out, m)


def corr_ref(z: jnp.ndarray, x: jnp.ndarray) -> jnp.ndarray:
    """Eq. 2 inner statistic: |pearson| between every row pair of z and x.

    z: (P, N) vectorized BN-output channels; x: (S, N) vectorized polyphase
    downsamplings of the input channels (S = 4*Q). Returns (P, S) absolute
    correlation coefficients. Zero-variance rows yield 0 (a constant
    channel carries no predictive signal).
    """
    zc = z - jnp.mean(z, axis=1, keepdims=True)
    xc = x - jnp.mean(x, axis=1, keepdims=True)
    num = zc @ xc.T
    zn = jnp.linalg.norm(zc, axis=1)
    xn = jnp.linalg.norm(xc, axis=1)
    denom = zn[:, None] * xn[None, :]
    return jnp.where(denom > 0, jnp.abs(num) / jnp.where(denom > 0, denom, 1.0), 0.0)


def gram_ref(z: jnp.ndarray, x: jnp.ndarray) -> jnp.ndarray:
    """The raw Gram matrix z @ x.T — what the Pallas corr kernel computes.

    (Centering/normalization are rank-1 corrections applied outside; see
    kernels/corr.py and DESIGN.md §Hardware-Adaptation.)
    """
    return z @ x.T


def conv_bn_ref(
    x: jnp.ndarray, w: jnp.ndarray, gamma, beta, mean, var, stride: int = 2
) -> jnp.ndarray:
    """3x3 SAME conv (NHWC x HWIO) + inference BN — the split layer."""
    u = jax.lax.conv_general_dilated(
        x,
        w,
        window_strides=(stride, stride),
        padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )
    inv = jax.lax.rsqrt(var + 1e-5)
    return (u - mean) * inv * gamma + beta
