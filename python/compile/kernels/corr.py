"""Pallas kernel: Eq. 2 correlation statistic as MXU-tiled Gram matmul.

The GPU-minded formulation of Eq. 2 is a per-(p,q,s) reduction. On TPU the
right shape is a Gram matrix: with Z in (P, N) (vectorized BN-output
channels) and X in (S, N) (S = 4*Q vectorized polyphase downsamplings of
the layer input), the O(P*S*N) work is G = Z @ X^T — a classic tiled
matmul the MXU systolic array eats — while means and norms are O((P+S)*N)
rank-1 corrections done outside:

    pearson(p,s) = (G[p,s] - N * mean_z[p] * mean_x[s])
                   / (||z_p - mean|| * ||x_s - mean||)

The kernel below is the standard three-axis blocked matmul with an
accumulation grid over N; block sizes adapt to the operand shapes (tests
sweep ragged shapes via padding in the wrapper).

Always interpret=True (see quantize.py).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _matmul_kernel(z_ref, x_ref, o_ref):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        z_ref[...], x_ref[...].T, preferred_element_type=jnp.float32
    )


def _pick_block(dim: int, target: int) -> int:
    """Largest divisor of ``dim`` not exceeding ``target``."""
    b = min(dim, target)
    while dim % b != 0:
        b -= 1
    return b


@jax.jit
def gram(z: jnp.ndarray, x: jnp.ndarray) -> jnp.ndarray:
    """G = z @ x.T via the blocked Pallas kernel. z: (P,N), x: (S,N)."""
    p, n = z.shape
    s, n2 = x.shape
    assert n == n2, "row-vector lengths must agree"
    bp = _pick_block(p, 32)
    bs = _pick_block(s, 64)
    bn = _pick_block(n, 128)
    return pl.pallas_call(
        _matmul_kernel,
        grid=(p // bp, s // bs, n // bn),
        in_specs=[
            pl.BlockSpec((bp, bn), lambda i, j, k: (i, k)),
            pl.BlockSpec((bs, bn), lambda i, j, k: (j, k)),
        ],
        out_specs=pl.BlockSpec((bp, bs), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((p, s), jnp.float32),
        interpret=True,
    )(z, x)


@jax.jit
def abs_pearson(z: jnp.ndarray, x: jnp.ndarray) -> jnp.ndarray:
    """Full Eq. 2 statistic: |pearson| between all row pairs, (P, S).

    Gram matrix on the (Pallas) MXU path, rank-1 corrections in plain jnp.
    Matches ref.corr_ref.
    """
    n = z.shape[1]
    g = gram(z, x)
    mz = jnp.mean(z, axis=1)
    mx = jnp.mean(x, axis=1)
    num = g - float(n) * mz[:, None] * mx[None, :]
    zn = jnp.sqrt(jnp.maximum(jnp.sum(z * z, axis=1) - float(n) * mz * mz, 0.0))
    xn = jnp.sqrt(jnp.maximum(jnp.sum(x * x, axis=1) - float(n) * mx * mx, 0.0))
    denom = zn[:, None] * xn[None, :]
    return jnp.where(denom > 0, jnp.abs(num) / jnp.where(denom > 0, denom, 1.0), 0.0)


def polyphase(x_img: jnp.ndarray) -> jnp.ndarray:
    """(H, W, Q) layer-l input -> (4*Q, H*W/4) polyphase row vectors.

    The four stride-2 offsets s = (0,0),(0,1),(1,0),(1,1) of §3.1 — each
    downsampled X_q matches Z_p's resolution. Row order: s-major, then q,
    i.e. row index = s * Q + q.
    """
    h, w, q = x_img.shape
    rows = []
    for si in range(2):
        for sj in range(2):
            sub = x_img[si::2, sj::2, :]  # (h/2, w/2, q)
            rows.append(sub.reshape(-1, q).T)  # (q, h*w/4)
    return jnp.concatenate(rows, axis=0)
