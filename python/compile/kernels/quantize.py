"""Pallas kernel: fused per-channel min/max + n-bit quantization (Eq. 4).

Grid layout: one program per channel (the quantizer in Eq. 4 is strictly
per-channel), each program owning a (1, H, W) VMEM block. The min/max
reduction, f16 side-info rounding, scale computation and rounding all
happen inside the same block — on TPU this means a single HBM->VMEM read
of the channel and two writes (q and the 2-element minmax), instead of the
three passes a naive min / max / quantize composition would do.

TPU notes (§Hardware-Adaptation): H*W here is 16*16 = 256 f32 = 1 KiB per
channel — far under VMEM; the lane dimension (W) is below 128 so interpret
mode is the only functional target, but the BlockSpec already expresses
the HBM<->VMEM schedule a Mosaic build would use with W padded to 128.

Always invoked with interpret=True: real TPU lowering emits a Mosaic
custom-call that the CPU PJRT plugin cannot execute.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import F16_SAFE_MAX, F16_SAFE_MIN


def _kernel(z_ref, q_ref, mm_ref, *, levels: float):
    z = z_ref[...]  # (1, H, W)
    m = jnp.clip(jnp.min(z), F16_SAFE_MIN, F16_SAFE_MAX)
    mx = jnp.clip(jnp.max(z), F16_SAFE_MIN, F16_SAFE_MAX)
    # Round the side info to f16 BEFORE quantizing so encoder and decoder
    # agree bit-for-bit (the paper transmits m, M as 16-bit floats).
    m = m.astype(jnp.float16).astype(jnp.float32)
    mx = mx.astype(jnp.float16).astype(jnp.float32)
    span = mx - m
    safe = jnp.where(span > 0, span, 1.0)
    q = jnp.round((z - m) / safe * levels)
    q = jnp.clip(q, 0.0, levels).astype(jnp.int32)
    q_ref[...] = jnp.where(span > 0, q, 0)
    mm_ref[...] = jnp.stack([m, mx]).reshape(1, 2)


@functools.partial(jax.jit, static_argnames=("n",))
def quantize(z: jnp.ndarray, n: int):
    """Quantize (C, H, W) f32 to n bits per channel.

    Returns (q int32 (C,H,W), minmax f32 (C,2)); matches ref.quantize_ref.
    """
    c, h, w = z.shape
    levels = float(2**n - 1)
    return pl.pallas_call(
        functools.partial(_kernel, levels=levels),
        grid=(c,),
        in_specs=[pl.BlockSpec((1, h, w), lambda i: (i, 0, 0))],
        out_specs=[
            pl.BlockSpec((1, h, w), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, 2), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((c, h, w), jnp.int32),
            jax.ShapeDtypeStruct((c, 2), jnp.float32),
        ],
        interpret=True,
    )(z)


def _dequant_kernel(q_ref, mm_ref, z_ref, *, levels: float):
    q = q_ref[...].astype(jnp.float32)
    m = mm_ref[0, 0]
    mx = mm_ref[0, 1]
    z_ref[...] = q / levels * (mx - m) + m


@functools.partial(jax.jit, static_argnames=("n",))
def dequantize(q: jnp.ndarray, minmax: jnp.ndarray, n: int) -> jnp.ndarray:
    """Eq. 5 inverse quantization; matches ref.dequantize_ref."""
    c, h, w = q.shape
    levels = float(2**n - 1)
    return pl.pallas_call(
        functools.partial(_dequant_kernel, levels=levels),
        grid=(c,),
        in_specs=[
            pl.BlockSpec((1, h, w), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, 2), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((1, h, w), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((c, h, w), jnp.float32),
        interpret=True,
    )(q, minmax)
