"""Offline channel-selection statistics (paper §3.1, Eq. 2–3).

Computes the absolute pairwise correlations rho(p, q) between every
BN-output channel Z_p and the four polyphase (stride-2 offset)
downsamplings of every split-layer input channel X_q, averaged over a
calibration set, then greedily orders the P channels by total correlation
(Eq. 3, repeated over the remaining channels).

The Gram-matrix heavy lifting goes through the L1 Pallas corr kernel
(kernels/corr.py); everything else is rank-1 bookkeeping.

The resulting ordering ships to the Rust side via
artifacts/channel_stats.json and is *static* at serving time — exactly as
in the paper, selection adds zero request-path complexity.
"""

from __future__ import annotations

from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from . import dataset as D
from . import detector as det
from .kernels import corr as KC


def correlation_matrix(det_params: Dict, images: int = 256, seed: int = 0xC0FFEE):
    """Mean-over-images rho matrix, shape (P, 4, Q).

    Eq. 2 averages the absolute correlation over the four offsets s; we
    keep the (P, 4, Q) tensor so tests can check each slice, and reduce to
    (P, Q) with .mean(axis=1).
    """
    fe = jax.jit(lambda img: det.frontend_with_x(det_params, img))
    p, q = det.P_CHANNELS, det.Q_CHANNELS
    acc = np.zeros((p, 4 * q), np.float64)
    done = 0
    for start in range(0, images, 32):
        cnt = min(32, images - start)
        imgs, _ = D.batch(dataset_seed=seed, start=start, count=cnt)
        z, x = fe(jnp.asarray(imgs))
        for i in range(cnt):
            zv = z[i].reshape(-1, p).T  # (P, 256)
            xv = KC.polyphase(x[i])  # (4Q, 256)
            acc += np.asarray(KC.abs_pearson(zv, xv), np.float64)
            done += 1
    rho = (acc / done).reshape(p, 4, q).astype(np.float32)
    return rho


def greedy_order(rho: np.ndarray) -> List[int]:
    """Eq. 3 selection, repeated over remaining channels.

    rho: (P, 4, Q). Score_p = sum_q mean_s rho[p, s, q]; channels are
    picked highest-score-first. (With a static rho this equals a
    descending sort, but we keep the paper's iterative form.)
    """
    score = rho.mean(axis=1).sum(axis=1).astype(np.float64)
    remaining = set(range(rho.shape[0]))
    order: List[int] = []
    while remaining:
        best = max(remaining, key=lambda p: (score[p], -p))
        order.append(int(best))
        remaining.discard(best)
    return order


def channel_stats(det_params: Dict, images: int = 256) -> Dict:
    """Everything the Rust side needs, JSON-serializable.

    * order: the greedy channel ranking (take the first C for any C);
    * rho_total: per-channel total-correlation scores (for ablations);
    * variance: per-channel Z variance over the calibration set (the
      'variance' selection ablation);
    * bn: split-layer BN parameters (inverse-BN on the cloud, §3.3);
    * global minmax stats of Z (container sanity checks).
    """
    rho = correlation_matrix(det_params, images=images)
    order = greedy_order(rho)

    z_pool = _z_sample(det_params, count=128)
    var = z_pool.reshape(-1, det.P_CHANNELS).var(axis=0)
    var_order = [int(i) for i in np.argsort(-var)]

    bn = det_params[det.SPLIT]["bn"]
    return {
        "split_layer": det.SPLIT,
        "p_channels": det.P_CHANNELS,
        "q_channels": det.Q_CHANNELS,
        "order": order,
        "rho_total": [float(v) for v in rho.mean(axis=1).sum(axis=1)],
        "variance_order": var_order,
        "variance": [float(v) for v in var],
        "bn": {k: [float(v) for v in np.asarray(bn[k])] for k in bn},
        "z_min": float(z_pool.min()),
        "z_max": float(z_pool.max()),
        "calibration_images": images,
    }


def _z_sample(det_params: Dict, count: int = 128) -> np.ndarray:
    fe = jax.jit(lambda img: det.frontend(det_params, img))
    imgs, _ = D.batch(dataset_seed=0x5EED, start=0, count=count)
    return np.asarray(fe(jnp.asarray(imgs)))
