"""AOT export: train (cached) -> lower to HLO text -> artifacts/.

Run as ``python -m compile.aot --out-dir ../artifacts`` (see Makefile).

Interchange format is HLO *text*, not serialized HloModuleProto: jax >=
0.5 emits protos with 64-bit instruction ids which the xla crate's
xla_extension 0.5.1 rejects (proto.id() <= INT_MAX); the text parser
reassigns ids and round-trips cleanly. See /opt/xla-example/README.md.

Everything the Rust runtime needs lands in artifacts/:
  *.hlo.txt            one per (stage, variant, batch) — weights baked in
  manifest.json        artifact index + model geometry (grid, anchors, ...)
  channel_stats.json   Eq. 2–3 channel ordering + split-layer BN params
  golden/              cross-language golden vectors (npy + json)
  cache/weights.npz    trained parameters (build cache only)
"""

from __future__ import annotations

import argparse
import json
import os
import time
from typing import Dict, List, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import baf as B
from . import dataset as D
from . import detector as det
from . import layers as L
from . import prng, stats, train
from .kernels import consolidate as kcons
from .kernels import ref as KR

# The (C, n) grid of BaF models to train/export. C sweep at n=8 mirrors the
# paper's Fig. 3 ({8..128} of 256 == {4..64} of 64); the n sweep at C=16
# mirrors Fig. 4 (C=64 of 256 == quarter of the channels).
C_SWEEP = (4, 8, 16, 32, 64)
N_SWEEP = (2, 3, 4, 5, 6, 7, 8)
C_FOR_N_SWEEP = 16
BATCHES = (1, 8)


def to_hlo_text(lowered) -> str:
    """Lowered jax function -> XLA HLO text (ids reassigned by parser)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    # print_large_constants: the baked weights ARE the model — without this
    # flag the printer elides them as '{...}' and the Rust-side parser would
    # load garbage.
    return comp.as_hlo_text(print_large_constants=True)


def export(fn, example_args: Sequence[jnp.ndarray], path: str) -> None:
    lowered = jax.jit(fn).lower(*example_args)
    text = to_hlo_text(lowered)
    with open(path, "w") as f:
        f.write(text)


# --------------------------------------------------------------------------
# Weight cache
# --------------------------------------------------------------------------
def _flatten(tree, prefix="") -> Dict[str, np.ndarray]:
    out = {}
    for k, v in tree.items():
        key = f"{prefix}{k}"
        if isinstance(v, dict):
            out.update(_flatten(v, key + "/"))
        else:
            out[key] = np.asarray(v)
    return out


def _unflatten(flat: Dict[str, np.ndarray]) -> Dict:
    tree: Dict = {}
    for key, v in flat.items():
        parts = key.split("/")
        node = tree
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = jnp.asarray(v)
    return tree


def save_weights(path: str, det_params: Dict, baf_models: Dict[Tuple[int, int], Dict]):
    flat = _flatten({"det": det_params})
    for (c, n), params in baf_models.items():
        flat.update(_flatten({f"baf_c{c}_n{n}": params}))
    np.savez(path, **flat)


def load_weights(path: str):
    data = np.load(path)
    groups: Dict[str, Dict[str, np.ndarray]] = {}
    for key in data.files:
        top, rest = key.split("/", 1)
        groups.setdefault(top, {})[rest] = data[key]
    det_params = _unflatten(groups["det"])
    baf_models = {}
    for top, flat in groups.items():
        if top.startswith("baf_c"):
            c, n = top[len("baf_") :].split("_")
            baf_models[(int(c[1:]), int(n[1:]))] = _unflatten(flat)
    return det_params, baf_models


# --------------------------------------------------------------------------
# Golden vectors (cross-language contract with the Rust side)
# --------------------------------------------------------------------------
def write_prng_golden(path: str) -> None:
    cases = []
    for seed in (0, 1, 42, 0xDEADBEEF, (1 << 64) - 1):
        r = prng.SplitMix64(seed)
        u64s = [str(r.next_u64()) for _ in range(8)]
        r2 = prng.SplitMix64(seed)
        f32s = [r2.next_f32() for _ in range(8)]
        r3 = prng.SplitMix64(seed)
        ranges = [r3.next_range(10, 29) for _ in range(8)]
        cases.append({"seed": str(seed), "u64": u64s, "f32": f32s, "range_10_29": ranges})
    with open(path, "w") as f:
        json.dump({"cases": cases}, f, indent=1)


def write_dataset_golden(dir_: str) -> None:
    cases = []
    for idx in range(4):
        s = D.generate(dataset_seed=42, index=idx)
        cases.append(
            {
                "index": idx,
                "sum": float(np.float64(s.image.sum())),
                "nboxes": int(s.boxes.shape[0]),
                "boxes": [[float(v) for v in b] for b in s.boxes],
            }
        )
    with open(os.path.join(dir_, "dataset.json"), "w") as f:
        json.dump({"dataset_seed": 42, "cases": cases}, f, indent=1)
    np.save(os.path.join(dir_, "dataset_img0.npy"), D.generate(42, 0).image)


def write_kernel_goldens(dir_: str) -> None:
    rng = np.random.default_rng(1234)
    z = rng.normal(size=(16, 16, 16)).astype(np.float32) * 2.0 + 0.3
    for n in (2, 4, 8):
        q, mm = KR.quantize_ref(jnp.asarray(z), n)
        zh = KR.dequantize_ref(q, mm, n)
        zt = jnp.asarray(
            z + rng.normal(size=z.shape).astype(np.float32) * 0.2
        )
        cons = KR.consolidate_ref(zt, q, mm, n)
        np.save(os.path.join(dir_, f"quant_n{n}_q.npy"), np.asarray(q, np.int32))
        np.save(os.path.join(dir_, f"quant_n{n}_mm.npy"), np.asarray(mm))
        np.save(os.path.join(dir_, f"quant_n{n}_deq.npy"), np.asarray(zh))
        np.save(os.path.join(dir_, f"quant_n{n}_cons.npy"), np.asarray(cons))
        if n == 4:
            np.save(os.path.join(dir_, "quant_zt.npy"), np.asarray(zt))
    np.save(os.path.join(dir_, "quant_z.npy"), z)


def write_pipeline_goldens(
    dir_: str, det_params: Dict, baf_models: Dict, order: List[int]
) -> None:
    """End-to-end golden: image 0 through every stage at (C=16, n=8)."""
    img = D.generate(dataset_seed=42, index=0).image[None]
    z = np.asarray(jax.jit(lambda i: det.frontend(det_params, i))(jnp.asarray(img)))
    c, n = 16, 8
    sel = tuple(order[:c])
    zc = z[0][:, :, list(sel)]  # (16,16,C)
    zc_chw = np.transpose(zc, (2, 0, 1))
    q, mm = KR.quantize_ref(jnp.asarray(zc_chw), n)
    zhat = KR.dequantize_ref(q, mm, n)
    zhat_nhwc = np.transpose(np.asarray(zhat), (1, 2, 0))[None]
    z_tilde = np.asarray(
        jax.jit(
            lambda zc_: B.predict(baf_models[(c, n)], det_params, zc_, sel)
        )(jnp.asarray(zhat_nhwc))
    )
    # consolidation + scatter (the Rust hot path repeats this)
    zt_sel = np.transpose(z_tilde[0][:, :, list(sel)], (2, 0, 1))
    cons = np.asarray(KR.consolidate_ref(jnp.asarray(zt_sel), q, mm, n))
    z_final = z_tilde.copy()
    z_final[0][:, :, list(sel)] = np.transpose(cons, (1, 2, 0))
    head = np.asarray(
        jax.jit(lambda zt: det.tail(det_params, zt))(jnp.asarray(z_final))
    )
    mono = np.asarray(
        jax.jit(lambda i: det.forward(det_params, i)[0])(jnp.asarray(img))
    )
    np.save(os.path.join(dir_, "pipe_img.npy"), img[0])
    np.save(os.path.join(dir_, "pipe_z.npy"), z[0])
    np.save(os.path.join(dir_, "pipe_q.npy"), np.asarray(q, np.int32))
    np.save(os.path.join(dir_, "pipe_mm.npy"), np.asarray(mm))
    np.save(os.path.join(dir_, "pipe_zhat.npy"), zhat_nhwc[0])
    np.save(os.path.join(dir_, "pipe_ztilde.npy"), z_tilde[0])
    np.save(os.path.join(dir_, "pipe_zfinal.npy"), z_final[0])
    np.save(os.path.join(dir_, "pipe_head.npy"), head[0])
    np.save(os.path.join(dir_, "pipe_mono_head.npy"), mono[0])
    with open(os.path.join(dir_, "pipe_meta.json"), "w") as f:
        json.dump({"c": c, "n": n, "sel": list(sel), "dataset_seed": 42, "index": 0}, f)


# --------------------------------------------------------------------------
# Main export
# --------------------------------------------------------------------------
def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--det-steps", type=int, default=700)
    ap.add_argument("--baf-steps", type=int, default=350)
    ap.add_argument("--calib-images", type=int, default=192)
    ap.add_argument("--force-train", action="store_true")
    args = ap.parse_args()

    out = os.path.abspath(args.out_dir)
    golden = os.path.join(out, "golden")
    cache = os.path.join(out, "cache")
    for d in (out, golden, cache):
        os.makedirs(d, exist_ok=True)

    t0 = time.time()
    weights_path = os.path.join(cache, "weights.npz")
    pairs = [(c, 8) for c in C_SWEEP] + [
        (C_FOR_N_SWEEP, n) for n in N_SWEEP if n != 8
    ]

    if os.path.exists(weights_path) and not args.force_train:
        print(f"[aot] loading cached weights from {weights_path}")
        det_params, baf_models = load_weights(weights_path)
        st = json.load(open(os.path.join(out, "channel_stats.json")))
        order = st["order"]
    else:
        det_params = train.train_detector(steps=args.det_steps)
        print(f"[aot] channel statistics over {args.calib_images} images ...")
        st = stats.channel_stats(det_params, images=args.calib_images)
        order = st["order"]
        with open(os.path.join(out, "channel_stats.json"), "w") as f:
            json.dump(st, f, indent=1)
        z_pool = train.compute_z_pool(det_params, count=768)
        baf_models = {}
        for c, n in pairs:
            sel = tuple(order[:c])
            baf_models[(c, n)] = train.train_baf(
                det_params, sel, n, z_pool, steps=args.baf_steps
            )
        save_weights(weights_path, det_params, baf_models)
        # training-time validation (the authoritative eval lives in Rust)
        from . import evalpy

        val_map = evalpy.evaluate_detector(det_params, images=64)
        print(f"[aot] detector val mAP@0.5 (python twin) = {val_map:.4f}")
    print(f"[aot] weights ready ({time.time() - t0:.1f}s)")

    # ---- goldens ----
    write_prng_golden(os.path.join(golden, "prng.json"))
    write_dataset_golden(golden)
    write_kernel_goldens(golden)
    write_pipeline_goldens(golden, det_params, baf_models, order)
    print(f"[aot] goldens written ({time.time() - t0:.1f}s)")

    # ---- HLO export ----
    manifest: Dict = {
        "version": 1,
        "image_size": D.IMG,
        "grid": det.GRID,
        "cell": det.CELL,
        "anchors": [list(a) for a in det.ANCHORS],
        "num_classes": det.NUM_CLASSES,
        "head_channels": det.HEAD_CH,
        "p_channels": det.P_CHANNELS,
        "q_channels": det.Q_CHANNELS,
        "z_shape": list(det.Z_SHAPE),
        "leaky_slope": L.LEAKY_SLOPE,
        "artifacts": {},
    }

    def art(name: str, fn, arg_shapes: List[List[int]], extra: Dict = None):
        path = os.path.join(out, f"{name}.hlo.txt")
        examples = [jnp.zeros(s, jnp.float32) for s in arg_shapes]
        export(fn, examples, path)
        entry = {"file": f"{name}.hlo.txt", "inputs": arg_shapes}
        if extra:
            entry.update(extra)
        manifest["artifacts"][name] = entry
        print(f"[aot] exported {name} ({os.path.getsize(path) // 1024} KiB)")

    img_sz = D.IMG
    zs = det.Z_SHAPE
    for b in BATCHES:
        art(
            f"frontend_b{b}",
            lambda i: det.frontend(det_params, i),
            [[b, img_sz, img_sz, 3]],
            {"output": [b, *zs], "stage": "frontend", "batch": b},
        )
        art(
            f"tail_b{b}",
            lambda zt: det.tail(det_params, zt),
            [[b, *zs]],
            {"output": [b, det.GRID, det.GRID, det.HEAD_CH], "stage": "tail", "batch": b},
        )
        art(
            f"monolith_b{b}",
            lambda i: det.forward(det_params, i)[0],
            [[b, img_sz, img_sz, 3]],
            {
                "output": [b, det.GRID, det.GRID, det.HEAD_CH],
                "stage": "monolith",
                "batch": b,
            },
        )

    for (c, n), params in sorted(baf_models.items()):
        sel = tuple(order[:c])
        batches = BATCHES if (c, n) == (C_FOR_N_SWEEP, 8) else (1,)
        for b in batches:
            art(
                f"baf_c{c}_n{n}_b{b}",
                lambda zc, p=params, s=sel: B.predict(
                    p, det_params, zc, s, use_pallas=True
                ),
                [[b, zs[0], zs[1], c]],
                {
                    "output": [b, *zs],
                    "stage": "baf",
                    "c": c,
                    "n": n,
                    "batch": b,
                    "sel": list(sel),
                },
            )

    # Fused cloud graph (ablation E6): BaF + in-graph Eq.6 consolidation +
    # tail in a single HLO — uses the Pallas consolidate kernel.
    c, n = C_FOR_N_SWEEP, 8
    sel = tuple(order[:c])
    params = baf_models[(c, n)]

    def fused(zc, qf, mm):
        z_tilde = B.predict(params, det_params, zc, sel, use_pallas=True)
        zt_sel = jnp.transpose(z_tilde[0][:, :, jnp.asarray(sel)], (2, 0, 1))
        cons = kcons.consolidate(zt_sel, qf.astype(jnp.int32)[0], mm, n)
        z_final = z_tilde.at[0, :, :, jnp.asarray(sel)].set(cons)
        return det.tail(det_params, z_final)

    path = os.path.join(out, f"fused_c{c}_n{n}_b1.hlo.txt")
    export(
        fused,
        [
            jnp.zeros((1, zs[0], zs[1], c), jnp.float32),
            jnp.zeros((1, c, zs[0], zs[1]), jnp.float32),
            jnp.zeros((c, 2), jnp.float32),
        ],
        path,
    )
    manifest["artifacts"][f"fused_c{c}_n{n}_b1"] = {
        "file": f"fused_c{c}_n{n}_b1.hlo.txt",
        "inputs": [[1, zs[0], zs[1], c], [1, c, zs[0], zs[1]], [c, 2]],
        "output": [1, det.GRID, det.GRID, det.HEAD_CH],
        "stage": "fused",
        "c": c,
        "n": n,
        "batch": 1,
        "sel": list(sel),
    }
    print(f"[aot] exported fused_c{c}_n{n}_b1")

    with open(os.path.join(out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"[aot] done in {time.time() - t0:.1f}s -> {out}")


if __name__ == "__main__":
    main()
