"""Build-time training: the YOLO-Lite detector and the BaF predictors.

This module only ever runs inside ``make artifacts`` (aot.py); nothing
here is on the request path. Weights are cached under artifacts/cache/ so
re-running the build is a no-op.

Detector loss — standard single-scale YOLO-v3 recipe:
  * each ground-truth box is assigned to its center cell and to the anchor
    with the best (w,h)-IoU;
  * coordinate loss: squared error on (sigmoid tx - tx*, sigmoid ty - ty*)
    and on (tw - log w/aw, th - log h/ah), weight 5.0;
  * objectness: BCE, positives weight 1.0, negatives 0.5;
  * class: softmax cross-entropy on positives.

BaF loss — the paper's Charbonnier penalty (Eq. 7) between sigma(Z-tilde)
and the true post-activation Y, eps = 1e-3, with the n-bit quantizer in
the loop (models are trained per (C, n) exactly as in §4). Consolidation
(Eq. 6) is ignored during training, as in the paper.
"""

from __future__ import annotations

import functools
import time
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import baf as B
from . import dataset as D
from . import detector as det
from . import layers as L
from . import optim
from .kernels import ref as KR

LAMBDA_COORD = 5.0
LAMBDA_NOOBJ = 0.5


# --------------------------------------------------------------------------
# Target assignment (NumPy, per batch — tiny, not worth jitting)
# --------------------------------------------------------------------------
def build_targets(boxes_list: List[np.ndarray]) -> Tuple[np.ndarray, np.ndarray]:
    """Ground truth -> dense YOLO targets.

    Returns (target, mask):
      target (N, G, G, A, 5 + K): tx*, ty*, tw*, th*, 1, one-hot class
      mask   (N, G, G, A): 1.0 where a GT is assigned
    """
    n = len(boxes_list)
    g, a, k = det.GRID, det.NUM_ANCHORS, det.NUM_CLASSES
    target = np.zeros((n, g, g, a, 5 + k), np.float32)
    mask = np.zeros((n, g, g, a), np.float32)
    anchors = np.asarray(det.ANCHORS, np.float32)
    for i, boxes in enumerate(boxes_list):
        for x0, y0, x1, y1, cls in boxes:
            cx, cy = (x0 + x1) / 2, (y0 + y1) / 2
            w, h = x1 - x0, y1 - y0
            gx = min(int(cx / det.CELL), g - 1)
            gy = min(int(cy / det.CELL), g - 1)
            # anchor with best (w,h) IoU
            inter = np.minimum(w, anchors[:, 0]) * np.minimum(h, anchors[:, 1])
            union = w * h + anchors[:, 0] * anchors[:, 1] - inter
            ai = int(np.argmax(inter / union))
            target[i, gy, gx, ai, 0] = cx / det.CELL - gx
            target[i, gy, gx, ai, 1] = cy / det.CELL - gy
            target[i, gy, gx, ai, 2] = np.log(max(w, 1e-3) / anchors[ai, 0])
            target[i, gy, gx, ai, 3] = np.log(max(h, 1e-3) / anchors[ai, 1])
            target[i, gy, gx, ai, 4] = 1.0
            target[i, gy, gx, ai, 5 + int(cls)] = 1.0
            mask[i, gy, gx, ai] = 1.0
    return target, mask


def yolo_loss(params: Dict, img, target, mask):
    """Detector loss; returns (scalar, new_params-with-EMA-BN)."""
    head, new_params = det.forward(params, img, train=True)
    n = head.shape[0]
    h = head.reshape(n, det.GRID, det.GRID, det.NUM_ANCHORS, 5 + det.NUM_CLASSES)
    pxy = L.sigmoid(h[..., 0:2])
    pwh = h[..., 2:4]
    pobj = h[..., 4]
    pcls = h[..., 5:]

    m = mask[..., None]
    coord = jnp.sum(m * (pxy - target[..., 0:2]) ** 2) + jnp.sum(
        m * (pwh - target[..., 2:4]) ** 2
    )
    # BCE with logits on objectness.
    tobj = target[..., 4]
    bce = jnp.maximum(pobj, 0) - pobj * tobj + jnp.log1p(jnp.exp(-jnp.abs(pobj)))
    obj = jnp.sum(mask * bce) + LAMBDA_NOOBJ * jnp.sum((1 - mask) * bce)
    # softmax CE on positives.
    logp = jax.nn.log_softmax(pcls, axis=-1)
    cls = -jnp.sum(m * target[..., 5:] * logp)
    total = (LAMBDA_COORD * coord + obj + cls) / n
    return total, new_params


@functools.partial(jax.jit, donate_argnums=(0, 1))
def _det_step(params, opt_state, img, target, mask, lr):
    (loss, new_params), grads = jax.value_and_grad(yolo_loss, has_aux=True)(
        params, img, target, mask
    )
    upd, opt_state = optim.adam_step(params, grads, opt_state, lr=lr)
    # keep the EMA'd BN stats from the forward pass, Adam-updated weights
    for name, _c, _s in det.CFG:
        upd[name]["bn"]["mean"] = new_params[name]["bn"]["mean"]
        upd[name]["bn"]["var"] = new_params[name]["bn"]["var"]
    return upd, opt_state, loss


def train_detector(
    seed: int = 7,
    steps: int = 700,
    batch: int = 32,
    pool: int = 4096,
    log=print,
) -> Dict:
    """Train YOLO-Lite on ShapeWorld; returns final params."""
    log(f"[train] generating {pool} ShapeWorld images ...")
    imgs, boxes = D.batch(dataset_seed=0xD5EA5ED, start=0, count=pool)
    targets, masks = zip(*(build_targets([b]) for b in boxes))
    targets = np.concatenate(targets)
    masks = np.concatenate(masks)

    params = det.init(jax.random.PRNGKey(seed))
    opt_state = optim.adam_init(params)
    rng = np.random.default_rng(seed)
    t0 = time.time()
    for step in range(steps):
        idx = rng.integers(0, pool, size=batch)
        lr = 1e-3 if step < steps * 0.7 else 2e-4
        params, opt_state, loss = _det_step(
            params,
            opt_state,
            jnp.asarray(imgs[idx]),
            jnp.asarray(targets[idx]),
            jnp.asarray(masks[idx]),
            lr,
        )
        if step % 100 == 0 or step == steps - 1:
            log(f"[train] det step {step:4d} loss {float(loss):8.3f} "
                f"({time.time() - t0:5.1f}s)")
    return params


# --------------------------------------------------------------------------
# BaF training
# --------------------------------------------------------------------------
def baf_loss(baf_params, det_params, z_hat_c, y_true, sel):
    """Charbonnier(sigma(Z-tilde), Y) per Eq. 7 (normalized per element)."""
    z_tilde = B.predict(baf_params, det_params, z_hat_c, sel)
    return B.charbonnier(L.leaky_relu(z_tilde), y_true) / y_true.size


@functools.partial(jax.jit, static_argnames=("sel", "n"), donate_argnums=(0, 1))
def _baf_step(baf_params, opt_state, det_params, z_batch, sel, n, lr):
    """One Adam step; quantize/dequantize of the selected channels in-loop."""
    sel_arr = jnp.asarray(sel, jnp.int32)
    zc = z_batch[:, :, :, sel_arr]  # (B,16,16,C)
    # per-sample, per-channel quantizer: fold batch into channel axis (C,H,W)
    b, h, w, c = zc.shape
    zc_chw = jnp.transpose(zc, (0, 3, 1, 2)).reshape(b * c, h, w)
    q, mm = KR.quantize_ref(zc_chw, n)
    zhat = KR.dequantize_ref(q, mm, n).reshape(b, c, h, w).transpose(0, 2, 3, 1)
    y_true = L.leaky_relu(z_batch)
    loss, grads = jax.value_and_grad(baf_loss)(
        baf_params, det_params, zhat, y_true, sel_arr
    )
    baf_params, opt_state = optim.adam_step(baf_params, grads, opt_state, lr=lr)
    return baf_params, opt_state, loss


def train_baf(
    det_params: Dict,
    sel: Tuple[int, ...],
    n: int,
    z_pool: np.ndarray,
    seed: int = 11,
    steps: int = 400,
    batch: int = 16,
    log=print,
) -> Dict:
    """Train one BaF model for (C=len(sel), n) on precomputed Z tensors."""
    c = len(sel)
    baf_params = B.init(jax.random.PRNGKey(seed + 101 * c + n), c)
    opt_state = optim.adam_init(baf_params)
    rng = np.random.default_rng(seed)
    t0 = time.time()
    for step in range(steps):
        idx = rng.integers(0, z_pool.shape[0], size=batch)
        lr = 2e-3 if step < steps * 0.6 else 5e-4
        baf_params, opt_state, loss = _baf_step(
            baf_params,
            opt_state,
            det_params,
            jnp.asarray(z_pool[idx]),
            tuple(int(s) for s in sel),
            n,
            lr,
        )
        if step % 100 == 0 or step == steps - 1:
            log(f"[train] baf C={c:3d} n={n} step {step:4d} "
                f"loss {float(loss):.5f} ({time.time() - t0:5.1f}s)")
    return baf_params


def compute_z_pool(det_params: Dict, count: int = 1024, seed: int = 0xCA11B) -> np.ndarray:
    """Run the frontend over ``count`` calibration images -> Z pool (N,16,16,P)."""
    fe = jax.jit(lambda img: det.frontend(det_params, img))
    out = []
    for start in range(0, count, 64):
        imgs, _ = D.batch(dataset_seed=seed, start=start, count=min(64, count - start))
        out.append(np.asarray(fe(jnp.asarray(imgs))))
    return np.concatenate(out)
