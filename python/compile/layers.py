"""L2 building blocks: conv / batch-norm / activations / upsampling.

Parameters are plain pytrees (dicts of jnp arrays) so everything works with
``jax.grad`` and serializes trivially to the ``.npz`` weight cache.

Conventions:
  * NHWC layout everywhere.
  * Conv weights are HWIO (kh, kw, cin, cout).
  * BatchNorm carries (gamma, beta, mean, var); ``bn_apply`` is the
    inference form; training uses batch statistics and EMA-updates the
    running stats (see ``bn_train``).
"""

from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

BN_EPS = 1e-5
LEAKY_SLOPE = 0.1


def conv_init(key, kh: int, kw: int, cin: int, cout: int) -> Dict:
    """He-normal conv init (matches Darknet's scheme closely enough)."""
    fan_in = kh * kw * cin
    std = jnp.sqrt(2.0 / fan_in)
    w = jax.random.normal(key, (kh, kw, cin, cout), jnp.float32) * std
    return {"w": w}


def bn_init(c: int) -> Dict:
    return {
        "gamma": jnp.ones((c,), jnp.float32),
        "beta": jnp.zeros((c,), jnp.float32),
        "mean": jnp.zeros((c,), jnp.float32),
        "var": jnp.ones((c,), jnp.float32),
    }


def prelu_init(c: int) -> Dict:
    """Per-channel PReLU slope, initialized at 0.25 (paper's BaF block)."""
    return {"alpha": jnp.full((c,), 0.25, jnp.float32)}


def conv2d(x: jnp.ndarray, w: jnp.ndarray, stride: int = 1) -> jnp.ndarray:
    """SAME-padded 2-D convolution, NHWC x HWIO -> NHWC."""
    return jax.lax.conv_general_dilated(
        x,
        w,
        window_strides=(stride, stride),
        padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )


def bn_apply(x: jnp.ndarray, bn: Dict) -> jnp.ndarray:
    """Inference-mode batch norm using running statistics."""
    inv = jax.lax.rsqrt(bn["var"] + BN_EPS)
    return (x - bn["mean"]) * inv * bn["gamma"] + bn["beta"]


def bn_inverse(z: jnp.ndarray, bn: Dict) -> jnp.ndarray:
    """Invert ``bn_apply``: recover the conv output u from z = BN(u).

    Used by the backward half of BaF prediction (§3.3). gamma is guarded
    away from zero; BN layers in a trained net essentially never have
    exactly-zero gamma, but the guard keeps the export well-defined.
    """
    gamma = jnp.where(jnp.abs(bn["gamma"]) < 1e-6, 1e-6, bn["gamma"])
    std = jnp.sqrt(bn["var"] + BN_EPS)
    return (z - bn["beta"]) / gamma * std + bn["mean"]


def bn_train(
    x: jnp.ndarray, bn: Dict, momentum: float = 0.9
) -> Tuple[jnp.ndarray, Dict]:
    """Training-mode BN: normalize with batch stats, EMA the running stats."""
    axes = (0, 1, 2)
    mean = jnp.mean(x, axes)
    var = jnp.var(x, axes)
    inv = jax.lax.rsqrt(var + BN_EPS)
    y = (x - mean) * inv * bn["gamma"] + bn["beta"]
    new_bn = {
        "gamma": bn["gamma"],
        "beta": bn["beta"],
        "mean": momentum * bn["mean"] + (1.0 - momentum) * mean,
        "var": momentum * bn["var"] + (1.0 - momentum) * var,
    }
    return y, new_bn


def leaky_relu(x: jnp.ndarray) -> jnp.ndarray:
    """YOLO's activation sigma(.) with slope 0.1."""
    return jnp.where(x >= 0, x, LEAKY_SLOPE * x)


def prelu(x: jnp.ndarray, p: Dict) -> jnp.ndarray:
    return jnp.where(x >= 0, x, p["alpha"] * x)


def upsample2x(x: jnp.ndarray) -> jnp.ndarray:
    """Nearest-neighbour 2x upsampling (first BaF deconv layer, §3.3)."""
    n, h, w, c = x.shape
    x = jnp.broadcast_to(x[:, :, None, :, None, :], (n, h, 2, w, 2, c))
    return x.reshape(n, 2 * h, 2 * w, c)


def sigmoid(x: jnp.ndarray) -> jnp.ndarray:
    return jax.nn.sigmoid(x)
