"""ShapeWorld — deterministic procedural object-detection dataset.

This is the COCO-2014 substitute (see DESIGN.md §2). Images are 64x64x3
float32 in [0,1]: a two-color diagonal-gradient background, 1..4 filled
shapes (circle / square / triangle / cross) of random size, position and
color, plus low-amplitude uniform noise. Ground truth is a list of
axis-aligned boxes (x0, y0, x1, y1, class), x1/y1 exclusive.

DETERMINISM CONTRACT (shared with rust/src/data/shapeworld.rs):

SplitMix64 is counter-based: draw ``j`` (0-indexed) of a stream with seed
``s`` is ``mix(s + (j+1)*GAMMA)``, so the stream can be generated either
sequentially (Rust) or vectorized (NumPy) with identical outputs.

Per-image stream seed: ``img_seed = dataset_seed XOR (i * GAMMA mod 2^64)``
for image index ``i``.

Draw layout (indices within the per-image stream):
  0..2   background color c0 (r,g,b) : f32 draws, scaled 0.10 + 0.55*f
  3..5   background color c1 (r,g,b) : same scaling
  6      nshapes = range(1, 5)
  7+k*8 .. 7+k*8+7  shape k (slots always reserved for k = 0..3):
         +0 class  = range(0, 4)         (0 circle, 1 square, 2 tri, 3 cross)
         +1 size   = range(10, 29)
         +2 cx     = range(half+1, 64-half)   where half = size // 2
         +3 cy     = range(half+1, 64-half)
         +4..6 color (r,g,b) : f32 draws, scaled 0.25 + 0.75*f
         +7 spare (always drawn, reserved)
  39 .. 39+64*64*3-1  per-pixel noise, row-major (y, x, channel):
         img += (f - 0.5) * 0.04, then clip to [0, 1]

Geometry (all integer; half = size//2; x is column, y is row):
  circle   : (x-cx)^2 + (y-cy)^2 <= half^2
  square   : |x-cx| <= half and |y-cy| <= half
  triangle : dy = y - (cy-half); 0 <= dy <= 2*half and |x-cx| <= dy // 2
  cross    : t = max(1, half//3);
             (|x-cx| <= t and |y-cy| <= half) or (|y-cy| <= t and |x-cx| <= half)
  box      : (cx-half, cy-half, cx+half+1, cy+half+1)

Background: bg[y,x,c] = c0[c] + (c1[c]-c0[c]) * ((x+y) * (1/126)) in f32.
Shapes painted in order (later shapes overdraw earlier ones); all shapes
are kept as ground truth regardless of occlusion.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import List, Tuple

import numpy as np

from .prng import GAMMA, MASK64, MIX1, MIX2

IMG = 64
CHANNELS = 3
NUM_CLASSES = 4
CLASS_NAMES = ("circle", "square", "triangle", "cross")
_NOISE_BASE = 39  # first draw index of the noise block
_NOISE_LEN = IMG * IMG * CHANNELS


def _mix(z: np.ndarray) -> np.ndarray:
    """SplitMix64 output function, vectorized over uint64 arrays."""
    z = (z ^ (z >> np.uint64(30))) * np.uint64(MIX1)
    z = (z ^ (z >> np.uint64(27))) * np.uint64(MIX2)
    return z ^ (z >> np.uint64(31))


def stream(seed: int, start: int, count: int) -> np.ndarray:
    """Draws [start, start+count) of the SplitMix64 stream with ``seed``."""
    idx = np.arange(start + 1, start + count + 1, dtype=np.uint64)
    with np.errstate(over="ignore"):
        return _mix(np.uint64(seed & MASK64) + idx * np.uint64(GAMMA))


def to_f32(u: np.ndarray) -> np.ndarray:
    """u64 -> f32 in [0,1) with 24-bit precision (matches prng.next_f32)."""
    return (u >> np.uint64(40)).astype(np.float32) * np.float32(1.0 / (1 << 24))


def to_range(u: np.ndarray, lo: int, hi: int) -> np.ndarray:
    return (np.uint64(lo) + u % np.uint64(hi - lo)).astype(np.int64)


def image_seed(dataset_seed: int, index: int) -> int:
    return (dataset_seed ^ ((index * GAMMA) & MASK64)) & MASK64


@dataclass
class Sample:
    """One ShapeWorld image with its ground truth."""

    image: np.ndarray  # (64, 64, 3) float32 in [0, 1]
    boxes: np.ndarray  # (n, 5) float32: x0, y0, x1, y1, class


def generate(dataset_seed: int, index: int) -> Sample:
    """Generate image ``index`` of the dataset with ``dataset_seed``."""
    s = image_seed(dataset_seed, index)
    head = stream(s, 0, _NOISE_BASE)

    c0 = np.float32(0.10) + np.float32(0.55) * to_f32(head[0:3])
    c1 = np.float32(0.10) + np.float32(0.55) * to_f32(head[3:6])
    nshapes = int(to_range(head[6:7], 1, 5)[0])

    # Background gradient.
    xs = np.arange(IMG, dtype=np.float32)
    t = (xs[None, :] + xs[:, None]) * np.float32(1.0 / 126.0)  # (y, x)
    img = c0[None, None, :] + (c1 - c0)[None, None, :] * t[:, :, None]
    img = img.astype(np.float32)

    yy, xx = np.meshgrid(np.arange(IMG), np.arange(IMG), indexing="ij")
    boxes: List[Tuple[float, float, float, float, float]] = []
    for k in range(nshapes):
        base = 7 + k * 8
        cls = int(to_range(head[base : base + 1], 0, 4)[0])
        size = int(to_range(head[base + 1 : base + 2], 10, 29)[0])
        half = size // 2
        cx = int(to_range(head[base + 2 : base + 3], half + 1, IMG - half)[0])
        cy = int(to_range(head[base + 3 : base + 4], half + 1, IMG - half)[0])
        color = np.float32(0.25) + np.float32(0.75) * to_f32(head[base + 4 : base + 7])
        # slot +7 is reserved (drawn but unused) — keeps the layout static.

        dx = xx - cx
        dy_c = yy - cy
        if cls == 0:  # circle
            mask = dx * dx + dy_c * dy_c <= half * half
        elif cls == 1:  # square
            mask = (np.abs(dx) <= half) & (np.abs(dy_c) <= half)
        elif cls == 2:  # triangle
            dy = yy - (cy - half)
            mask = (dy >= 0) & (dy <= 2 * half) & (np.abs(dx) <= dy // 2)
        else:  # cross
            tbar = max(1, half // 3)
            mask = ((np.abs(dx) <= tbar) & (np.abs(dy_c) <= half)) | (
                (np.abs(dy_c) <= tbar) & (np.abs(dx) <= half)
            )
        img[mask] = color[None, :]
        boxes.append(
            (
                float(cx - half),
                float(cy - half),
                float(cx + half + 1),
                float(cy + half + 1),
                float(cls),
            )
        )

    noise = to_f32(stream(s, _NOISE_BASE, _NOISE_LEN)).reshape(IMG, IMG, CHANNELS)
    img = np.clip(img + (noise - np.float32(0.5)) * np.float32(0.04), 0.0, 1.0)
    return Sample(image=img.astype(np.float32), boxes=np.asarray(boxes, np.float32))


def batch(dataset_seed: int, start: int, count: int):
    """Generate ``count`` consecutive samples; images stacked, boxes listed."""
    samples = [generate(dataset_seed, start + i) for i in range(count)]
    return (
        np.stack([s.image for s in samples]),
        [s.boxes for s in samples],
    )
