"""Minimal Adam over pytrees (optax is unavailable offline)."""

from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp


def adam_init(params) -> Dict[str, Any]:
    zeros = jax.tree_util.tree_map(jnp.zeros_like, params)
    return {"m": zeros, "v": jax.tree_util.tree_map(jnp.zeros_like, params), "t": 0}


def adam_step(
    params,
    grads,
    state: Dict[str, Any],
    lr: float = 1e-3,
    b1: float = 0.9,
    b2: float = 0.999,
    eps: float = 1e-8,
) -> Tuple[Any, Dict[str, Any]]:
    t = state["t"] + 1
    m = jax.tree_util.tree_map(lambda m_, g: b1 * m_ + (1 - b1) * g, state["m"], grads)
    v = jax.tree_util.tree_map(
        lambda v_, g: b2 * v_ + (1 - b2) * g * g, state["v"], grads
    )
    mhat_scale = 1.0 / (1.0 - b1**t)
    vhat_scale = 1.0 / (1.0 - b2**t)
    new_params = jax.tree_util.tree_map(
        lambda p, m_, v_: p
        - lr * (m_ * mhat_scale) / (jnp.sqrt(v_ * vhat_scale) + eps),
        params,
        m,
        v,
    )
    return new_params, {"m": m, "v": v, "t": t}
