"""YOLO-Lite: the scaled-down YOLO-v3 stand-in (DESIGN.md §2).

An 8-layer Darknet-style single-scale detector over 64x64x3 ShapeWorld
images. Structurally it preserves everything the BaF method relies on:

  * the split layer ``l`` = layer 4 is a 3x3 *stride-2* conv followed by BN,
    and the network is cut *after* BN, *before* the LeakyReLU activation;
  * no residual connection bypasses the split layer;
  * the split-layer input X is 32x32x32 (post-activation of layer 3) and the
    BN output Z is 16x16x64 — the same 4x resolution ratio and channel
    expansion the paper's l=12 has (64x64x256 from 128x128x128).

Head: 8x8 grid, B=2 anchors, 4 classes -> 8x8x(2*(5+4)) = 8x8x18 raw output.
Anchor boxes are (16,16) and (40,40) pixels, chosen to bracket ShapeWorld's
11..29-pixel shapes.
"""

from __future__ import annotations

from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp

from . import layers as L

# (name, cout, stride); all convs 3x3 except the 1x1 head.
CFG: List[Tuple[str, int, int]] = [
    ("l1", 16, 1),  # 64x64x16
    ("l2", 32, 2),  # 32x32x32
    ("l3", 32, 1),  # 32x32x32
    ("l4", 64, 2),  # 16x16x64   <- SPLIT layer l: conv + BN, cut pre-activation
    ("l5", 64, 1),  # 16x16x64
    ("l6", 128, 2),  # 8x8x128
    ("l7", 64, 1),  # 8x8x64
]
SPLIT = "l4"
SPLIT_INDEX = 3  # position of the split layer in CFG

GRID = 8
CELL = 8  # pixels per cell (64 / GRID)
NUM_ANCHORS = 2
ANCHORS = ((16.0, 16.0), (40.0, 40.0))
NUM_CLASSES = 4
HEAD_CH = NUM_ANCHORS * (5 + NUM_CLASSES)  # 18

# Shapes at the split (the paper's 64x64x256 analog).
X_SHAPE = (32, 32, 32)  # layer-l input (post-sigma of l3)
Z_SHAPE = (16, 16, 64)  # layer-l BN output (pre-sigma)
P_CHANNELS = Z_SHAPE[2]
Q_CHANNELS = X_SHAPE[2]


def init(key) -> Dict:
    """Initialize all detector parameters."""
    params: Dict = {}
    cin = 3
    keys = jax.random.split(key, len(CFG) + 1)
    for k, (name, cout, _stride) in zip(keys, CFG):
        params[name] = {"conv": L.conv_init(k, 3, 3, cin, cout), "bn": L.bn_init(cout)}
        cin = cout
    params["head"] = {
        "conv": L.conv_init(keys[-1], 1, 1, cin, HEAD_CH),
        "bias": jnp.zeros((HEAD_CH,), jnp.float32),
    }
    return params


def _block(x, p, stride, train: bool):
    """conv -> BN -> LeakyReLU. Returns (y, updated_bn)."""
    u = L.conv2d(x, p["conv"]["w"], stride)
    if train:
        z, new_bn = L.bn_train(u, p["bn"])
    else:
        z, new_bn = L.bn_apply(u, p["bn"]), p["bn"]
    return L.leaky_relu(z), new_bn


def forward(params: Dict, img: jnp.ndarray, train: bool = False):
    """Full monolithic forward pass: image -> raw head (cloud-only path).

    Returns (head, new_params) where new_params carries EMA'd BN stats when
    ``train`` is True (identical to ``params`` otherwise).
    """
    x = img
    new_params = dict(params)
    for name, _cout, stride in CFG:
        x, new_bn = _block(x, params[name], stride, train)
        new_params[name] = {"conv": params[name]["conv"], "bn": new_bn}
    head = L.conv2d(x, params["head"]["conv"]["w"], 1) + params["head"]["bias"]
    return head, new_params


def frontend(params: Dict, img: jnp.ndarray) -> jnp.ndarray:
    """Edge half: image -> Z, the split-layer BN output (PRE-activation).

    This is what runs on the mobile device: layers 1..l-1 complete
    (conv+BN+sigma), then layer l's conv and BN only — the activation is
    applied cloud-side after reconstruction (Fig. 1 of the paper).
    """
    x = img
    for name, _cout, stride in CFG[:SPLIT_INDEX]:
        x, _ = _block(x, params[name], stride, train=False)
    p = params[SPLIT]
    u = L.conv2d(x, p["conv"]["w"], 2)
    return L.bn_apply(u, p["bn"])


def frontend_with_x(params: Dict, img: jnp.ndarray):
    """Like ``frontend`` but also returns X, the split-layer input.

    Only used offline: channel-selection statistics (Eq. 2) and BaF
    training targets need X; it never leaves the build machine.
    """
    x = img
    for name, _cout, stride in CFG[:SPLIT_INDEX]:
        x, _ = _block(x, params[name], stride, train=False)
    p = params[SPLIT]
    u = L.conv2d(x, p["conv"]["w"], 2)
    return L.bn_apply(u, p["bn"]), x


def tail(params: Dict, z_tilde: jnp.ndarray) -> jnp.ndarray:
    """Cloud half: reconstructed Z-tilde (pre-activation) -> raw head.

    The first op is the split layer's activation sigma(.), then the
    remaining layers run unchanged with pre-trained weights.
    """
    x = L.leaky_relu(z_tilde)
    for name, _cout, stride in CFG[SPLIT_INDEX + 1 :]:
        x, _ = _block(x, params[name], stride, train=False)
    return L.conv2d(x, params["head"]["conv"]["w"], 1) + params["head"]["bias"]


def decode_head(head: jnp.ndarray) -> jnp.ndarray:
    """Raw head (N,8,8,18) -> (N, 8*8*2, 6) boxes: x0,y0,x1,y1,score,class.

    Box parameterization is YOLO-v3's: sigmoid offsets within the cell,
    exponential anchor scaling. Score = objectness * max class prob.
    NMS and thresholding live in the Rust eval module (and a NumPy twin in
    train.py for training-time validation).
    """
    n = head.shape[0]
    h = head.reshape(n, GRID, GRID, NUM_ANCHORS, 5 + NUM_CLASSES)
    gy, gx = jnp.meshgrid(
        jnp.arange(GRID, dtype=jnp.float32),
        jnp.arange(GRID, dtype=jnp.float32),
        indexing="ij",
    )
    aw = jnp.asarray([a[0] for a in ANCHORS], jnp.float32)
    ah = jnp.asarray([a[1] for a in ANCHORS], jnp.float32)
    cx = (gx[None, :, :, None] + L.sigmoid(h[..., 0])) * CELL
    cy = (gy[None, :, :, None] + L.sigmoid(h[..., 1])) * CELL
    bw = aw[None, None, None, :] * jnp.exp(jnp.clip(h[..., 2], -6, 6))
    bh = ah[None, None, None, :] * jnp.exp(jnp.clip(h[..., 3], -6, 6))
    obj = L.sigmoid(h[..., 4])
    cls_prob = jax.nn.softmax(h[..., 5:], axis=-1)
    cls_id = jnp.argmax(cls_prob, axis=-1).astype(jnp.float32)
    score = obj * jnp.max(cls_prob, axis=-1)
    boxes = jnp.stack(
        [cx - bw / 2, cy - bh / 2, cx + bw / 2, cy + bh / 2, score, cls_id], axis=-1
    )
    return boxes.reshape(n, GRID * GRID * NUM_ANCHORS, 6)
