"""The hand-rolled Adam: convergence and bias-correction sanity."""

import jax
import jax.numpy as jnp
import numpy as np

from compile import optim


def test_adam_converges_on_quadratic():
    target = jnp.asarray([3.0, -2.0, 0.5])
    params = {"w": jnp.zeros(3)}
    state = optim.adam_init(params)

    def loss(p):
        return jnp.sum((p["w"] - target) ** 2)

    for _ in range(400):
        g = jax.grad(loss)(params)
        params, state = optim.adam_step(params, g, state, lr=5e-2)
    np.testing.assert_allclose(np.asarray(params["w"]), np.asarray(target), atol=1e-2)


def test_first_step_size_is_lr():
    """With bias correction, |delta| of step 1 equals lr (for any gradient scale)."""
    for scale in [1e-3, 1.0, 1e3]:
        params = {"w": jnp.zeros(1)}
        state = optim.adam_init(params)
        g = {"w": jnp.asarray([scale])}
        new, _ = optim.adam_step(params, g, state, lr=0.1)
        np.testing.assert_allclose(abs(float(new["w"][0])), 0.1, rtol=1e-4)


def test_state_counts_steps():
    params = {"w": jnp.zeros(2)}
    state = optim.adam_init(params)
    g = {"w": jnp.ones(2)}
    for i in range(3):
        params, state = optim.adam_step(params, g, state)
        assert state["t"] == i + 1


def test_tree_structure_preserved():
    params = {"a": {"b": jnp.ones((2, 2))}, "c": jnp.zeros(3)}
    state = optim.adam_init(params)
    g = jax.tree_util.tree_map(jnp.ones_like, params)
    new, state2 = optim.adam_step(params, g, state)
    assert set(new.keys()) == {"a", "c"}
    assert new["a"]["b"].shape == (2, 2)
    assert state2["m"]["a"]["b"].shape == (2, 2)
