"""AOT export path: HLO text form, weight cache roundtrip, stats, and
manifest integrity (artifact checks skip when `make artifacts` hasn't run).
"""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, detector as det, stats

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


def test_hlo_text_includes_large_constants():
    params = det.init(jax.random.PRNGKey(0))
    lowered = jax.jit(lambda z: det.tail(params, z)).lower(
        jnp.zeros((1, *det.Z_SHAPE))
    )
    text = aot.to_hlo_text(lowered)
    assert text.startswith("HloModule")
    assert "{...}" not in text, "weights must be printed, not elided"
    # the tail's first conv weight tensor appears with its full shape
    assert "f32[3,3,64,64]" in text


def test_weight_cache_roundtrip(tmp_path):
    det_params = det.init(jax.random.PRNGKey(1))
    from compile import baf as B

    baf_models = {(8, 8): B.init(jax.random.PRNGKey(2), 8)}
    path = str(tmp_path / "w.npz")
    aot.save_weights(path, det_params, baf_models)
    det2, baf2 = aot.load_weights(path)
    for name, _c, _s in det.CFG:
        np.testing.assert_array_equal(
            np.asarray(det_params[name]["conv"]["w"]),
            np.asarray(det2[name]["conv"]["w"]),
        )
    np.testing.assert_array_equal(
        np.asarray(baf_models[(8, 8)]["c1"]["w"]), np.asarray(baf2[(8, 8)]["c1"]["w"])
    )


def test_greedy_order_is_permutation_and_sorted_by_score():
    rng = np.random.default_rng(0)
    rho = rng.uniform(0, 1, (16, 4, 8)).astype(np.float32)
    order = stats.greedy_order(rho)
    assert sorted(order) == list(range(16))
    score = rho.mean(axis=1).sum(axis=1)
    got = [score[i] for i in order]
    assert all(got[i] >= got[i + 1] - 1e-9 for i in range(len(got) - 1))


needs_artifacts = pytest.mark.skipif(
    not os.path.exists(os.path.join(ART, "manifest.json")),
    reason="artifacts not built (run `make artifacts`)",
)


@needs_artifacts
def test_manifest_covers_all_stages():
    with open(os.path.join(ART, "manifest.json")) as f:
        m = json.load(f)
    names = set(m["artifacts"])
    for required in ["frontend_b1", "tail_b1", "monolith_b1", "baf_c16_n8_b1"]:
        assert required in names
    for name, spec in m["artifacts"].items():
        path = os.path.join(ART, spec["file"])
        assert os.path.exists(path), f"{name}: missing {path}"
        assert os.path.getsize(path) > 10_000, f"{name}: suspiciously small"
        assert spec["inputs"], name


@needs_artifacts
def test_channel_stats_consistent_with_manifest():
    with open(os.path.join(ART, "manifest.json")) as f:
        m = json.load(f)
    with open(os.path.join(ART, "channel_stats.json")) as f:
        st = json.load(f)
    assert st["p_channels"] == m["p_channels"]
    assert st["q_channels"] == m["q_channels"]
    assert sorted(st["order"]) == list(range(st["p_channels"]))
    # the BaF artifacts' baked selections agree with the stats order
    for name, spec in m["artifacts"].items():
        if spec.get("sel"):
            c = spec["c"]
            assert spec["sel"] == st["order"][:c], name


@needs_artifacts
def test_goldens_present():
    g = os.path.join(ART, "golden")
    for f in [
        "prng.json",
        "dataset.json",
        "dataset_img0.npy",
        "quant_z.npy",
        "pipe_z.npy",
        "pipe_head.npy",
        "pipe_meta.json",
    ]:
        assert os.path.exists(os.path.join(g, f)), f
