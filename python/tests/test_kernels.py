"""L1 correctness: every Pallas kernel vs its pure-jnp oracle.

Hypothesis sweeps shapes, dtypes-in-range values and bit depths; each
property asserts allclose (or exact equality for integer outputs) between
the interpret-mode Pallas kernel and ref.py. This is the core correctness
signal for the compile path.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import consolidate as KC
from compile.kernels import conv_bn as KB
from compile.kernels import corr as KR
from compile.kernels import quantize as KQ
from compile.kernels import ref as R

settings.register_profile("kernels", max_examples=25, deadline=None)
settings.load_profile("kernels")


def arr(rng, *shape, scale=3.0):
    return (rng.standard_normal(shape) * scale).astype(np.float32)


@st.composite
def chw_case(draw):
    c = draw(st.sampled_from([1, 2, 4, 8, 16]))
    h = draw(st.sampled_from([4, 8, 16]))
    w = draw(st.sampled_from([4, 8, 16]))
    n = draw(st.sampled_from([2, 3, 4, 6, 8, 12]))
    seed = draw(st.integers(0, 2**31 - 1))
    return c, h, w, n, seed


@given(chw_case())
def test_quantize_matches_ref(case):
    c, h, w, n, seed = case
    rng = np.random.default_rng(seed)
    z = jnp.asarray(arr(rng, c, h, w))
    q1, mm1 = KQ.quantize(z, n)
    q2, mm2 = R.quantize_ref(z, n)
    np.testing.assert_array_equal(np.asarray(q1), np.asarray(q2))
    np.testing.assert_array_equal(np.asarray(mm1), np.asarray(mm2))


@given(chw_case())
def test_dequantize_matches_ref(case):
    c, h, w, n, seed = case
    rng = np.random.default_rng(seed)
    z = jnp.asarray(arr(rng, c, h, w))
    q, mm = R.quantize_ref(z, n)
    d1 = KQ.dequantize(q, mm, n)
    d2 = R.dequantize_ref(q, mm, n)
    # identical formula; tolerance covers fma/association differences
    # between the pallas-interpret and plain-jnp lowerings
    np.testing.assert_allclose(np.asarray(d1), np.asarray(d2), rtol=1e-6, atol=1e-5)


@given(chw_case())
def test_consolidate_matches_ref(case):
    c, h, w, n, seed = case
    rng = np.random.default_rng(seed)
    z = jnp.asarray(arr(rng, c, h, w))
    q, mm = R.quantize_ref(z, n)
    zt = z + jnp.asarray(arr(rng, c, h, w, scale=0.3))
    c1 = KC.consolidate(zt, q, mm, n)
    c2 = R.consolidate_ref(zt, q, mm, n)
    # tolerance covers fma/association differences between lowerings
    np.testing.assert_allclose(np.asarray(c1), np.asarray(c2), rtol=1e-6, atol=1e-5)


def test_quantize_constant_channel():
    z = jnp.ones((2, 4, 4)) * 0.5
    q, mm = KQ.quantize(z, 8)
    assert np.all(np.asarray(q) == 0)
    np.testing.assert_allclose(np.asarray(mm)[:, 0], 0.5)


@given(
    st.sampled_from([16, 32, 64]),
    st.sampled_from([32, 64, 128]),
    st.sampled_from([128, 256]),
    st.integers(0, 2**31 - 1),
)
def test_gram_and_pearson_match_ref(p, s, nvec, seed):
    rng = np.random.default_rng(seed)
    z = jnp.asarray(arr(rng, p, nvec))
    x = jnp.asarray(arr(rng, s, nvec))
    np.testing.assert_allclose(
        np.asarray(KR.gram(z, x)), np.asarray(R.gram_ref(z, x)), rtol=2e-4, atol=2e-3
    )
    np.testing.assert_allclose(
        np.asarray(KR.abs_pearson(z, x)), np.asarray(R.corr_ref(z, x)), atol=2e-4
    )


def test_pearson_known_correlations():
    n = 256
    t = np.linspace(0, 1, n, dtype=np.float32)
    z = jnp.asarray(np.stack([t, -t]))  # rows perfectly (anti)correlated with t
    x = jnp.asarray(np.stack([t, np.ones_like(t)]))
    rho = np.asarray(KR.abs_pearson(z, x))
    np.testing.assert_allclose(rho[:, 0], 1.0, atol=1e-4)  # |corr| -> sign-free
    np.testing.assert_allclose(rho[:, 1], 0.0, atol=1e-4)  # constant row -> 0


@given(
    st.sampled_from([1, 2]),
    st.sampled_from([8, 16, 32]),
    st.sampled_from([4, 8, 16]),
    st.sampled_from([8, 16]),
    st.integers(0, 2**31 - 1),
)
def test_conv_bn_matches_ref(b, hw, cin, cout, seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(arr(rng, b, hw, hw, cin, scale=1.0))
    w = jnp.asarray(arr(rng, 3, 3, cin, cout, scale=0.1))
    gamma = jnp.asarray(rng.uniform(0.5, 1.5, cout).astype(np.float32))
    beta = jnp.asarray(arr(rng, cout, scale=0.5))
    mean = jnp.asarray(arr(rng, cout, scale=0.5))
    var = jnp.asarray(rng.uniform(0.2, 2.0, cout).astype(np.float32))
    got = KB.conv3x3s2_bn(x, w, gamma, beta, mean, var)
    want = R.conv_bn_ref(x, w, gamma, beta, mean, var, stride=2)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)


def test_polyphase_layout():
    h, w, q = 8, 8, 3
    x = jnp.arange(h * w * q, dtype=jnp.float32).reshape(h, w, q)
    rows = np.asarray(KR.polyphase(x))
    assert rows.shape == (4 * q, h * w // 4)
    # row 0 = offset (0,0), channel 0
    np.testing.assert_array_equal(
        rows[0], np.asarray(x)[0::2, 0::2, 0].reshape(-1)
    )
    # row s*q + c layout: offset s=(1,1) is the 4th block
    np.testing.assert_array_equal(
        rows[3 * q + 2], np.asarray(x)[1::2, 1::2, 2].reshape(-1)
    )


@pytest.mark.parametrize("n", [2, 8])
def test_consolidate_clips_to_bin(n):
    rng = np.random.default_rng(0)
    z = jnp.asarray(arr(rng, 2, 8, 8))
    q, mm = R.quantize_ref(z, n)
    far = z + 100.0
    out = np.asarray(R.consolidate_ref(far, q, mm, n))
    # every element must be the UPPER boundary of its bin
    step = (np.asarray(mm)[:, 1] - np.asarray(mm)[:, 0]) / (2**n - 1)
    hi = np.asarray(mm)[:, 0][:, None, None] + (np.asarray(q) + 0.5) * step[:, None, None]
    np.testing.assert_allclose(out, hi, atol=1e-5)
