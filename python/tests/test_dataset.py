"""ShapeWorld generator: determinism, draw-layout, geometry invariants."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import dataset as D
from compile.prng import SplitMix64

settings.register_profile("ds", max_examples=20, deadline=None)
settings.load_profile("ds")


def test_sequential_equals_counterbased():
    r = SplitMix64(123)
    seq = [r.next_u64() for _ in range(50)]
    vec = D.stream(123, 0, 50)
    np.testing.assert_array_equal(np.asarray(seq, np.uint64), vec)


@given(st.integers(0, 2**63), st.integers(0, 10_000))
def test_generation_deterministic(seed, idx):
    a = D.generate(seed, idx)
    b = D.generate(seed, idx)
    np.testing.assert_array_equal(a.image, b.image)
    np.testing.assert_array_equal(a.boxes, b.boxes)


@given(st.integers(0, 2**31), st.integers(0, 500))
def test_image_and_box_invariants(seed, idx):
    s = D.generate(seed, idx)
    assert s.image.shape == (64, 64, 3)
    assert s.image.dtype == np.float32
    assert s.image.min() >= 0.0 and s.image.max() <= 1.0
    assert 1 <= len(s.boxes) <= 4
    for x0, y0, x1, y1, cls in s.boxes:
        assert 0 <= x0 < x1 <= 64
        assert 0 <= y0 < y1 <= 64
        assert cls in (0, 1, 2, 3)
        # boxes are odd-sized squares (2*half+1)
        assert (x1 - x0) == (y1 - y0)
        assert int(x1 - x0) % 2 == 1


def test_different_indices_differ():
    a = D.generate(7, 0)
    b = D.generate(7, 1)
    assert not np.array_equal(a.image, b.image)


def test_shape_is_painted_at_center():
    # the last-drawn shape's center must carry its color (never overdrawn)
    for idx in range(10):
        s = D.generate(99, idx)
        x0, y0, x1, y1, cls = s.boxes[-1]
        cx, cy = int((x0 + x1) / 2), int((y0 + y1) / 2)
        px = s.image[cy, cx]
        # shape colors are in [0.25, 1.0]; noise is +-0.02
        assert px.max() > 0.2


def test_batch_matches_individual():
    imgs, boxes = D.batch(5, 10, 3)
    for i in range(3):
        s = D.generate(5, 10 + i)
        np.testing.assert_array_equal(imgs[i], s.image)
        np.testing.assert_array_equal(boxes[i], s.boxes)


def test_noise_block_layout():
    # draws 39.. are noise; regenerating with the same head but a
    # different noise slice must change pixels (sanity of the layout
    # documented in the module docstring)
    s = D.image_seed(42, 0)
    head1 = D.stream(s, 0, D._NOISE_BASE)
    noise1 = D.stream(s, D._NOISE_BASE, 10)
    # stream slices are consistent with one big draw
    allv = D.stream(s, 0, D._NOISE_BASE + 10)
    np.testing.assert_array_equal(allv[: D._NOISE_BASE], head1)
    np.testing.assert_array_equal(allv[D._NOISE_BASE :], noise1)


@pytest.mark.parametrize("lo,hi", [(10, 29), (0, 4), (1, 5)])
def test_range_draws_in_bounds(lo, hi):
    u = D.stream(1234, 0, 1000)
    v = D.to_range(u, lo, hi)
    assert v.min() >= lo and v.max() < hi
    # all values hit for small ranges
    assert set(np.unique(v)) == set(range(lo, hi))
