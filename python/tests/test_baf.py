"""The BaF predictor: shapes, frozen forward path, loss properties."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import baf as B
from compile import detector as det
from compile import layers as L


@pytest.fixture(scope="module")
def det_params():
    return det.init(jax.random.PRNGKey(1))


@pytest.mark.parametrize("c", [4, 16, 64])
def test_predict_shapes(det_params, c):
    baf_params = B.init(jax.random.PRNGKey(2), c)
    sel = tuple(range(c))
    zc = jnp.asarray(
        np.random.default_rng(0).normal(size=(2, 16, 16, c)).astype(np.float32)
    )
    x_tilde = B.backward_predict(baf_params, zc, det_params[det.SPLIT]["bn"], sel)
    assert x_tilde.shape == (2, *det.X_SHAPE)
    z_tilde = B.predict(baf_params, det_params, zc, sel)
    assert z_tilde.shape == (2, *det.Z_SHAPE)


def test_forward_predict_matches_frontend_layer(det_params):
    """The forward half with pallas must equal the plain-lax split layer."""
    rng = np.random.default_rng(5)
    x = jnp.asarray(rng.normal(size=(1, *det.X_SHAPE)).astype(np.float32))
    lax_out = B.forward_predict(det_params, x, use_pallas=False)
    pallas_out = B.forward_predict(det_params, x, use_pallas=True)
    np.testing.assert_allclose(
        np.asarray(lax_out), np.asarray(pallas_out), rtol=1e-4, atol=1e-4
    )


def test_perfect_input_gives_good_forward_prediction(det_params):
    """If the deconv-net recovered X exactly, forward prediction IS the
    true Z — the upper bound the backward net is trained toward."""
    rng = np.random.default_rng(6)
    img = jnp.asarray(rng.uniform(0, 1, (1, 64, 64, 3)).astype(np.float32))
    z_true, x_true = det.frontend_with_x(det_params, img)
    z_fwd = B.forward_predict(det_params, x_true)
    np.testing.assert_allclose(
        np.asarray(z_true), np.asarray(z_fwd), rtol=1e-4, atol=1e-4
    )


def test_charbonnier_properties():
    a = jnp.zeros((4, 4))
    assert float(B.charbonnier(a, a)) == pytest.approx(16 * 1e-3, rel=1e-3)
    b = jnp.ones((4, 4))
    big = float(B.charbonnier(a, b))
    assert big == pytest.approx(16 * np.sqrt(1 + 1e-6), rel=1e-4)
    # monotone in |a - b|
    assert float(B.charbonnier(a, 2 * b)) > big


def test_gradients_flow_only_into_baf(det_params):
    """Training must not touch detector weights (paper: no retraining)."""
    c = 8
    baf_params = B.init(jax.random.PRNGKey(3), c)
    sel = tuple(range(c))
    rng = np.random.default_rng(7)
    zc = jnp.asarray(rng.normal(size=(1, 16, 16, c)).astype(np.float32))
    y = jnp.asarray(rng.normal(size=(1, *det.Z_SHAPE)).astype(np.float32))

    def loss(bp, dp):
        zt = B.predict(bp, dp, zc, sel)
        return B.charbonnier(L.leaky_relu(zt), y)

    g_baf, g_det = jax.grad(loss, argnums=(0, 1))(baf_params, det_params)
    # BaF grads are nonzero
    leaves = jax.tree_util.tree_leaves(g_baf)
    assert any(float(jnp.abs(l).max()) > 0 for l in leaves)
    # in deployment only baf_params are passed to the optimizer; the
    # detector gradient exists mathematically but is discarded — verify
    # the training step treats det_params as a constant by API shape.
    from compile import train as T

    assert "det_params" in T._baf_step.__wrapped__.__code__.co_varnames


def test_prelu_identity_last_layer(det_params):
    """The last deconv layer must be linear (identity activation)."""
    c = 4
    p = B.init(jax.random.PRNGKey(4), c)
    sel = tuple(range(c))
    bn = det_params[det.SPLIT]["bn"]
    z1 = B.backward_predict(p, jnp.zeros((1, 16, 16, c)), bn, sel)
    z2 = B.backward_predict(p, jnp.zeros((1, 16, 16, c)) + 1e-6, bn, sel)
    # tiny input perturbation -> tiny output change (no dead zone at the end)
    assert float(jnp.abs(z2 - z1).max()) < 1e-2
