"""NumPy eval twin: metric definition matches the Rust implementation."""

import numpy as np

from compile import evalpy as E


def det(x, score, cls):
    return np.array([x, 0.0, x + 10.0, 10.0, score, cls], np.float32)


def gt(x, cls):
    return np.array([x, 0.0, x + 10.0, 10.0, cls], np.float32)


def test_iou_cases():
    a = np.array([0, 0, 2, 2], np.float32)
    b = np.array([1, 0, 3, 2], np.float32)
    assert abs(E.iou(a, b) - 1 / 3) < 1e-6
    assert E.iou(a, a) == 1.0
    assert E.iou(a, np.array([5, 5, 6, 6], np.float32)) == 0.0


def test_nms_suppresses_same_class_only():
    boxes = np.stack(
        [det(0, 0.9, 0), det(1, 0.8, 0), det(1, 0.7, 1), det(40, 0.6, 0)]
    )
    kept = E.nms(boxes)
    assert len(kept) == 3
    assert 0.8 not in kept[:, 4]


def test_perfect_map_is_one():
    dets = [np.stack([det(0, 0.9, 0), det(20, 0.8, 1)])]
    gts = [np.stack([gt(0, 0), gt(20, 1)])]
    assert abs(E.mean_ap(dets, gts, 4) - 1.0) < 1e-9


def test_miss_halves_recall():
    dets = [np.stack([det(0, 0.9, 0)])]
    gts = [np.stack([gt(0, 0), gt(30, 0)])]
    m = E.mean_ap(dets, gts, 4)
    assert 0.4 < m < 0.6


def test_false_positive_lowers_map():
    clean = E.mean_ap([np.stack([det(0, 0.9, 0)])], [np.stack([gt(0, 0)])], 4)
    noisy = E.mean_ap(
        [np.stack([det(40, 0.95, 0), det(0, 0.9, 0)])], [np.stack([gt(0, 0)])], 4
    )
    assert noisy < clean


def test_empty_inputs():
    assert E.mean_ap([np.zeros((0, 6))], [np.zeros((0, 5))], 4) == 0.0
    assert E.nms(np.zeros((0, 6))).shape == (0, 6)
