"""L2 model structure: split consistency, shapes, BN, head decoding."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import detector as det
from compile import layers as L


@pytest.fixture(scope="module")
def params():
    return det.init(jax.random.PRNGKey(0))


@pytest.fixture(scope="module")
def img():
    rng = np.random.default_rng(1)
    return jnp.asarray(rng.uniform(0, 1, (2, 64, 64, 3)).astype(np.float32))


def test_forward_shapes(params, img):
    head, _ = det.forward(params, img)
    assert head.shape == (2, det.GRID, det.GRID, det.HEAD_CH)


def test_split_consistency(params, img):
    """sigma(frontend) -> tail must equal the monolith exactly.

    This is the structural fact the whole paper rests on: cutting at the
    split layer (post-BN, pre-activation) and re-entering the tail is
    the identity transformation of the network.
    """
    z = det.frontend(params, img)
    assert z.shape == (2, *det.Z_SHAPE)
    head_split = det.tail(params, z)
    head_mono, _ = det.forward(params, img)
    np.testing.assert_allclose(
        np.asarray(head_split), np.asarray(head_mono), rtol=1e-5, atol=1e-5
    )


def test_frontend_with_x_consistency(params, img):
    z1 = det.frontend(params, img)
    z2, x = det.frontend_with_x(params, img)
    np.testing.assert_allclose(np.asarray(z1), np.asarray(z2), atol=1e-6)
    assert x.shape == (2, *det.X_SHAPE)


def test_z_is_pre_activation(params, img):
    """Z must contain negative values (BN output before LeakyReLU)."""
    z = np.asarray(det.frontend(params, img))
    assert (z < 0).any(), "split tensor should be pre-activation"


def test_bn_inverse_roundtrip():
    rng = np.random.default_rng(3)
    bn = {
        "gamma": jnp.asarray(rng.uniform(0.5, 1.5, 8).astype(np.float32)),
        "beta": jnp.asarray(rng.normal(size=8).astype(np.float32)),
        "mean": jnp.asarray(rng.normal(size=8).astype(np.float32)),
        "var": jnp.asarray(rng.uniform(0.5, 2.0, 8).astype(np.float32)),
    }
    u = jnp.asarray(rng.normal(size=(2, 4, 4, 8)).astype(np.float32))
    z = L.bn_apply(u, bn)
    u2 = L.bn_inverse(z, bn)
    np.testing.assert_allclose(np.asarray(u), np.asarray(u2), rtol=1e-4, atol=1e-4)


def test_bn_train_normalizes_and_updates_stats():
    rng = np.random.default_rng(4)
    x = jnp.asarray((rng.normal(size=(8, 6, 6, 4)) * 3 + 5).astype(np.float32))
    bn = L.bn_init(4)
    y, new_bn = L.bn_train(x, bn)
    ym = np.asarray(jnp.mean(y, axis=(0, 1, 2)))
    ys = np.asarray(jnp.std(y, axis=(0, 1, 2)))
    np.testing.assert_allclose(ym, 0.0, atol=1e-4)
    np.testing.assert_allclose(ys, 1.0, atol=1e-3)
    assert np.all(np.asarray(new_bn["mean"]) != 0.0)


def test_upsample2x_nearest():
    x = jnp.asarray(np.arange(4, dtype=np.float32).reshape(1, 2, 2, 1))
    y = np.asarray(L.upsample2x(x))[0, :, :, 0]
    want = np.array([[0, 0, 1, 1], [0, 0, 1, 1], [2, 2, 3, 3], [2, 2, 3, 3]], np.float32)
    np.testing.assert_array_equal(y, want)


def test_leaky_relu_slope():
    x = jnp.asarray([-10.0, -1.0, 0.0, 2.0])
    y = np.asarray(L.leaky_relu(x))
    np.testing.assert_allclose(y, [-1.0, -0.1, 0.0, 2.0], atol=1e-7)


def test_decode_head_boxes_in_frame(params, img):
    head, _ = det.forward(params, img)
    boxes = np.asarray(det.decode_head(head))
    assert boxes.shape == (2, det.GRID * det.GRID * det.NUM_ANCHORS, 6)
    # scores are probabilities
    assert (boxes[..., 4] >= 0).all() and (boxes[..., 4] <= 1).all()
    # classes are valid ids
    assert set(np.unique(boxes[..., 5])).issubset(set(range(det.NUM_CLASSES)))


def test_decode_head_localizes_peak():
    """A hand-built head with one hot cell must decode to that cell."""
    head = np.full((1, det.GRID, det.GRID, det.NUM_ANCHORS, 5 + det.NUM_CLASSES), -8.0, np.float32)
    gy, gx, a = 3, 5, 0
    head[0, gy, gx, a, 0:2] = 0.0  # center of cell
    head[0, gy, gx, a, 2:4] = 0.0  # anchor-sized
    head[0, gy, gx, a, 4] = 8.0  # high objectness
    head[0, gy, gx, a, 5] = 8.0  # class 0
    boxes = np.asarray(
        det.decode_head(jnp.asarray(head.reshape(1, det.GRID, det.GRID, -1)))
    )
    best = boxes[0, np.argmax(boxes[0, :, 4])]
    cx, cy = (best[0] + best[2]) / 2, (best[1] + best[3]) / 2
    assert abs(cx - (gx + 0.5) * det.CELL) < 1e-3
    assert abs(cy - (gy + 0.5) * det.CELL) < 1e-3
    w = best[2] - best[0]
    assert abs(w - det.ANCHORS[a][0]) < 1e-3
    assert best[5] == 0.0
