//! E4 — lossless codec comparison on tiled quantized tensors (the [5]
//! comparison): TLC (FLIF stand-in) vs PNG-like vs zstd, rate and
//! throughput, across C and n. Also micro-benchmarks of the codec hot
//! paths on synthetic planes (used by the §Perf iteration log), and the
//! striped-container scaling section: encode+decode throughput vs stripe
//! count K on a 64-channel tensor, with the acceptance checks
//! (size within 1% of v1, zero steady-state codec allocations, and —
//! on machines with >= 4 cores — >= 2x combined throughput at K=4).
//!
//! Run: `cargo bench --bench bench_codec` (add `--smoke` for a quick
//! tier-1 pass, `--json-out [DIR]` for `BENCH_codec.json`).

#![allow(clippy::unwrap_used, clippy::expect_used)]

use baf::bench::{fmt_stats, json_out_from, time_fn, JsonReport};
use baf::codec::container::{pack, pack_v2_with, parse, unpack_with};
use baf::codec::scratch::ScratchPool;
use baf::codec::{CodecKind, ImageMeta};
use baf::experiments::{codec_table, codec_table_fmt, Context};
use baf::quant::{quantize, QuantizedTensor};
use baf::runtime::pool::WorkerPool;
use baf::tensor::Tensor;
use baf::util::SplitMix64;

fn synthetic_plane(w: usize, h: usize, n: u8, seed: u64) -> Vec<u16> {
    // smooth field + noise: representative of tiled BN-output tensors
    let mut r = SplitMix64::new(seed);
    let maxv = ((1u32 << n) - 1) as f32;
    (0..w * h)
        .map(|i| {
            let x = (i % w) as f32 / w as f32;
            let y = (i / w) as f32 / h as f32;
            let v = 0.5
                + 0.25 * (x * 9.0).sin() * (y * 7.0).cos()
                + 0.08 * (r.next_f32() - 0.5);
            (v.clamp(0.0, 1.0) * maxv) as u16
        })
        .collect()
}

/// A 64-channel synthetic tensor shaped like a BN output (smooth per
/// channel, channel-correlated), quantized to n bits.
fn synthetic_quant(c: usize, h: usize, w: usize, n: u8) -> QuantizedTensor {
    let mut data = Vec::with_capacity(c * h * w);
    for ch in 0..c {
        let plane = synthetic_plane(w, h, 12, 1000 + ch as u64);
        let scale = 1.0 + (ch as f32) * 0.01;
        data.extend(plane.iter().map(|&s| s as f32 / 4096.0 * scale - 0.5));
    }
    quantize(&Tensor::from_vec(&[c, h, w], data), n)
}

/// One full codec round trip of a striped frame, recycling every pooled
/// buffer — the steady-state serving loop in miniature.
fn roundtrip(q: &QuantizedTensor, k: usize, pool: &WorkerPool, scratch: &ScratchPool) -> usize {
    let frame = pack_v2_with(q, CodecKind::Tlc, 0, k, pool, scratch);
    let len = frame.len();
    let parsed = parse(&frame).unwrap();
    let q2 = unpack_with(&parsed, pool, scratch).unwrap();
    assert_eq!(q2.bins, q.bins, "striped roundtrip must be lossless");
    scratch.put_u16(q2.bins);
    scratch.put_u8(frame);
    len
}

fn main() -> anyhow::Result<()> {
    baf::util::logging::init();
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let smoke = argv.iter().any(|a| a == "--smoke");
    let json_dir = json_out_from(&argv);
    let mut report = JsonReport::new("codec");
    let dir = baf::runtime::default_artifact_dir();
    let budget = if smoke { 30.0 } else { 300.0 };

    // ---- real-tensor comparison table (E4 proper) ----
    if !smoke && dir.join("manifest.json").exists() {
        let ctx = Context::open(&dir, 32)?;
        let rows = codec_table(&ctx, &[8, 16, 32], &[2, 4, 6, 8])?;
        println!("{}", codec_table_fmt(&rows));
        // FLIF-property assertion: TLC rate grows with n
        for &c in &[8usize, 16, 32] {
            let tlc: Vec<f64> = rows
                .iter()
                .filter(|r| r.codec == "tlc" && r.c == c)
                .map(|r| r.mean_bytes)
                .collect();
            assert!(
                tlc.windows(2).all(|w| w[0] < w[1]),
                "TLC rate must grow with n at C={c}: {tlc:?}"
            );
        }
    } else if !smoke {
        eprintln!("[bench_codec] no artifacts — skipping real-tensor table");
    }

    // ---- hot-path micro-benches (synthetic 128x128 plane) ----
    println!("codec hot-path micro-benches (128x128 plane):");
    let (w, h) = (128usize, 128usize);
    for n in [4u8, 8] {
        let plane = synthetic_plane(w, h, n, 42);
        for codec in [CodecKind::Tlc, CodecKind::PngLike, CodecKind::ZstdRaw] {
            let enc = codec.encode_image(&plane, w, h, n, 0);
            let s = time_fn(
                || {
                    std::hint::black_box(codec.encode_image(&plane, w, h, n, 0));
                },
                3,
                20,
                budget,
            );
            println!(
                "{}  ({} bytes, {:.1} MB/s enc)",
                fmt_stats(&format!("{} encode n={n}", codec.name()), &s),
                enc.len(),
                (w * h) as f64 / s.mean_us
            );
            let meta = ImageMeta { width: w, height: h, n };
            let sd = time_fn(
                || {
                    std::hint::black_box(codec.decode_image(&enc, &meta, 0).unwrap());
                },
                3,
                20,
                budget,
            );
            println!(
                "{}  ({:.1} MB/s dec)",
                fmt_stats(&format!("{} decode n={n}", codec.name()), &sd),
                (w * h) as f64 / sd.mean_us
            );
            let case = format!("{}_n{n}", codec.name());
            report.stats(&format!("{case}_encode"), &s);
            report.stats(&format!("{case}_decode"), &sd);
            report.metric(&format!("{case}_encode"), "bytes", enc.len());
            report.metric(
                &format!("{case}_encode"),
                "throughput_msamples_s",
                (w * h) as f64 / s.mean_us,
            );
            report.metric(
                &format!("{case}_decode"),
                "throughput_msamples_s",
                (w * h) as f64 / sd.mean_us,
            );
        }
    }

    // ---- striped-container scaling (the parallel-codec tentpole) ----
    // 64 channels of 48x48 -> a 384x384 tiled plane, the paper's C=64
    // operating point. Encode+decode the same tensor at K stripes with a
    // K-wide pool; the whole round trip recycles through one scratch pool.
    println!("\nstriped container scaling (TLC, C=64, 48x48 channels):");
    let q = synthetic_quant(64, 48, 48, 8);
    let samples = (64 * 48 * 48) as f64;
    let v1_len = pack(&q, CodecKind::Tlc, 0).len();
    println!("  v1 frame: {v1_len} bytes");
    report.metric("striped_summary", "v1_bytes", v1_len);
    let scratch = ScratchPool::new();
    let mut combined: Vec<(usize, f64)> = Vec::new();
    for k in [1usize, 2, 4, 8] {
        let pool = WorkerPool::new(k);
        let len = roundtrip(&q, k, &pool, &scratch);
        let s = time_fn(
            || {
                std::hint::black_box(roundtrip(&q, k, &pool, &scratch));
            },
            2,
            if smoke { 3 } else { 10 },
            if smoke { 60.0 } else { 1500.0 },
        );
        let tput = samples / s.mean_us; // Msamples/s through enc+dec
        println!(
            "{}  ({len} bytes, {tput:.1} Msamples/s enc+dec)",
            fmt_stats(&format!("tlc striped K={k}"), &s)
        );
        let case = format!("striped_tlc_k{k}");
        report.stats(&case, &s);
        report.metric(&case, "bytes", len);
        report.metric(&case, "throughput_msamples_s", tput);
        report.metric(&case, "size_overhead_vs_v1", len as f64 / v1_len as f64 - 1.0);
        combined.push((k, tput));
        // acceptance: stripe restarts must stay within 1% of the v1
        // bitstream at the paper-scale tensor for K <= 4
        if k <= 4 {
            assert!(
                len as f64 <= v1_len as f64 * 1.01,
                "K={k} frame is {len} bytes, more than 1% over v1's {v1_len}"
            );
        }
    }

    // acceptance: >= 2x combined throughput at K=4 vs K=1 — only
    // meaningful when the machine actually has >= 4 cores
    let cores = std::thread::available_parallelism().map(|c| c.get()).unwrap_or(1);
    let t1 = combined.iter().find(|(k, _)| *k == 1).map(|(_, t)| *t).unwrap();
    let t4 = combined.iter().find(|(k, _)| *k == 4).map(|(_, t)| *t).unwrap();
    let speedup = t4 / t1;
    println!("  K=4 vs K=1 enc+dec speedup: {speedup:.2}x ({cores} cores)");
    report.metric("striped_summary", "speedup_k4", speedup);
    report.metric("striped_summary", "cores", cores);
    if cores >= 4 && !smoke {
        assert!(
            speedup >= 2.0,
            "striped codec must reach 2x at K=4 on {cores} cores, got {speedup:.2}x"
        );
    }

    // acceptance: zero codec-layer allocations per frame at steady state
    // — after warmup, further round trips must not add a single scratch
    // miss (every take is served by a recycled buffer)
    for _ in 0..5 {
        roundtrip(&q, 4, &WorkerPool::new(4), &scratch);
    }
    let warm = scratch.stats();
    for _ in 0..20 {
        roundtrip(&q, 4, &WorkerPool::new(4), &scratch);
    }
    let steady = scratch.stats();
    println!(
        "  scratch after warmup: {} hits, {} misses (+{} misses over 20 steady frames)",
        steady.hits,
        steady.misses,
        steady.misses - warm.misses
    );
    report.metric("striped_summary", "steady_state_misses", steady.misses - warm.misses);
    assert_eq!(
        steady.misses, warm.misses,
        "steady-state round trips must not allocate (scratch misses grew)"
    );

    // ---- lossy codec RD sanity ----
    if !smoke {
        println!("\nMIC lossy micro-bench (128x128 plane, n=8):");
        let plane = synthetic_plane(w, h, 8, 7);
        for qp in [4u8, 16, 28, 40] {
            let enc = CodecKind::Mic.encode_image(&plane, w, h, 8, qp);
            let s = time_fn(
                || {
                    std::hint::black_box(CodecKind::Mic.encode_image(&plane, w, h, 8, qp));
                },
                2,
                10,
                200.0,
            );
            println!(
                "{}  ({} bytes)",
                fmt_stats(&format!("mic encode qp={qp}"), &s),
                enc.len()
            );
            let case = format!("mic_qp{qp}");
            report.stats(&case, &s);
            report.metric(&case, "bytes", enc.len());
        }
    }

    if let Some(dir) = json_dir {
        std::fs::create_dir_all(&dir)?;
        let path = report.write(&dir)?;
        println!("\nJSON results -> {}", path.display());
    }
    Ok(())
}
