//! E4 — lossless codec comparison on tiled quantized tensors (the [5]
//! comparison): TLC (FLIF stand-in) vs PNG-like vs zstd, rate and
//! throughput, across C and n. Also micro-benchmarks of the codec hot
//! paths on synthetic planes (used by the §Perf iteration log).
//!
//! Run: `cargo bench --bench bench_codec`.


#![allow(clippy::unwrap_used, clippy::expect_used)]

use baf::bench::{fmt_stats, time_fn};
use baf::codec::{CodecKind, ImageMeta};
use baf::experiments::{codec_table, codec_table_fmt, Context};
use baf::util::SplitMix64;

fn synthetic_plane(w: usize, h: usize, n: u8, seed: u64) -> Vec<u16> {
    // smooth field + noise: representative of tiled BN-output tensors
    let mut r = SplitMix64::new(seed);
    let maxv = ((1u32 << n) - 1) as f32;
    (0..w * h)
        .map(|i| {
            let x = (i % w) as f32 / w as f32;
            let y = (i / w) as f32 / h as f32;
            let v = 0.5
                + 0.25 * (x * 9.0).sin() * (y * 7.0).cos()
                + 0.08 * (r.next_f32() - 0.5);
            (v.clamp(0.0, 1.0) * maxv) as u16
        })
        .collect()
}

fn main() -> anyhow::Result<()> {
    baf::util::logging::init();
    let dir = baf::runtime::default_artifact_dir();

    // ---- real-tensor comparison table (E4 proper) ----
    if dir.join("manifest.json").exists() {
        let ctx = Context::open(&dir, 32)?;
        let rows = codec_table(&ctx, &[8, 16, 32], &[2, 4, 6, 8])?;
        println!("{}", codec_table_fmt(&rows));
        // FLIF-property assertion: TLC rate grows with n
        for &c in &[8usize, 16, 32] {
            let tlc: Vec<f64> = rows
                .iter()
                .filter(|r| r.codec == "tlc" && r.c == c)
                .map(|r| r.mean_bytes)
                .collect();
            assert!(
                tlc.windows(2).all(|w| w[0] < w[1]),
                "TLC rate must grow with n at C={c}: {tlc:?}"
            );
        }
    } else {
        eprintln!("[bench_codec] no artifacts — skipping real-tensor table");
    }

    // ---- hot-path micro-benches (synthetic 128x128 plane) ----
    println!("codec hot-path micro-benches (128x128 plane):");
    let (w, h) = (128usize, 128usize);
    for n in [4u8, 8] {
        let plane = synthetic_plane(w, h, n, 42);
        for codec in [CodecKind::Tlc, CodecKind::PngLike, CodecKind::ZstdRaw] {
            let enc = codec.encode_image(&plane, w, h, n, 0);
            let s = time_fn(
                || {
                    std::hint::black_box(codec.encode_image(&plane, w, h, n, 0));
                },
                3,
                20,
                300.0,
            );
            println!(
                "{}  ({} bytes, {:.1} MB/s enc)",
                fmt_stats(&format!("{} encode n={n}", codec.name()), &s),
                enc.len(),
                (w * h) as f64 / s.mean_us
            );
            let meta = ImageMeta { width: w, height: h, n };
            let sd = time_fn(
                || {
                    std::hint::black_box(codec.decode_image(&enc, &meta, 0).unwrap());
                },
                3,
                20,
                300.0,
            );
            println!(
                "{}  ({:.1} MB/s dec)",
                fmt_stats(&format!("{} decode n={n}", codec.name()), &sd),
                (w * h) as f64 / sd.mean_us
            );
        }
    }
    // lossy codec RD sanity
    println!("\nMIC lossy micro-bench (128x128 plane, n=8):");
    let plane = synthetic_plane(w, h, 8, 7);
    for qp in [4u8, 16, 28, 40] {
        let enc = CodecKind::Mic.encode_image(&plane, w, h, 8, qp);
        let s = time_fn(
            || {
                std::hint::black_box(CodecKind::Mic.encode_image(&plane, w, h, 8, qp));
            },
            2,
            10,
            200.0,
        );
        println!("{}  ({} bytes)", fmt_stats(&format!("mic encode qp={qp}"), &s), enc.len());
    }
    Ok(())
}
