//! E6 — design-choice ablations called out in DESIGN.md:
//!   * channel-selection policy (Eq. 2–3 correlation vs variance vs
//!     random vs first-C), isolated from BaF via the beta-fill
//!     reconstruction;
//!   * Eq. 6 consolidation on/off across quantizer depths;
//!   * split vs fused cloud graph (BaF + consolidate + tail in one HLO,
//!     using the Pallas consolidate kernel in-graph) — execution-time
//!     comparison of the two deployments.
//!
//! Run: `cargo bench --bench bench_ablation` (`--json-out [DIR]` writes
//! `BENCH_ablation.json`).


#![allow(clippy::unwrap_used, clippy::expect_used)]

use baf::bench::{fmt_stats, json_out_dir, time_fn, JsonReport};
use baf::codec::CodecKind;
use baf::experiments::Context;
use baf::quant;
use baf::runtime::Engine;
use baf::selection::Policy;
use baf::tensor::{gather_channels_hwc_to_chw, Tensor};
use std::rc::Rc;

fn main() -> anyhow::Result<()> {
    baf::util::logging::init();
    let json_dir = json_out_dir();
    let mut report = JsonReport::new("ablation");
    let dir = baf::runtime::default_artifact_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("[bench_ablation] no artifacts — run `make artifacts` first");
        return Ok(());
    }
    let images: usize = std::env::var("BAF_EVAL_IMAGES")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(96);
    let ctx = Context::open(&dir, images)?;

    println!("selection policy (beta-fill, no BaF, C=16, n=8):");
    println!("| policy | mAP@0.5 | bytes/img |");
    println!("|---|---|---|");
    let mut corr_map = 0.0;
    let mut rand_map = 0.0;
    for p in [Policy::Correlation, Policy::Variance, Policy::FirstC, Policy::Random(1)] {
        let (map, bytes) = ctx.beta_fill(p, 16, 8)?;
        if p == Policy::Correlation {
            corr_map = map;
        }
        if matches!(p, Policy::Random(_)) {
            rand_map = map;
        }
        println!("| {} | {map:.4} | {bytes:.0} |", p.name());
        let case = format!("policy_{}", p.name());
        report.metric(&case, "map_50", map);
        report.metric(&case, "bytes", bytes);
    }
    let (baf_map, _) = ctx.point(16, 8, CodecKind::Tlc, 0)?;
    println!("| correlation + BaF | {baf_map:.4} | (same rate) |");
    report.metric("policy_correlation_baf", "map_50", baf_map);
    assert!(
        baf_map > corr_map,
        "BaF must improve over no-prediction ({baf_map} vs {corr_map})"
    );
    let _ = rand_map;

    println!("\nEq.6 consolidation (C=16):");
    println!("| n | mAP on | mAP off | clamp rate |");
    println!("|---|---|---|---|");
    for n in [4u8, 6, 8] {
        let (on, off, rate) = ctx.consolidation_ablation(16, n)?;
        println!("| {n} | {on:.4} | {off:.4} | {rate:.4} |");
        let case = format!("consolidation_n{n}");
        report.metric(&case, "map_on", on);
        report.metric(&case, "map_off", off);
        report.metric(&case, "clamp_rate", rate);
    }

    // ---- split vs fused cloud graph ----
    println!("\nsplit vs fused cloud graph (C=16, n=8, single request):");
    let engine = Rc::new(Engine::new(&dir)?);
    let m = engine.manifest().clone();
    let stats = baf::selection::ChannelStats::load(&dir)?;
    let sel = stats.select(Policy::Correlation, 16);
    // prepare one decoded frame worth of inputs
    let sample = baf::data::eval_set(1).remove(0);
    let img = sample.image.clone().reshape(&[1, m.image_size, m.image_size, 3]);
    let z = engine
        .run("frontend_b1", &[&img])?
        .reshape(&[m.z_shape.0, m.z_shape.1, m.z_shape.2]);
    let planes = gather_channels_hwc_to_chw(&z, &sel);
    let q = quant::quantize(&planes, 8);
    let zhat = baf::tensor::chw_to_hwc(&quant::dequantize(&q)).reshape(&[
        1,
        m.z_shape.0,
        m.z_shape.1,
        16,
    ]);

    let baf_exe = engine.load("baf_c16_n8_b1")?;
    let tail_exe = engine.load("tail_b1")?;
    let split_stats = time_fn(
        || {
            let zt = baf_exe.run(&[&zhat]).unwrap().reshape(&[
                m.z_shape.0,
                m.z_shape.1,
                m.z_shape.2,
            ]);
            let mut ztm = zt;
            let pred = gather_channels_hwc_to_chw(&ztm, &sel);
            let cons = quant::consolidate(&pred, &q);
            baf::tensor::scatter_channels_chw_into_hwc(&cons, &sel, &mut ztm);
            let zin = ztm.reshape(&[1, m.z_shape.0, m.z_shape.1, m.z_shape.2]);
            std::hint::black_box(tail_exe.run(&[&zin]).unwrap());
        },
        3,
        20,
        2000.0,
    );
    println!("{}", fmt_stats("split graph (2 PJRT calls + rust Eq.6)", &split_stats));
    report.stats("split_graph", &split_stats);

    if engine.load("fused_c16_n8_b1").is_ok() {
        let fused = engine.load("fused_c16_n8_b1")?;
        // fused graph wants q as f32 (1, C, H, W) + minmax (C, 2)
        let qf = Tensor::from_vec(
            &[1, 16, m.z_shape.0, m.z_shape.1],
            q.bins.iter().map(|&b| b as f32).collect(),
        );
        let mm = Tensor::from_vec(
            &[16, 2],
            q.ranges.iter().flat_map(|r| [r.min, r.max]).collect(),
        );
        let fused_stats = time_fn(
            || {
                std::hint::black_box(fused.run(&[&zhat, &qf, &mm]).unwrap());
            },
            3,
            20,
            2000.0,
        );
        println!("{}", fmt_stats("fused graph (1 PJRT call, Eq.6 in-HLO)", &fused_stats));
        println!(
            "fused / split mean ratio: {:.3}",
            fused_stats.mean_us / split_stats.mean_us
        );
        report.stats("fused_graph", &fused_stats);
        report.metric(
            "fused_graph",
            "fused_split_ratio",
            fused_stats.mean_us / split_stats.mean_us,
        );
    } else {
        println!("(fused artifact not present)");
    }
    if let Some(dir) = json_dir {
        std::fs::create_dir_all(&dir)?;
        let path = report.write(&dir)?;
        println!("JSON results -> {}", path.display());
    }
    Ok(())
}
