//! E1 / Fig. 3 — mAP vs number of transmitted channels (n = 8).
//!
//! Regenerates the paper's Fig. 3: the mAP curve over the C sweep against
//! the cloud-only benchmark line, plus the no-prediction (beta-fill)
//! control that shows how much of the recovery is due to BaF itself.
//!
//! Run: `cargo bench --bench bench_fig3` (BAF_EVAL_IMAGES overrides the
//! eval-set size; BAF_ARTIFACTS overrides the artifact dir;
//! `--json-out [DIR]` writes `BENCH_fig3.json`).


#![allow(clippy::unwrap_used, clippy::expect_used)]

use baf::bench::{json_out_dir, JsonReport};
use baf::experiments::{fig3, fig3_table, Context, DEFAULT_EVAL_IMAGES};

fn main() -> anyhow::Result<()> {
    baf::util::logging::init();
    let json_dir = json_out_dir();
    let mut report = JsonReport::new("fig3");
    let images: usize = std::env::var("BAF_EVAL_IMAGES")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(DEFAULT_EVAL_IMAGES);
    let dir = baf::runtime::default_artifact_dir();
    eprintln!("[bench_fig3] artifacts={} images={images}", dir.display());
    let ctx = Context::open(&dir, images)?;
    let (cloud_map, rows) = fig3(&ctx, &[4, 8, 16, 32, 64])?;
    println!("{}", fig3_table(cloud_map, &rows));
    report.metric("cloud_only", "map_50", cloud_map);
    for r in &rows {
        let case = format!("c{}", r.c);
        report.metric(&case, "map_50", r.map_50);
        report.metric(&case, "beta_fill_map", r.beta_fill_map);
        report.metric(&case, "delta_vs_cloud", r.delta_vs_cloud);
        report.metric(&case, "mean_bytes", r.mean_bytes);
    }
    // paper-shape assertions: monotone-ish saturation toward cloud-only
    let full = rows.last().expect("rows");
    assert!(
        full.delta_vs_cloud.abs() < 0.02,
        "C = P should recover cloud-only mAP (delta {})",
        full.delta_vs_cloud
    );
    assert!(
        rows.iter().all(|r| r.map_50 >= r.beta_fill_map - 0.02),
        "BaF must not lose to the no-prediction control"
    );
    if let Some(dir) = json_dir {
        std::fs::create_dir_all(&dir)?;
        let path = report.write(&dir)?;
        eprintln!("[bench_fig3] JSON results -> {}", path.display());
    }
    Ok(())
}
