//! E5 — end-to-end serving: latency breakdown, throughput, and the
//! dynamic-batching ablation (batch cap 1 vs 4 vs 8), plus offered-load
//! scaling. This is the coordinator-contribution bench: it shows the
//! split pipeline keeps the added (non-inference) work off the critical
//! path and that batching the cloud stage lifts throughput.
//!
//! Run: `cargo bench --bench bench_e2e`.


#![allow(clippy::unwrap_used, clippy::expect_used)]

use baf::config::{PipelineConfig, ServerConfig};
use baf::coordinator::run_server;

fn main() -> anyhow::Result<()> {
    baf::util::logging::init();
    let dir = baf::runtime::default_artifact_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("[bench_e2e] no artifacts — run `make artifacts` first");
        return Ok(());
    }
    let pcfg = PipelineConfig { artifact_dir: dir, ..Default::default() };

    println!("batching ablation (256 requests @ 300/s offered):");
    println!("| batch cap | deadline us | throughput rps | mean batch | p50 e2e ms | p95 e2e ms |");
    println!("|---|---|---|---|---|---|");
    for (cap, deadline) in [(1usize, 0u64), (4, 2000), (8, 2000), (8, 8000)] {
        let scfg = ServerConfig {
            batch_cap: cap,
            batch_deadline_us: deadline,
            arrival_rate: 300.0,
            num_requests: 256,
            decode_workers: 2,
            queue_depth: 64,
            burst_factor: 1.0,
            corrupt_rate: 0.0,
        };
        let r = run_server(&pcfg, &scfg)?;
        let lat = r.metrics.get("latencies").unwrap();
        let e2e = lat.get("5_e2e").unwrap();
        println!(
            "| {cap} | {deadline} | {:.1} | {:.2} | {:.2} | {:.2} |",
            r.throughput_rps,
            r.mean_batch_size,
            e2e.get("p50_us").unwrap().as_f64().unwrap() / 1e3,
            e2e.get("p95_us").unwrap().as_f64().unwrap() / 1e3,
        );
    }

    println!("\noffered-load scaling (batch cap 8, deadline 2 ms):");
    println!("| offered rps | achieved rps | p50 e2e ms | p95 e2e ms |");
    println!("|---|---|---|---|");
    for rate in [50.0, 150.0, 300.0, 600.0] {
        let scfg = ServerConfig {
            batch_cap: 8,
            batch_deadline_us: 2000,
            arrival_rate: rate,
            num_requests: 256,
            decode_workers: 2,
            queue_depth: 64,
            burst_factor: 1.0,
            corrupt_rate: 0.0,
        };
        let r = run_server(&pcfg, &scfg)?;
        let lat = r.metrics.get("latencies").unwrap();
        let e2e = lat.get("5_e2e").unwrap();
        println!(
            "| {rate:.0} | {:.1} | {:.2} | {:.2} |",
            r.throughput_rps,
            e2e.get("p50_us").unwrap().as_f64().unwrap() / 1e3,
            e2e.get("p95_us").unwrap().as_f64().unwrap() / 1e3,
        );
    }

    println!("\nbursty arrivals (MMPP-2, mean 300/s, cap 8):");
    println!("| burst factor | achieved rps | p50 e2e ms | p95 e2e ms | p99 e2e ms |");
    println!("|---|---|---|---|---|");
    for bf in [1.0f64, 4.0, 10.0] {
        let scfg = ServerConfig {
            batch_cap: 8,
            batch_deadline_us: 2000,
            arrival_rate: 300.0,
            num_requests: 256,
            decode_workers: 2,
            queue_depth: 64,
            burst_factor: bf,
            corrupt_rate: 0.0,
        };
        let r = run_server(&pcfg, &scfg)?;
        let lat = r.metrics.get("latencies").unwrap();
        let e2e = lat.get("5_e2e").unwrap();
        println!(
            "| {bf:.0} | {:.1} | {:.2} | {:.2} | {:.2} |",
            r.throughput_rps,
            e2e.get("p50_us").unwrap().as_f64().unwrap() / 1e3,
            e2e.get("p95_us").unwrap().as_f64().unwrap() / 1e3,
            e2e.get("p99_us").unwrap().as_f64().unwrap() / 1e3,
        );
    }

    println!("\nfull stage table at 300/s, cap 8:");
    let scfg = ServerConfig {
        batch_cap: 8,
        batch_deadline_us: 2000,
        arrival_rate: 300.0,
        num_requests: 256,
        decode_workers: 2,
        queue_depth: 64,
        burst_factor: 1.0,
            corrupt_rate: 0.0,
    };
    let r = run_server(&pcfg, &scfg)?;
    println!("{}", r.table);
    Ok(())
}
