//! E5 — end-to-end serving: latency breakdown, throughput, and the
//! dynamic-batching ablation (batch cap 1 vs 4 vs 8), plus offered-load
//! scaling. This is the coordinator-contribution bench: it shows the
//! split pipeline keeps the added (non-inference) work off the critical
//! path and that batching the cloud stage lifts throughput.
//!
//! Run: `cargo bench --bench bench_e2e` (`--json-out [DIR]` writes
//! `BENCH_e2e.json`).


#![allow(clippy::unwrap_used, clippy::expect_used)]

use baf::bench::{json_out_from, JsonReport};
use baf::config::{PipelineConfig, ServerConfig};
use baf::coordinator::run_server;

fn main() -> anyhow::Result<()> {
    baf::util::logging::init();
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let json_dir = json_out_from(&argv);
    let mut report = JsonReport::new("e2e");
    let dir = baf::runtime::default_artifact_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("[bench_e2e] no artifacts — run `make artifacts` first");
        return Ok(());
    }
    let pcfg = PipelineConfig { artifact_dir: dir, ..Default::default() };

    println!("batching ablation (256 requests @ 300/s offered):");
    println!("| batch cap | deadline us | throughput rps | mean batch | p50 e2e ms | p95 e2e ms |");
    println!("|---|---|---|---|---|---|");
    for (cap, deadline) in [(1usize, 0u64), (4, 2000), (8, 2000), (8, 8000)] {
        let scfg = ServerConfig {
            batch_cap: cap,
            batch_deadline_us: deadline,
            arrival_rate: 300.0,
            num_requests: 256,
            decode_workers: 2,
            queue_depth: 64,
            burst_factor: 1.0,
            corrupt_rate: 0.0,
            ..Default::default()
        };
        let r = run_server(&pcfg, &scfg)?;
        let lat = r.metrics.get("latencies").unwrap();
        let e2e = lat.get("5_e2e").unwrap();
        let (p50, p95) = (
            e2e.get("p50_us").unwrap().as_f64().unwrap() / 1e3,
            e2e.get("p95_us").unwrap().as_f64().unwrap() / 1e3,
        );
        println!(
            "| {cap} | {deadline} | {:.1} | {:.2} | {p50:.2} | {p95:.2} |",
            r.throughput_rps, r.mean_batch_size,
        );
        let case = format!("batch_cap{cap}_dl{deadline}");
        report.metric(&case, "throughput_rps", r.throughput_rps);
        report.metric(&case, "mean_batch", r.mean_batch_size);
        report.metric(&case, "p50_e2e_ms", p50);
        report.metric(&case, "p95_e2e_ms", p95);
    }

    println!("\noffered-load scaling (batch cap 8, deadline 2 ms):");
    println!("| offered rps | achieved rps | p50 e2e ms | p95 e2e ms |");
    println!("|---|---|---|---|");
    for rate in [50.0, 150.0, 300.0, 600.0] {
        let scfg = ServerConfig {
            batch_cap: 8,
            batch_deadline_us: 2000,
            arrival_rate: rate,
            num_requests: 256,
            decode_workers: 2,
            queue_depth: 64,
            burst_factor: 1.0,
            corrupt_rate: 0.0,
            ..Default::default()
        };
        let r = run_server(&pcfg, &scfg)?;
        let lat = r.metrics.get("latencies").unwrap();
        let e2e = lat.get("5_e2e").unwrap();
        let (p50, p95) = (
            e2e.get("p50_us").unwrap().as_f64().unwrap() / 1e3,
            e2e.get("p95_us").unwrap().as_f64().unwrap() / 1e3,
        );
        println!("| {rate:.0} | {:.1} | {p50:.2} | {p95:.2} |", r.throughput_rps);
        let case = format!("load_{rate:.0}rps");
        report.metric(&case, "throughput_rps", r.throughput_rps);
        report.metric(&case, "p50_e2e_ms", p50);
        report.metric(&case, "p95_e2e_ms", p95);
    }

    println!("\nbursty arrivals (MMPP-2, mean 300/s, cap 8):");
    println!("| burst factor | achieved rps | p50 e2e ms | p95 e2e ms | p99 e2e ms |");
    println!("|---|---|---|---|---|");
    for bf in [1.0f64, 4.0, 10.0] {
        let scfg = ServerConfig {
            batch_cap: 8,
            batch_deadline_us: 2000,
            arrival_rate: 300.0,
            num_requests: 256,
            decode_workers: 2,
            queue_depth: 64,
            burst_factor: bf,
            corrupt_rate: 0.0,
            ..Default::default()
        };
        let r = run_server(&pcfg, &scfg)?;
        let lat = r.metrics.get("latencies").unwrap();
        let e2e = lat.get("5_e2e").unwrap();
        let (p50, p95, p99) = (
            e2e.get("p50_us").unwrap().as_f64().unwrap() / 1e3,
            e2e.get("p95_us").unwrap().as_f64().unwrap() / 1e3,
            e2e.get("p99_us").unwrap().as_f64().unwrap() / 1e3,
        );
        println!(
            "| {bf:.0} | {:.1} | {p50:.2} | {p95:.2} | {p99:.2} |",
            r.throughput_rps,
        );
        let case = format!("burst_{bf:.0}x");
        report.metric(&case, "throughput_rps", r.throughput_rps);
        report.metric(&case, "p50_e2e_ms", p50);
        report.metric(&case, "p95_e2e_ms", p95);
        report.metric(&case, "p99_e2e_ms", p99);
    }

    println!("\nfull stage table at 300/s, cap 8:");
    let scfg = ServerConfig {
        batch_cap: 8,
        batch_deadline_us: 2000,
        arrival_rate: 300.0,
        num_requests: 256,
        decode_workers: 2,
        queue_depth: 64,
        burst_factor: 1.0,
            corrupt_rate: 0.0,
            ..Default::default()
    };
    let r = run_server(&pcfg, &scfg)?;
    println!("{}", r.table);

    if let Some(dir) = json_dir {
        std::fs::create_dir_all(&dir)?;
        let path = report.write(&dir)?;
        println!("JSON results -> {}", path.display());
    }
    Ok(())
}
