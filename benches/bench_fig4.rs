//! E2/E3 / Fig. 4 — rate–mAP curves, headline savings and BD-Bitrate-mAP.
//!
//! Regenerates the paper's Fig. 4: (a) BaF + lossless coding over the n
//! sweep at C = quarter of the channels, (b) BaF 6-bit + lossy transform
//! coding over a QP sweep, (c) the [4]-style baseline that lossy-codes
//! ALL channels at 8 bits, and the cloud-only reference. Prints the
//! bit-savings at <1 % and <2 % mAP loss and the BD-Bitrate-mAP of BaF vs
//! the all-channel baseline (paper: 62 % / 75 % savings; >90 % BD-rate).
//!
//! Run: `cargo bench --bench bench_fig4` (`--json-out [DIR]` writes
//! `BENCH_fig4.json`).


#![allow(clippy::unwrap_used, clippy::expect_used)]

use baf::bench::{json_out_dir, JsonReport};
use baf::experiments::{fig4, fig4_json, fig4_table, Context, DEFAULT_EVAL_IMAGES};

fn main() -> anyhow::Result<()> {
    baf::util::logging::init();
    let json_dir = json_out_dir();
    let mut report = JsonReport::new("fig4");
    let images: usize = std::env::var("BAF_EVAL_IMAGES")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(DEFAULT_EVAL_IMAGES);
    let dir = baf::runtime::default_artifact_dir();
    eprintln!("[bench_fig4] artifacts={} images={images}", dir.display());
    let ctx = Context::open(&dir, images)?;
    let c = 16; // quarter of P=64, the paper's C=64-of-256 analog
    let r = fig4(&ctx, c)?;
    println!("{}", fig4_table(&r, c));
    // machine-readable dump for EXPERIMENTS.md bookkeeping
    let out = dir.join("fig4_results.json");
    baf::json::to_file(&out, &fig4_json(&r))?;
    eprintln!("[bench_fig4] wrote {}", out.display());

    // paper-shape assertions
    let rates: Vec<f64> = r.baf_lossless.iter().map(|(_, p)| p.rate).collect();
    assert!(
        rates.windows(2).all(|w| w[0] < w[1]),
        "lossless rate must grow with n: {rates:?}"
    );
    if let Some(bd) = r.bd_rate_vs_all {
        assert!(bd < 0.0, "BaF should save bits vs all-channel lossy (bd={bd})");
    }

    report.metric("cloud_only", "map_50", r.cloud_map);
    report.metric("cloud_only", "bytes", r.cloud_bytes);
    for (n, p) in &r.baf_lossless {
        let case = format!("baf_lossless_n{n}");
        report.metric(&case, "bytes", p.rate);
        report.metric(&case, "map_50", p.map);
    }
    for (qp, p) in &r.baf_lossy6 {
        let case = format!("baf_lossy6_qp{qp}");
        report.metric(&case, "bytes", p.rate);
        report.metric(&case, "map_50", p.map);
    }
    for (qp, p) in &r.all_lossy {
        let case = format!("all_lossy_qp{qp}");
        report.metric(&case, "bytes", p.rate);
        report.metric(&case, "map_50", p.map);
    }
    if let Some((sav, _)) = r.savings_1pct {
        report.metric("headline", "savings_1pct", sav);
    }
    if let Some((sav, _)) = r.savings_2pct {
        report.metric("headline", "savings_2pct", sav);
    }
    if let Some(bd) = r.bd_rate_vs_all {
        report.metric("headline", "bd_rate_vs_all_pct", bd);
    }
    if let Some(dir) = json_dir {
        std::fs::create_dir_all(&dir)?;
        let path = report.write(&dir)?;
        eprintln!("[bench_fig4] JSON results -> {}", path.display());
    }
    Ok(())
}
