//! # BaF — Back-and-Forth prediction for deep tensor compression
//!
//! A full-system reproduction of Choi, Cohen & Bajić, *"Back-and-Forth
//! prediction for deep tensor compression"* (ICASSP 2020), built as a
//! three-layer Rust + JAX + Pallas stack:
//!
//! * **L3 (this crate)** — the collaborative-intelligence runtime: edge
//!   node (frontend inference, channel selection, quantization, tiling,
//!   entropy coding), cloud node (decoding, BaF prediction, Eq. 6
//!   consolidation, detector tail), a dynamic batcher and a pipelined
//!   server, plus every substrate the paper depends on (lossless + lossy
//!   image codecs, mAP evaluation, BD-rate metrics, a procedural
//!   detection dataset).
//! * **L2 (python/compile, build time only)** — the YOLO-Lite detector
//!   and BaF predictor in JAX, AOT-lowered to HLO text artifacts.
//! * **L1 (python/compile/kernels)** — Pallas kernels for the hot spots
//!   (quantize, consolidate, correlation, split-layer conv+BN) that lower
//!   into the same artifacts.
//!
//! Python never runs on the request path: the `runtime` module loads the
//! HLO artifacts through the PJRT C API (`xla` crate) once and executes
//! them natively thereafter.
//!
//! See DESIGN.md for the architecture and experiment index, and
//! EXPERIMENTS.md for reproduction results.

pub mod bench;
pub mod cli;
pub mod codec;
pub mod config;
pub mod experiments;
pub mod golden;
pub mod coordinator;
pub mod data;
pub mod eval;
pub mod json;
pub mod metrics;
pub mod quant;
pub mod runtime;
pub mod selection;
pub mod tensor;
pub mod tile;
pub mod tio;
pub mod util;
