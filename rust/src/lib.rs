//! # BaF — Back-and-Forth prediction for deep tensor compression
//!
//! A full-system reproduction of Choi, Cohen & Bajić, *"Back-and-Forth
//! prediction for deep tensor compression"* (ICASSP 2020), built as a
//! three-layer Rust + JAX + Pallas stack:
//!
//! * **L3 (this crate)** — the collaborative-intelligence runtime: edge
//!   node (frontend inference, channel selection, quantization, tiling,
//!   entropy coding), cloud node (decoding, BaF prediction, Eq. 6
//!   consolidation, detector tail), a dynamic batcher and a pipelined
//!   server, plus every substrate the paper depends on (lossless + lossy
//!   image codecs, mAP evaluation, BD-rate metrics, a procedural
//!   detection dataset).
//! * **L2 (python/compile, build time only)** — the YOLO-Lite detector
//!   and BaF predictor in JAX, AOT-lowered to HLO text artifacts.
//! * **L1 (python/compile/kernels)** — Pallas kernels for the hot spots
//!   (quantize, consolidate, correlation, split-layer conv+BN) that lower
//!   into the same artifacts.
//!
//! Python never runs on the request path: the `runtime` module loads the
//! HLO artifacts through the PJRT C API (`xla` crate) once and executes
//! them natively thereafter.
//!
//! See DESIGN.md for the architecture and experiment index, and
//! EXPERIMENTS.md for reproduction results.

// The decode path (codec, including the `codec::scratch` buffer pool),
// the network transport (net — it reads attacker-controlled wire bytes)
// and the serving stack (coordinator) carry a no-panic contract:
// attacker-controlled bytes must never unwrap. Tier-1 CI enforces it
// with `cargo clippy --all-targets -- -D clippy::unwrap_used
// -D clippy::expect_used`; the modules outside that contract opt out
// explicitly below (their inputs are trusted, produced by this crate).
// `runtime` opts out as a whole, but `runtime::pool` — which runs codec
// work and must never poison its scope — opts back IN via an inner
// `#![deny]`. Test modules everywhere opt back in via inner `#![allow]`.

#[allow(clippy::unwrap_used, clippy::expect_used)]
pub mod bench;
#[allow(clippy::unwrap_used, clippy::expect_used)]
pub mod cli;
#[deny(clippy::unwrap_used, clippy::expect_used)]
pub mod codec;
#[allow(clippy::unwrap_used, clippy::expect_used)]
pub mod config;
#[allow(clippy::unwrap_used, clippy::expect_used)]
pub mod experiments;
#[allow(clippy::unwrap_used, clippy::expect_used)]
pub mod golden;
#[deny(clippy::unwrap_used, clippy::expect_used)]
pub mod coordinator;
#[allow(clippy::unwrap_used, clippy::expect_used)]
pub mod data;
#[allow(clippy::unwrap_used, clippy::expect_used)]
pub mod eval;
#[allow(clippy::unwrap_used, clippy::expect_used)]
pub mod json;
// the lint walks untrusted-ish source text; hold it to its own standard
#[deny(clippy::unwrap_used, clippy::expect_used)]
pub mod lint;
#[deny(clippy::unwrap_used, clippy::expect_used)]
pub mod metrics;
#[deny(clippy::unwrap_used, clippy::expect_used)]
pub mod net;
#[allow(clippy::unwrap_used, clippy::expect_used)]
pub mod quant;
#[allow(clippy::unwrap_used, clippy::expect_used)]
pub mod runtime;
#[allow(clippy::unwrap_used, clippy::expect_used)]
pub mod selection;
#[allow(clippy::unwrap_used, clippy::expect_used)]
pub mod tensor;
#[allow(clippy::unwrap_used, clippy::expect_used)]
pub mod tile;
#[allow(clippy::unwrap_used, clippy::expect_used)]
pub mod tio;
#[allow(clippy::unwrap_used, clippy::expect_used)]
pub mod util;
