//! Channel tiling (paper §3.2): arrange C quantized channel planes into
//! one rectangular "image" for compression by an image codec.
//!
//! With C a power of two, the tiled layout is
//! `cols = 2^ceil(log2(C)/2)` channels across and `rows = 2^floor(...)`
//! down (e.g. C=64 -> 8x8, C=32 -> 8x4, C=8 -> 4x2); channel k lands at
//! tile (k / cols, k % cols), row-major. Non-power-of-two C is supported
//! by padding with zero tiles (the paper always picks powers of two; we
//! keep the general case for the ablation benches).

use crate::quant::QuantizedTensor;

/// A tiled single-plane image of u16 samples (bit depth <= 16).
#[derive(Debug, Clone, PartialEq)]
pub struct TiledImage {
    pub width: usize,
    pub height: usize,
    /// Samples, row-major, each < 2^n.
    pub samples: Vec<u16>,
    /// Bit depth of the samples.
    pub n: u8,
    /// Tile geometry (cols, rows) and per-tile size (w, h).
    pub cols: usize,
    pub rows: usize,
    pub tile_w: usize,
    pub tile_h: usize,
    /// Number of real (non-padding) channels.
    pub channels: usize,
}

/// Tile geometry per §3.2: cols = 2^ceil(log2 C / 2), rows = 2^floor(...).
///
/// Exact integer bit math: the old `(c as f64).log2().ceil()` loses
/// precision once `c` no longer fits a f64 mantissa (e.g. `2^53 + 1`
/// rounds to 53.0 and yields a grid with fewer cells than channels).
pub fn grid_for(c: usize) -> (usize, usize) {
    assert!(c > 0);
    // ceil(log2 c) without floats: ilog2 is floor(log2 c)
    let lg = if c.is_power_of_two() { c.ilog2() } else { c.ilog2() + 1 };
    let cols = 1usize << lg.div_ceil(2);
    let rows = 1usize << (lg / 2);
    debug_assert!(cols.checked_mul(rows).is_some_and(|cells| cells >= c));
    (cols, rows)
}

/// Arrange quantized channel planes into the tiled image.
pub fn tile(q: &QuantizedTensor) -> TiledImage {
    tile_with_buffer(q, Vec::new())
}

/// Like [`tile`] but building the sample plane in a recycled buffer
/// (cleared and zero-filled here), so steady-state encoding does not
/// allocate — pair with [`crate::codec::scratch::ScratchPool`].
pub fn tile_with_buffer(q: &QuantizedTensor, mut samples: Vec<u16>) -> TiledImage {
    let (cols, rows) = grid_for(q.c);
    let (tw, th) = (q.w, q.h);
    samples.clear();
    samples.resize(cols * tw * rows * th, 0);
    let width = cols * tw;
    for ch in 0..q.c {
        let (ty, tx) = (ch / cols, ch % cols);
        let plane = q.plane(ch);
        for y in 0..th {
            let dst_row = (ty * th + y) * width + tx * tw;
            samples[dst_row..dst_row + tw].copy_from_slice(&plane[y * tw..(y + 1) * tw]);
        }
    }
    TiledImage {
        width,
        height: rows * th,
        samples,
        n: q.n,
        cols,
        rows,
        tile_w: tw,
        tile_h: th,
        channels: q.c,
    }
}

/// Inverse of `tile`: recover the C channel planes (bins only — ranges
/// travel separately as container side info).
pub fn untile(img: &TiledImage) -> Vec<u16> {
    let mut bins = vec![0u16; img.channels * img.tile_h * img.tile_w];
    untile_into(img, &mut bins);
    bins
}

/// [`untile`] into a caller-owned slice of exactly
/// `channels * tile_h * tile_w` samples (trusted local plumbing — a
/// mismatch is a programming error, hence the assert).
pub fn untile_into(img: &TiledImage, bins: &mut [u16]) {
    assert_eq!(bins.len(), img.channels * img.tile_h * img.tile_w);
    for ch in 0..img.channels {
        let (ty, tx) = (ch / img.cols, ch % img.cols);
        for y in 0..img.tile_h {
            let src_row = (ty * img.tile_h + y) * img.width + tx * img.tile_w;
            let dst = ch * img.tile_h * img.tile_w + y * img.tile_w;
            bins[dst..dst + img.tile_w]
                .copy_from_slice(&img.samples[src_row..src_row + img.tile_w]);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::{quantize, QuantizedTensor};
    use crate::tensor::Tensor;
    use crate::util::SplitMix64;

    fn random_quant(c: usize, h: usize, w: usize, n: u8, seed: u64) -> QuantizedTensor {
        let mut r = SplitMix64::new(seed);
        let z = Tensor::from_vec(
            &[c, h, w],
            (0..c * h * w).map(|_| r.next_f32() * 4.0 - 2.0).collect(),
        );
        quantize(&z, n)
    }

    #[test]
    fn grid_matches_paper_formula() {
        assert_eq!(grid_for(8), (4, 2));
        assert_eq!(grid_for(16), (4, 4));
        assert_eq!(grid_for(32), (8, 4));
        assert_eq!(grid_for(64), (8, 8));
        assert_eq!(grid_for(128), (16, 8));
        assert_eq!(grid_for(4), (2, 2));
        assert_eq!(grid_for(1), (1, 1));
    }

    #[test]
    fn grid_is_exact_beyond_f64_mantissa() {
        // non-powers-of-two round up
        assert_eq!(grid_for(5), (4, 2));
        assert_eq!(grid_for(9), (4, 4));
        assert_eq!(grid_for(65), (16, 8));
        // 2^53 + 1: the old float path computed ceil(log2) = 53 (the +1
        // is below f64 resolution) and produced a grid with fewer cells
        // than channels; integer math rounds up to 54 bits
        #[cfg(target_pointer_width = "64")]
        {
            let c = (1usize << 53) + 1;
            let (cols, rows) = grid_for(c);
            assert_eq!((cols, rows), (1 << 27, 1 << 27));
            assert!(cols * rows >= c);
        }
    }

    #[test]
    fn tile_with_buffer_reuses_capacity() {
        let q = random_quant(8, 8, 8, 8, 11);
        let img = tile(&q);
        let buf = Vec::with_capacity(img.samples.len());
        let cap = buf.capacity();
        let img2 = tile_with_buffer(&q, buf);
        assert_eq!(img2, img);
        assert_eq!(img2.samples.capacity(), cap);
        let mut bins = vec![0u16; q.bins.len()];
        untile_into(&img2, &mut bins);
        assert_eq!(bins, q.bins);
    }

    #[test]
    fn tile_untile_roundtrip() {
        for &c in &[4usize, 8, 16, 32, 64] {
            let q = random_quant(c, 16, 16, 8, c as u64);
            let img = tile(&q);
            assert_eq!(img.width * img.height, (img.cols * img.rows) * 256);
            assert_eq!(untile(&img), q.bins, "C={c}");
        }
    }

    #[test]
    fn tile_places_channel_zero_top_left() {
        let q = random_quant(8, 4, 4, 6, 3);
        let img = tile(&q);
        for y in 0..4 {
            for x in 0..4 {
                assert_eq!(img.samples[y * img.width + x], q.plane(0)[y * 4 + x]);
            }
        }
    }

    #[test]
    fn non_power_of_two_pads_with_zeros() {
        let q = random_quant(5, 4, 4, 4, 8);
        let img = tile(&q);
        assert_eq!((img.cols, img.rows), (4, 2));
        assert_eq!(untile(&img).len(), 5 * 16);
        // padding tiles are zero
        let last = img.samples[(img.height - 1) * img.width + img.width - 1];
        assert_eq!(last, 0);
    }
}
