//! Tiny CLI argument parser (clap is unavailable offline).
//!
//! Grammar: `baf <command> [--key value]... [--flag]... [positional]...`
//! `--key=value` is also accepted. Unknown keys are rejected by each
//! command via `expect_known`.
//!
//! Ambiguity rule: `--name token` always binds `token` as the value of
//! `--name` (greedy). A bare flag must therefore come last or be followed
//! by another `--option`; use `--flag --` style ordering when mixing
//! flags and positionals.

use anyhow::{anyhow, bail, Result};
use std::collections::BTreeMap;

#[derive(Debug, Default, Clone)]
pub struct Args {
    pub command: String,
    pub positional: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
}

impl Args {
    /// Parse from raw argv (without the binary name).
    pub fn parse(argv: &[String]) -> Result<Args> {
        let mut out = Args::default();
        let mut it = argv.iter().peekable();
        out.command = it.next().cloned().unwrap_or_else(|| "help".to_string());
        while let Some(arg) = it.next() {
            if let Some(name) = arg.strip_prefix("--") {
                if let Some((k, v)) = name.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if let Some(value) = it.next_if(|n| !n.starts_with("--")) {
                    out.options.insert(name.to_string(), value.clone());
                } else {
                    out.flags.push(name.to_string());
                }
            } else {
                out.positional.push(arg.clone());
            }
        }
        Ok(out)
    }

    pub fn opt(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(String::as_str)
    }

    pub fn opt_parse<T: std::str::FromStr>(&self, key: &str) -> Result<Option<T>> {
        match self.opt(key) {
            None => Ok(None),
            Some(s) => s
                .parse::<T>()
                .map(Some)
                .map_err(|_| anyhow!("--{key}: cannot parse '{s}'")),
        }
    }

    pub fn has_flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    /// Error on unknown option keys/flags (catches typos).
    pub fn expect_known(&self, known: &[&str]) -> Result<()> {
        for k in self.options.keys() {
            if !known.contains(&k.as_str()) {
                bail!("unknown option --{k} for '{}'", self.command);
            }
        }
        for f in &self.flags {
            if !known.contains(&f.as_str()) {
                bail!("unknown flag --{f} for '{}'", self.command);
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    #![allow(clippy::unwrap_used, clippy::expect_used)]

    use super::*;

    fn argv(s: &str) -> Vec<String> {
        s.split_whitespace().map(String::from).collect()
    }

    #[test]
    fn parses_mixed_forms() {
        let a = Args::parse(&argv("sweep pos1 --c 16 --codec=tlc --verbose")).unwrap();
        assert_eq!(a.command, "sweep");
        assert_eq!(a.opt("c"), Some("16"));
        assert_eq!(a.opt("codec"), Some("tlc"));
        assert!(a.has_flag("verbose"));
        assert_eq!(a.positional, vec!["pos1"]);
        // greedy rule: a token after --name binds as its value
        let b = Args::parse(&argv("sweep --verbose pos1")).unwrap();
        assert_eq!(b.opt("verbose"), Some("pos1"));
    }

    #[test]
    fn typed_parse_and_errors() {
        let a = Args::parse(&argv("x --n 6")).unwrap();
        assert_eq!(a.opt_parse::<u8>("n").unwrap(), Some(6));
        assert_eq!(a.opt_parse::<u8>("missing").unwrap(), None);
        let b = Args::parse(&argv("x --n six")).unwrap();
        assert!(b.opt_parse::<u8>("n").is_err());
    }

    #[test]
    fn unknown_option_rejected() {
        let a = Args::parse(&argv("run --typo 3")).unwrap();
        assert!(a.expect_known(&["c", "n"]).is_err());
        let b = Args::parse(&argv("run --c 3")).unwrap();
        assert!(b.expect_known(&["c", "n"]).is_ok());
    }

    #[test]
    fn flag_followed_by_flag() {
        let a = Args::parse(&argv("run --fast --c 4")).unwrap();
        assert!(a.has_flag("fast"));
        assert_eq!(a.opt("c"), Some("4"));
    }
}
