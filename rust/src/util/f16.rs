//! IEEE 754 binary16 conversion (the `half` crate is unavailable offline).
//!
//! Only what the pipeline needs: f32 -> f16 bits (round-to-nearest-even)
//! and back. The quantizer side info (per-channel min/max, §3.2 of the
//! paper) is transmitted as f16, so encoder and decoder must round
//! identically — these routines match the hardware/numpy semantics, which
//! is checked against numpy-produced goldens in `tests/golden.rs`.

/// Convert f32 to IEEE binary16 bits with round-to-nearest-even.
pub fn f32_to_f16_bits(x: f32) -> u16 {
    let bits = x.to_bits();
    let sign = ((bits >> 16) & 0x8000) as u16;
    let mut exp = ((bits >> 23) & 0xff) as i32;
    let mut man = bits & 0x007f_ffff;

    if exp == 255 {
        // Inf / NaN
        return if man == 0 {
            sign | 0x7c00
        } else {
            sign | 0x7e00 // quiet NaN
        };
    }

    exp -= 127; // unbias
    if exp > 15 {
        return sign | 0x7c00; // overflow -> inf
    }
    if exp >= -14 {
        // normal f16
        let mut m = man >> 13; // keep 10 bits
        let rem = man & 0x1fff;
        // round to nearest even
        if rem > 0x1000 || (rem == 0x1000 && (m & 1) == 1) {
            m += 1;
        }
        let mut e = (exp + 15) as u32;
        if m == 0x400 {
            m = 0;
            e += 1;
            if e >= 31 {
                return sign | 0x7c00;
            }
        }
        return sign | ((e as u16) << 10) | m as u16;
    }
    // subnormal f16 (or underflow to zero)
    if exp < -25 {
        return sign; // too small -> +-0
    }
    man |= 0x0080_0000; // implicit leading 1
    let shift = (-14 - exp) as u32 + 13;
    let m = man >> shift;
    let rem = man & ((1u32 << shift) - 1);
    let half = 1u32 << (shift - 1);
    let mut m16 = m as u16;
    if rem > half || (rem == half && (m16 & 1) == 1) {
        m16 += 1; // may carry into the exponent — that is correct
    }
    sign | m16
}

/// Convert IEEE binary16 bits to f32.
pub fn f16_bits_to_f32(h: u16) -> f32 {
    let sign = ((h & 0x8000) as u32) << 16;
    let exp = ((h >> 10) & 0x1f) as u32;
    let man = (h & 0x3ff) as u32;
    let bits = if exp == 0 {
        if man == 0 {
            sign // +-0
        } else {
            // subnormal: value = man * 2^-24; normalize so the implicit
            // bit lands at 0x400 after k shifts -> biased f32 exp = 113-k
            let mut m = man;
            let mut k = 0u32;
            while m & 0x400 == 0 {
                m <<= 1;
                k += 1;
            }
            m &= 0x3ff;
            sign | ((113 - k) << 23) | (m << 13)
        }
    } else if exp == 31 {
        sign | 0x7f80_0000 | (man << 13) // inf / nan
    } else {
        sign | ((exp + 127 - 15) << 23) | (man << 13)
    };
    f32::from_bits(bits)
}

/// Round an f32 through f16 precision (what the side-info channel does).
#[inline]
pub fn round_via_f16(x: f32) -> f32 {
    f16_bits_to_f32(f32_to_f16_bits(x))
}

/// Clamp to the f16-representable range, then round (matches the Python
/// side's `minmax_f16`, which clips to +-65504 before casting).
#[inline]
pub fn saturate_to_f16(x: f32) -> f32 {
    round_via_f16(x.clamp(-65504.0, 65504.0))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exact_small_integers_roundtrip() {
        for i in -2048..=2048 {
            let x = i as f32;
            assert_eq!(round_via_f16(x), x, "{x}");
        }
    }

    #[test]
    fn known_values() {
        assert_eq!(f32_to_f16_bits(1.0), 0x3c00);
        assert_eq!(f32_to_f16_bits(-2.0), 0xc000);
        assert_eq!(f32_to_f16_bits(0.5), 0x3800);
        assert_eq!(f32_to_f16_bits(65504.0), 0x7bff);
        assert_eq!(f16_bits_to_f32(0x3c00), 1.0);
        assert_eq!(f16_bits_to_f32(0x7bff), 65504.0);
        // smallest positive subnormal
        assert!((f16_bits_to_f32(0x0001) - 5.960_464_5e-8).abs() < 1e-12);
    }

    #[test]
    fn round_to_nearest_even() {
        // 1 + 2^-11 is exactly between 1.0 and the next f16 (1 + 2^-10):
        // ties-to-even keeps 1.0.
        let x = 1.0 + f32::powi(2.0, -11);
        assert_eq!(round_via_f16(x), 1.0);
        // 1 + 3*2^-11 ties between (1+2^-10) and (1+2^-9): even -> 1+2^-9.
        let y = 1.0 + 3.0 * f32::powi(2.0, -11);
        assert_eq!(round_via_f16(y), 1.0 + f32::powi(2.0, -9));
    }

    #[test]
    fn subnormal_roundtrip() {
        for bits in [0x0001u16, 0x0155, 0x03ff, 0x8001, 0x83ff] {
            let f = f16_bits_to_f32(bits);
            assert_eq!(f32_to_f16_bits(f), bits, "bits {bits:#x}");
        }
    }

    #[test]
    fn overflow_saturates() {
        assert_eq!(saturate_to_f16(1e9), 65504.0);
        assert_eq!(saturate_to_f16(-1e9), -65504.0);
        assert_eq!(f32_to_f16_bits(1e9), 0x7c00); // raw conversion -> inf
    }

    #[test]
    fn monotone_on_grid() {
        let mut prev = f16_bits_to_f32(0);
        for bits in 1..0x7c00u16 {
            let v = f16_bits_to_f32(bits);
            assert!(v > prev, "bits {bits:#x}");
            prev = v;
        }
    }
}
