//! Foundation utilities: PRNG, f16, timing, thread pool, logging.

pub mod f16;
pub mod logging;
pub mod pool;
pub mod prng;
pub mod timer;

pub use f16::{f16_bits_to_f32, f32_to_f16_bits, round_via_f16, saturate_to_f16};
pub use prng::SplitMix64;
pub use timer::{StageClock, Timer};
