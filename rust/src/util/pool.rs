//! A small fixed-size thread pool with scoped parallel-for.
//!
//! Rationale: rayon/tokio are unavailable offline; the coordinator's
//! pipeline threads are long-lived and hand-rolled (see
//! `coordinator::server`), but data-parallel loops (dataset generation,
//! codec benchmarks, mAP evaluation over many images) want a simple
//! `parallel_for` — this is it. Work is distributed in contiguous chunks;
//! the closure must be `Sync` and output slots are disjoint, so no locks
//! are taken on the hot path.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

/// Number of worker threads to use for data-parallel helpers.
pub fn default_parallelism() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4)
}

/// Run `f(i)` for every `i in 0..n` across `threads` OS threads.
///
/// Indices are claimed from a shared atomic in blocks of `chunk`, which
/// keeps scheduling overhead negligible while still load-balancing uneven
/// work (e.g. images with different shape counts).
pub fn parallel_for<F>(n: usize, chunk: usize, f: F)
where
    F: Fn(usize) + Sync,
{
    let threads = default_parallelism().min(n.max(1));
    if threads <= 1 || n <= chunk {
        for i in 0..n {
            f(i);
        }
        return;
    }
    let next = Arc::new(AtomicUsize::new(0));
    let chunk = chunk.max(1);
    std::thread::scope(|scope| {
        for _ in 0..threads {
            let next = Arc::clone(&next);
            let f = &f;
            scope.spawn(move || loop {
                let start = next.fetch_add(chunk, Ordering::Relaxed);
                if start >= n {
                    break;
                }
                for i in start..(start + chunk).min(n) {
                    f(i);
                }
            });
        }
    });
}

/// Map `f` over `0..n` in parallel, collecting results in order.
pub fn parallel_map<T, F>(n: usize, chunk: usize, f: F) -> Vec<T>
where
    T: Send + Default + Clone,
    F: Fn(usize) -> T + Sync,
{
    let mut out = vec![T::default(); n];
    {
        let slots = SyncSlice(out.as_mut_ptr());
        let slots_ref = &slots; // capture the wrapper, not the raw field
        parallel_for(n, chunk, move |i| {
            // SAFETY: each index i is claimed by exactly one worker, and
            // the vector outlives the scope inside parallel_for.
            unsafe { slots_ref.write(i, f(i)) };
        });
    }
    out
}

struct SyncSlice<T>(*mut T);

impl<T> SyncSlice<T> {
    /// SAFETY: caller guarantees exclusive access to slot `i` and that the
    /// backing allocation outlives the call.
    unsafe fn write(&self, i: usize, v: T) {
        unsafe { *self.0.add(i) = v };
    }
}

// SAFETY: SyncSlice is only a channel for disjoint-slot writes — every
// user hands each index to exactly one worker (see `write`'s contract),
// so sharing the wrapper across threads cannot alias a slot. T: Send
// because slot values move to the writing thread.
unsafe impl<T: Send> Sync for SyncSlice<T> {}
// SAFETY: the wrapper holds a raw pointer into a Vec owned by the
// caller's stack frame, which outlives the scoped threads; moving the
// wrapper moves only the pointer, never the allocation.
unsafe impl<T: Send> Send for SyncSlice<T> {}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn parallel_for_covers_every_index_once() {
        let hits: Vec<AtomicU64> = (0..1000).map(|_| AtomicU64::new(0)).collect();
        parallel_for(1000, 7, |i| {
            hits[i].fetch_add(1, Ordering::Relaxed);
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn parallel_map_preserves_order() {
        let out = parallel_map(257, 8, |i| i * i);
        for (i, v) in out.iter().enumerate() {
            assert_eq!(*v, i * i);
        }
    }

    #[test]
    fn empty_and_single() {
        let out: Vec<usize> = parallel_map(0, 4, |i| i);
        assert!(out.is_empty());
        let one = parallel_map(1, 4, |i| i + 5);
        assert_eq!(one, vec![5]);
    }
}
