//! SplitMix64 — the cross-language deterministic PRNG (Rust twin).
//!
//! The specification lives in `python/compile/prng.py`; the two
//! implementations are pinned against each other through
//! `artifacts/golden/prng.json` (see `tests/golden.rs`).
//!
//! SplitMix64 is counter-based: draw `j` (0-indexed) of a stream seeded
//! with `s` equals `mix(s + (j+1)*GAMMA)`, which lets NumPy generate the
//! same stream vectorized while Rust walks it sequentially.

pub const GAMMA: u64 = 0x9E37_79B9_7F4A_7C15;
const MIX1: u64 = 0xBF58_476D_1CE4_E5B9;
const MIX2: u64 = 0x94D0_49BB_1331_11EB;

/// The SplitMix64 output function applied to a raw state value.
#[inline]
pub fn mix(mut z: u64) -> u64 {
    z = (z ^ (z >> 30)).wrapping_mul(MIX1);
    z = (z ^ (z >> 27)).wrapping_mul(MIX2);
    z ^ (z >> 31)
}

/// Sequential SplitMix64 stream.
#[derive(Debug, Clone)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    /// Next raw 64-bit output.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(GAMMA);
        mix(self.state)
    }

    /// Uniform f32 in [0, 1) with 24 bits of precision.
    ///
    /// Contract: `(next_u64() >> 40) as f32 / 2^24` — identical to the
    /// Python side's `to_f32`.
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u32 << 24) as f32)
    }

    /// Uniform integer in [lo, hi). Panics if the range is empty.
    #[inline]
    pub fn next_range(&mut self, lo: i64, hi: i64) -> i64 {
        assert!(hi > lo, "next_range needs a non-empty range");
        lo + (self.next_u64() % (hi - lo) as u64) as i64
    }

    /// Derive an independent stream.
    pub fn fork(&mut self) -> SplitMix64 {
        SplitMix64::new(self.next_u64())
    }

    /// Uniform f64 in [0, 1) with 53 bits (for workload generators that
    /// do not need cross-language exactness, e.g. Poisson arrivals).
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Exponentially distributed inter-arrival time with the given rate.
    #[inline]
    pub fn next_exp(&mut self, rate: f64) -> f64 {
        let u = self.next_f64().max(1e-12);
        -u.ln() / rate
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = (self.next_u64() % (i as u64 + 1)) as usize;
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_based_equals_sequential() {
        let mut seq = SplitMix64::new(12345);
        for j in 0..100u64 {
            let counter = mix(12345u64.wrapping_add((j + 1).wrapping_mul(GAMMA)));
            assert_eq!(seq.next_u64(), counter, "draw {j}");
        }
    }

    #[test]
    fn f32_in_unit_interval() {
        let mut r = SplitMix64::new(7);
        for _ in 0..1000 {
            let f = r.next_f32();
            assert!((0.0..1.0).contains(&f));
        }
    }

    #[test]
    fn range_bounds_inclusive_exclusive() {
        let mut r = SplitMix64::new(9);
        let mut seen_lo = false;
        let mut seen_hi = false;
        for _ in 0..10_000 {
            let v = r.next_range(3, 7);
            assert!((3..7).contains(&v));
            seen_lo |= v == 3;
            seen_hi |= v == 6;
        }
        assert!(seen_lo && seen_hi);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = SplitMix64::new(1);
        let mut v: Vec<u32> = (0..50).collect();
        r.shuffle(&mut v);
        let mut s = v.clone();
        s.sort_unstable();
        assert_eq!(s, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn streams_differ_by_seed() {
        let a: Vec<u64> = {
            let mut r = SplitMix64::new(1);
            (0..8).map(|_| r.next_u64()).collect()
        };
        let b: Vec<u64> = {
            let mut r = SplitMix64::new(2);
            (0..8).map(|_| r.next_u64()).collect()
        };
        assert_ne!(a, b);
    }
}
