//! Lightweight timing helpers used by the coordinator and bench harness.

use std::time::{Duration, Instant};

/// A simple scope timer: `let t = Timer::start(); ...; t.elapsed_ms()`.
#[derive(Debug, Clone, Copy)]
pub struct Timer {
    t0: Instant,
}

impl Timer {
    pub fn start() -> Self {
        Self { t0: Instant::now() }
    }

    pub fn elapsed(&self) -> Duration {
        self.t0.elapsed()
    }

    pub fn elapsed_ms(&self) -> f64 {
        self.t0.elapsed().as_secs_f64() * 1e3
    }

    pub fn elapsed_us(&self) -> f64 {
        self.t0.elapsed().as_secs_f64() * 1e6
    }
}

/// Per-stage latency breakdown of one request through the pipeline.
/// All values in microseconds; `record` accumulates named stages in order.
#[derive(Debug, Clone, Default)]
pub struct StageClock {
    stages: Vec<(&'static str, f64)>,
    last: Option<Instant>,
}

impl StageClock {
    pub fn new() -> Self {
        Self { stages: Vec::with_capacity(8), last: Some(Instant::now()) }
    }

    /// Close the current stage under `name` and start the next one.
    pub fn lap(&mut self, name: &'static str) {
        let now = Instant::now();
        if let Some(prev) = self.last {
            self.stages.push((name, now.duration_since(prev).as_secs_f64() * 1e6));
        }
        self.last = Some(now);
    }

    pub fn stages(&self) -> &[(&'static str, f64)] {
        &self.stages
    }

    pub fn total_us(&self) -> f64 {
        self.stages.iter().map(|(_, v)| v).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stage_clock_accumulates_in_order() {
        let mut c = StageClock::new();
        std::thread::sleep(Duration::from_millis(2));
        c.lap("a");
        c.lap("b");
        assert_eq!(c.stages().len(), 2);
        assert_eq!(c.stages()[0].0, "a");
        assert!(c.stages()[0].1 >= 1000.0, "{:?}", c.stages());
        assert!(c.total_us() >= c.stages()[0].1);
    }
}
