//! Minimal leveled stderr logger wired to the `log` crate facade.

use log::{Level, LevelFilter, Metadata, Record};
use std::time::Instant;

static LOGGER: StderrLogger = StderrLogger;
static START: once_cell::sync::Lazy<Instant> = once_cell::sync::Lazy::new(Instant::now);

struct StderrLogger;

impl log::Log for StderrLogger {
    fn enabled(&self, metadata: &Metadata) -> bool {
        metadata.level() <= log::max_level()
    }

    fn log(&self, record: &Record) {
        if !self.enabled(record.metadata()) {
            return;
        }
        let t = START.elapsed().as_secs_f64();
        let lvl = match record.level() {
            Level::Error => "ERR",
            Level::Warn => "WRN",
            Level::Info => "INF",
            Level::Debug => "DBG",
            Level::Trace => "TRC",
        };
        eprintln!("[{t:9.3}s {lvl} {}] {}", record.target(), record.args());
    }

    fn flush(&self) {}
}

/// Install the logger. Level comes from `BAF_LOG` (error|warn|info|debug|trace),
/// defaulting to `info`. Safe to call more than once.
pub fn init() {
    let level = match std::env::var("BAF_LOG").as_deref() {
        Ok("error") => LevelFilter::Error,
        Ok("warn") => LevelFilter::Warn,
        Ok("debug") => LevelFilter::Debug,
        Ok("trace") => LevelFilter::Trace,
        _ => LevelFilter::Info,
    };
    let _ = log::set_logger(&LOGGER);
    log::set_max_level(level);
    once_cell::sync::Lazy::force(&START);
}
