//! `baf_lint` — the repo's static analysis gate (see `baf::lint`).
//!
//! Usage: `baf_lint [ROOT] [--json PATH]`
//!
//! Walks `ROOT/rust/src` (default: the current directory), prints a
//! human report, writes the machine-readable report (default
//! `ROOT/target/lint-report.json`), and exits nonzero on any
//! unsuppressed finding or ROADMAP constant drift. Run it from the repo
//! root as `cargo run --release --bin baf_lint`.

#![allow(clippy::unwrap_used, clippy::expect_used)]

use std::path::PathBuf;
use std::process::ExitCode;

const USAGE: &str = "usage: baf_lint [ROOT] [--json PATH]\n\
  ROOT         repo root to lint (default: .)\n\
  --json PATH  where to write the JSON report\n\
               (default: ROOT/target/lint-report.json)\n";

fn main() -> ExitCode {
    let mut root = PathBuf::from(".");
    let mut json_out: Option<PathBuf> = None;
    let mut args = std::env::args().skip(1);
    while let Some(a) = args.next() {
        match a.as_str() {
            "--json" => match args.next() {
                Some(p) => json_out = Some(PathBuf::from(p)),
                None => {
                    eprintln!("baf_lint: --json needs a path\n{USAGE}");
                    return ExitCode::from(2);
                }
            },
            "-h" | "--help" => {
                print!("{USAGE}");
                return ExitCode::SUCCESS;
            }
            other => root = PathBuf::from(other),
        }
    }
    let json_out = json_out.unwrap_or_else(|| root.join("target").join("lint-report.json"));

    let report = match baf::lint::run(&root) {
        Ok(r) => r,
        Err(e) => {
            eprintln!("baf_lint: walking {}: {e}", root.display());
            return ExitCode::from(2);
        }
    };
    print!("{}", report.human());

    if let Some(dir) = json_out.parent() {
        if let Err(e) = std::fs::create_dir_all(dir) {
            eprintln!("baf_lint: creating {}: {e}", dir.display());
            return ExitCode::from(2);
        }
    }
    if let Err(e) = baf::json::to_file(&json_out, &report.to_value()) {
        eprintln!("baf_lint: writing {}: {e}", json_out.display());
        return ExitCode::from(2);
    }
    println!("report: {}", json_out.display());

    if report.clean() {
        ExitCode::SUCCESS
    } else {
        ExitCode::FAILURE
    }
}
