//! Cross-language golden verification: every contract between the Python
//! build path and the Rust runtime is pinned by files under
//! `artifacts/golden/` and re-checked here (CLI `baf golden` and the
//! integration test `tests/golden.rs`).
//!
//! Layers checked, lowest to highest:
//!   1. SplitMix64 PRNG draws (u64 / f32 / ranged)
//!   2. ShapeWorld image + box generation (bit-exact f32)
//!   3. quantize / dequantize / consolidate vs the jnp oracles
//!   4. the full pipeline tensors: frontend Z, BaF Z-tilde, consolidated
//!      Z-final, head — Rust runtime (PJRT) vs Python (jax) on image 0.

use crate::data;
use crate::json::{self};
use crate::quant::{self, ChannelRange, QuantizedTensor};
use crate::tensor::Tensor;
use crate::tio;
use crate::util::SplitMix64;
use anyhow::{bail, Context, Result};
use std::path::Path;

fn load_f32(dir: &Path, name: &str) -> Result<Tensor> {
    tio::read(&dir.join(name))?.into_tensor().context(name.to_string())
}

fn assert_close(name: &str, a: &Tensor, b: &Tensor, tol: f32) -> Result<()> {
    if a.shape() != b.shape() {
        bail!("{name}: shape {:?} vs {:?}", a.shape(), b.shape());
    }
    let d = a.max_abs_diff(b);
    if d > tol {
        bail!("{name}: max abs diff {d} > tol {tol}");
    }
    log::debug!("golden {name}: max abs diff {d:.3e} (tol {tol:.1e})");
    Ok(())
}

/// 1. PRNG goldens.
pub fn verify_prng(dir: &Path) -> Result<()> {
    let v = json::from_file(&dir.join("prng.json"))?;
    for case in v.req("cases")?.as_arr().unwrap_or(&[]) {
        let seed: u64 = case
            .req("seed")?
            .as_str()
            .context("seed")?
            .parse()
            .context("seed parse")?;
        let mut r = SplitMix64::new(seed);
        for (i, want) in case.req("u64")?.as_arr().unwrap_or(&[]).iter().enumerate() {
            let want: u64 = want.as_str().context("u64")?.parse()?;
            let got = r.next_u64();
            if got != want {
                bail!("prng seed {seed} draw {i}: {got} != {want}");
            }
        }
        let mut r = SplitMix64::new(seed);
        for (i, want) in case.req("f32")?.as_arr().unwrap_or(&[]).iter().enumerate() {
            let want = want.as_f64().context("f32")? as f32;
            let got = r.next_f32();
            if got != want {
                bail!("prng seed {seed} f32 draw {i}: {got} != {want}");
            }
        }
        let mut r = SplitMix64::new(seed);
        for (i, want) in
            case.req("range_10_29")?.as_arr().unwrap_or(&[]).iter().enumerate()
        {
            let want = want.as_i64().context("range")?;
            let got = r.next_range(10, 29);
            if got != want {
                bail!("prng seed {seed} range draw {i}: {got} != {want}");
            }
        }
    }
    Ok(())
}

/// 2. ShapeWorld goldens (bit-exact images + boxes).
pub fn verify_dataset(dir: &Path) -> Result<()> {
    let v = json::from_file(&dir.join("dataset.json"))?;
    let seed = v.req("dataset_seed")?.as_i64().context("seed")? as u64;
    for case in v.req("cases")?.as_arr().unwrap_or(&[]) {
        let idx = case.req("index")?.as_usize().context("index")?;
        let s = data::generate(seed, idx);
        let want_sum = case.req("sum")?.as_f64().context("sum")?;
        let got_sum: f64 = s.image.data().iter().map(|&x| x as f64).sum();
        if (got_sum - want_sum).abs() > 1e-3 {
            bail!("dataset image {idx}: sum {got_sum} != {want_sum}");
        }
        let want_boxes = case.req("boxes")?.as_arr().context("boxes")?;
        if want_boxes.len() != s.boxes.len() {
            bail!("dataset image {idx}: {} boxes != {}", s.boxes.len(), want_boxes.len());
        }
        for (b, w) in s.boxes.iter().zip(want_boxes) {
            let w = w.as_f64_vec().context("box")?;
            let got = [b.x0, b.y0, b.x1, b.y1, b.class as f32];
            for (g, ww) in got.iter().zip(&w) {
                if (*g as f64 - ww).abs() > 1e-6 {
                    bail!("dataset image {idx}: box {got:?} != {w:?}");
                }
            }
        }
    }
    // bit-exact pixel check on image 0
    let want = load_f32(dir, "dataset_img0.npy")?;
    let got = data::generate(seed, 0).image;
    assert_close("dataset_img0", &got, &want, 0.0)?;
    Ok(())
}

/// 3. Quantizer / consolidation goldens vs the jnp oracles.
pub fn verify_quant(dir: &Path) -> Result<()> {
    let z = load_f32(dir, "quant_z.npy")?;
    for n in [2u8, 4, 8] {
        let q = quant::quantize(&z, n);
        let (shape, want_bins) = tio::read(&dir.join(format!("quant_n{n}_q.npy")))?
            .into_i32()
            .context("bins")?;
        if shape != z.shape() {
            bail!("quant n={n}: bin shape {:?}", shape);
        }
        for (i, (&g, &w)) in q.bins.iter().zip(&want_bins).enumerate() {
            if g as i32 != w {
                bail!("quant n={n} bin {i}: {g} != {w}");
            }
        }
        let want_mm = load_f32(dir, &format!("quant_n{n}_mm.npy"))?;
        for (ch, r) in q.ranges.iter().enumerate() {
            let wm = want_mm.data()[ch * 2];
            let wx = want_mm.data()[ch * 2 + 1];
            if r.min != wm || r.max != wx {
                bail!("quant n={n} ch {ch}: range ({}, {}) != ({wm}, {wx})", r.min, r.max);
            }
        }
        let deq = quant::dequantize(&q);
        assert_close(
            &format!("dequant n={n}"),
            &deq,
            &load_f32(dir, &format!("quant_n{n}_deq.npy"))?,
            1e-5,
        )?;
        if n == 4 {
            let zt = load_f32(dir, "quant_zt.npy")?;
            let cons = quant::consolidate(&zt, &q);
            assert_close(
                "consolidate n=4",
                &cons,
                &load_f32(dir, "quant_n4_cons.npy")?,
                1e-5,
            )?;
        }
    }
    Ok(())
}

/// 4. Full-pipeline goldens through the PJRT runtime.
pub fn verify_pipeline(artifact_dir: &Path) -> Result<()> {
    use crate::runtime::{Engine, Manifest};
    let dir = artifact_dir.join("golden");
    let meta = json::from_file(&dir.join("pipe_meta.json"))?;
    let c = meta.req("c")?.as_usize().context("c")?;
    let n = meta.req("n")?.as_i64().context("n")? as u8;
    let sel = meta.req("sel")?.as_usize_vec().context("sel")?;

    let engine = Engine::new(artifact_dir)?;
    let m = engine.manifest().clone();

    // frontend
    let img = load_f32(&dir, "pipe_img.npy")?;
    let z = engine
        .run(
            "frontend_b1",
            &[&img.clone().reshape(&[1, m.image_size, m.image_size, 3])],
        )?
        .reshape(&[m.z_shape.0, m.z_shape.1, m.z_shape.2]);
    let z_want = load_f32(&dir, "pipe_z.npy")?;
    // PJRT CPU vs jax CPU: same HLO, minor scheduling differences
    assert_close("pipe_z (frontend)", &z, &z_want, 2e-4)?;

    // quantization of the selected channels
    let planes = crate::tensor::gather_channels_hwc_to_chw(&z_want, &sel);
    let q = quant::quantize(&planes, n);
    let (_, want_bins) = tio::read(&dir.join("pipe_q.npy"))?.into_i32()?;
    let mism = q
        .bins
        .iter()
        .zip(&want_bins)
        .filter(|(&g, &w)| g as i32 != w)
        .count();
    if mism > 0 {
        bail!("pipe quant: {mism} of {} bins differ", q.bins.len());
    }

    // BaF prediction from the python-dequantized input
    let zhat = load_f32(&dir, "pipe_zhat.npy")?;
    let z_tilde = engine
        .run(
            &Manifest::baf_name(c, n, 1),
            &[&zhat.clone().reshape(&[1, m.z_shape.0, m.z_shape.1, c])],
        )?
        .reshape(&[m.z_shape.0, m.z_shape.1, m.z_shape.2]);
    let zt_want = load_f32(&dir, "pipe_ztilde.npy")?;
    assert_close("pipe_ztilde (BaF)", &z_tilde, &zt_want, 5e-4)?;

    // consolidation + scatter
    let mm = load_f32(&dir, "pipe_mm.npy")?;
    let ranges: Vec<ChannelRange> = (0..c)
        .map(|ch| ChannelRange { min: mm.data()[ch * 2], max: mm.data()[ch * 2 + 1] })
        .collect();
    let qt = QuantizedTensor {
        bins: want_bins.iter().map(|&v| v as u16).collect(),
        c,
        h: m.z_shape.0,
        w: m.z_shape.1,
        n,
        ranges,
    };
    let mut z_final = zt_want.clone();
    let pred = crate::tensor::gather_channels_hwc_to_chw(&zt_want, &sel);
    let cons = quant::consolidate(&pred, &qt);
    crate::tensor::scatter_channels_chw_into_hwc(&cons, &sel, &mut z_final);
    assert_close("pipe_zfinal (Eq.6)", &z_final, &load_f32(&dir, "pipe_zfinal.npy")?, 5e-4)?;

    // tail + monolith
    let head = engine
        .run(
            "tail_b1",
            &[&load_f32(&dir, "pipe_zfinal.npy")?
                .reshape(&[1, m.z_shape.0, m.z_shape.1, m.z_shape.2])],
        )?
        .reshape(&[m.grid, m.grid, m.head_channels]);
    assert_close("pipe_head (tail)", &head, &load_f32(&dir, "pipe_head.npy")?, 1e-3)?;

    let mono = engine
        .run(
            "monolith_b1",
            &[&img.reshape(&[1, m.image_size, m.image_size, 3])],
        )?
        .reshape(&[m.grid, m.grid, m.head_channels]);
    assert_close("pipe_mono_head", &mono, &load_f32(&dir, "pipe_mono_head.npy")?, 1e-3)?;
    Ok(())
}

/// Run every golden check (CLI `baf golden`).
pub fn verify_all(artifact_dir: &Path) -> Result<()> {
    let dir = artifact_dir.join("golden");
    verify_prng(&dir).context("prng goldens")?;
    verify_dataset(&dir).context("dataset goldens")?;
    verify_quant(&dir).context("quant goldens")?;
    verify_pipeline(artifact_dir).context("pipeline goldens")?;
    Ok(())
}
