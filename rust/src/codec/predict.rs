//! Spatial predictors for the lossless path.
//!
//! MED (LOCO-I/JPEG-LS median edge detector) is TLC's primary predictor —
//! the same family FLIF's MANIAC contexts build on; Paeth is used by the
//! PNG-like baseline.

/// MED / LOCO-I prediction from left (a), top (b), top-left (c).
#[inline]
pub fn med(a: i32, b: i32, c: i32) -> i32 {
    let (mn, mx) = if a < b { (a, b) } else { (b, a) };
    if c >= mx {
        mn
    } else if c <= mn {
        mx
    } else {
        a + b - c
    }
}

/// Paeth predictor (PNG filter type 4).
#[inline]
pub fn paeth(a: i32, b: i32, c: i32) -> i32 {
    let p = a + b - c;
    let pa = (p - a).abs();
    let pb = (p - b).abs();
    let pc = (p - c).abs();
    if pa <= pb && pa <= pc {
        a
    } else if pb <= pc {
        b
    } else {
        c
    }
}

/// Gradient-activity context bucket for TLC's residual models: quantizes
/// the local texture |a-c| + |c-b| into one of `NUM_CONTEXTS` bins so
/// flat and busy regions adapt separate probability models.
pub const NUM_CONTEXTS: usize = 8;

#[inline]
pub fn activity_context(a: i32, b: i32, c: i32, n_bits: u8) -> usize {
    // normalize activity to the 8-bit scale so context boundaries are
    // comparable across bit depths: scale down for depths above 8 bits,
    // up for depths below (a full-scale edge must land in the top
    // context regardless of precision)
    let d = (a - c).abs() + (c - b).abs();
    let act = if n_bits >= 8 {
        d >> (n_bits - 8)
    } else {
        d << (8 - n_bits)
    };
    match act {
        0 => 0,
        1 => 1,
        2 => 2,
        3..=4 => 3,
        5..=8 => 4,
        9..=16 => 5,
        17..=32 => 6,
        _ => 7,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn med_selects_edges() {
        // vertical edge: c == b -> predict a? c >= max(a,b) when b==c>a -> min = a
        assert_eq!(med(10, 50, 50), 10);
        // horizontal edge
        assert_eq!(med(50, 10, 50), 10);
        // smooth gradient: planar prediction
        assert_eq!(med(20, 30, 25), 25);
        // c below both -> max
        assert_eq!(med(20, 30, 10), 30);
    }

    #[test]
    fn paeth_matches_png_spec_cases() {
        assert_eq!(paeth(0, 0, 0), 0);
        assert_eq!(paeth(10, 20, 10), 20); // p=20, pb=0
        assert_eq!(paeth(20, 10, 10), 20); // p=20, pa=0
        assert_eq!(paeth(5, 5, 9), 5); // ties prefer a
    }

    #[test]
    fn contexts_cover_and_order() {
        assert_eq!(activity_context(5, 5, 5, 8), 0);
        assert!(activity_context(0, 255, 128, 8) >= 6);
        let mut last = 0;
        for act_pair in [(0, 0), (1, 0), (2, 0), (4, 0), (8, 0), (16, 0), (32, 0), (64, 0)] {
            let ctx = activity_context(act_pair.0, 0, 0, 8);
            assert!(ctx >= last, "activity must map monotonically");
            last = ctx;
        }
        // higher bit depth shifts activity down
        assert_eq!(activity_context(1024, 0, 0, 12), activity_context(64, 0, 0, 8));
    }

    #[test]
    fn low_bit_depths_scale_activity_up() {
        // a 4-bit activity of 2 is the same relative texture as an 8-bit
        // activity of 32 (2 << 4) and must land in the same context
        assert_eq!(activity_context(2, 0, 0, 4), activity_context(32, 0, 0, 8));
        // 6-bit activity of 8 == 8-bit activity of 32 (8 << 2)
        assert_eq!(activity_context(8, 0, 0, 6), activity_context(32, 0, 0, 8));
        // a full-scale edge saturates the top context at every depth
        for n in [1u8, 2, 4, 6, 8, 12, 16] {
            let full = (1i32 << n) - 1;
            assert_eq!(
                activity_context(full, 0, 0, n),
                7,
                "full-scale edge at n={n} must hit the busiest context"
            );
        }
    }
}
