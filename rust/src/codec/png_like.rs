//! PNG-like baseline: per-row Paeth filtering + DEFLATE.
//!
//! Stands in for the paper's PNG reference point ([3] compresses 8-bit
//! feature maps with PNG). Samples wider than 8 bits are split
//! big-endian like PNG's 16-bit mode.

use super::predict::paeth;
use super::scratch::ScratchPool;
use super::{Error, ImageMeta, Result};
use flate2::read::ZlibDecoder;
use flate2::write::ZlibEncoder;
use flate2::Compression;
use std::io::{Read, Write};

fn bytes_per_sample(n: u8) -> usize {
    if n <= 8 {
        1
    } else {
        2
    }
}

/// Paeth-filter rows then DEFLATE.
pub fn encode(samples: &[u16], width: usize, height: usize, n: u8) -> Vec<u8> {
    let scratch = ScratchPool::new();
    let mut out = Vec::new();
    encode_into(samples, width, height, n, &scratch, &mut out);
    out
}

/// Re-entrant [`encode`]: the raw/filtered intermediates come from
/// `scratch` and go back when done, and the deflate output lands in
/// `out` (cleared first, capacity reused). DEFLATE's internal state is
/// the one allocation this cannot pool (flate2 owns it).
// baf-lint: allow(panic-macro) -- encoder contract (ROADMAP): trusted in-memory deflate, a write failure is a bug, not an input
pub fn encode_into(
    samples: &[u16],
    width: usize,
    height: usize,
    n: u8,
    scratch: &ScratchPool,
    out: &mut Vec<u8>,
) {
    let bps = bytes_per_sample(n);
    let stride = width * bps;
    let mut raw = scratch.take_u8(height * stride);
    raw.resize(height * stride, 0);
    for y in 0..height {
        for x in 0..width {
            let v = samples[y * width + x];
            let off = y * stride + x * bps;
            if bps == 1 {
                raw[off] = v as u8;
            } else {
                raw[off] = (v >> 8) as u8;
                raw[off + 1] = v as u8;
            }
        }
    }
    // Paeth filter per byte-lane (PNG semantics: the "left" neighbour is
    // bps bytes back)
    let mut filtered = scratch.take_u8(raw.len());
    filtered.resize(raw.len(), 0);
    for y in 0..height {
        for i in 0..stride {
            let cur = raw[y * stride + i] as i32;
            let a = if i >= bps { raw[y * stride + i - bps] as i32 } else { 0 };
            let b = if y > 0 { raw[(y - 1) * stride + i] as i32 } else { 0 };
            let c = if y > 0 && i >= bps { raw[(y - 1) * stride + i - bps] as i32 } else { 0 };
            filtered[y * stride + i] = (cur - paeth(a, b, c)) as u8;
        }
    }
    let mut sink = std::mem::take(out);
    sink.clear();
    let mut enc = ZlibEncoder::new(sink, Compression::best());
    // in-memory sink: a write failure is a programming error, not input
    if let Err(e) = enc.write_all(&filtered) {
        panic!("in-memory deflate write failed: {e}");
    }
    *out = match enc.finish() {
        Ok(out) => out,
        Err(e) => panic!("deflate finish failed: {e}"),
    };
    scratch.put_u8(raw);
    scratch.put_u8(filtered);
}

/// Inverse of `encode`.
///
/// Total: the inflate read is bounded to the expected output size plus
/// one byte (so a deflate bomb cannot allocate more than the validated
/// geometry allows), and both short and long streams are rejected.
pub fn decode(bytes: &[u8], meta: &ImageMeta) -> Result<Vec<u16>> {
    let samples_len = meta.checked_samples()?;
    let scratch = ScratchPool::new();
    let mut samples = vec![0u16; samples_len];
    decode_into(bytes, meta, &scratch, &mut samples)?;
    Ok(samples)
}

/// Re-entrant [`decode`]: intermediates come from `scratch`, the result
/// lands in a caller-owned slice of exactly `width * height` samples (a
/// mismatch is [`Error::Corrupt`]). Error paths still return their
/// scratch buffers to the pool.
// baf-lint: allow(raw-index) -- unfilter/unpack loops: y<height, i<stride, x<width bound every index into the exactly-sized planes
pub fn decode_into(
    bytes: &[u8],
    meta: &ImageMeta,
    scratch: &ScratchPool,
    samples: &mut [u16],
) -> Result<()> {
    let samples_len = meta.checked_samples()?;
    if samples.len() != samples_len {
        return Err(Error::Corrupt(format!(
            "png-like output slice is {} samples, geometry says {samples_len}",
            samples.len()
        )));
    }
    let (width, height, n) = (meta.width, meta.height, meta.n);
    let bps = bytes_per_sample(n);
    let stride = width * bps;
    let expected = samples_len
        .checked_mul(bps)
        .ok_or_else(|| Error::Corrupt("png-like plane size overflow".into()))?;
    let mut filtered = scratch.take_u8(expected);
    // `.take(expected + 1)`: enough to detect an over-long stream without
    // ever buffering an unbounded decompression
    if let Err(e) = ZlibDecoder::new(bytes)
        .take(expected as u64 + 1)
        .read_to_end(&mut filtered)
    {
        scratch.put_u8(filtered);
        return Err(Error::Corrupt(format!("inflate failed: {e}")));
    }
    if filtered.len() < expected {
        let got = filtered.len();
        scratch.put_u8(filtered);
        return Err(Error::Truncated {
            what: "png-like filtered plane",
            needed: expected,
            got,
        });
    }
    if filtered.len() > expected {
        scratch.put_u8(filtered);
        return Err(Error::Corrupt(format!(
            "png-like stream inflates past expected {expected} bytes"
        )));
    }
    let mut raw = scratch.take_u8(filtered.len());
    raw.resize(filtered.len(), 0);
    for y in 0..height {
        for i in 0..stride {
            let a = if i >= bps { raw[y * stride + i - bps] as i32 } else { 0 };
            let b = if y > 0 { raw[(y - 1) * stride + i] as i32 } else { 0 };
            let c = if y > 0 && i >= bps { raw[(y - 1) * stride + i - bps] as i32 } else { 0 };
            raw[y * stride + i] =
                (filtered[y * stride + i] as i32 + paeth(a, b, c)) as u8;
        }
    }
    for y in 0..height {
        for x in 0..width {
            let off = y * stride + x * bps;
            samples[y * width + x] = if bps == 1 {
                raw[off] as u16
            } else {
                ((raw[off] as u16) << 8) | raw[off + 1] as u16
            };
        }
    }
    scratch.put_u8(filtered);
    scratch.put_u8(raw);
    Ok(())
}

#[cfg(test)]
mod tests {
    #![allow(clippy::unwrap_used, clippy::expect_used)]

    use super::*;
    use crate::util::SplitMix64;

    #[test]
    fn roundtrip_8_and_16_bit() {
        let mut r = SplitMix64::new(21);
        for n in [1u8, 4, 8, 12, 16] {
            let mask = (1u32 << n) - 1;
            let samples: Vec<u16> =
                (0..40 * 30).map(|_| (r.next_u64() as u32 & mask) as u16).collect();
            let bytes = encode(&samples, 40, 30, n);
            let meta = ImageMeta { width: 40, height: 30, n };
            assert_eq!(decode(&bytes, &meta).unwrap(), samples, "n={n}");
        }
    }

    #[test]
    fn garbage_and_truncation_are_rejected() {
        let samples: Vec<u16> = (0..16 * 16).map(|i| (i & 255) as u16).collect();
        let bytes = encode(&samples, 16, 16, 8);
        let meta = ImageMeta { width: 16, height: 16, n: 8 };
        assert!(decode(&[], &meta).is_err());
        assert!(decode(&[0xde, 0xad, 0xbe, 0xef], &meta).is_err());
        assert!(decode(&bytes[..bytes.len() / 2], &meta).is_err());
        // stream longer than the geometry claims is corrupt, not a panic
        let small = ImageMeta { width: 4, height: 4, n: 8 };
        assert!(matches!(decode(&bytes, &small), Err(Error::Corrupt(_))));
    }

    #[test]
    fn smooth_content_compresses() {
        let w = 64;
        let samples: Vec<u16> = (0..w * w).map(|i| ((i % w) + i / w) as u16 / 2).collect();
        let bytes = encode(&samples, w, w, 8);
        assert!(bytes.len() < w * w / 4);
    }
}
