//! The BaF bitstream container — what actually travels edge -> cloud.
//!
//! v1 layout (all integers little-endian):
//!
//! ```text
//! offset size  field
//! 0      4     magic "BAFT"
//! 4      1     version (1)
//! 5      1     codec id (CodecKind)
//! 6      1     n  (sample bit depth)
//! 7      1     qp (lossy codecs only; 0 otherwise)
//! 8      2     C  (number of channels)
//! 10     2     tile_w
//! 12     2     tile_h
//! 14     2     cols
//! 16     2     rows
//! 18     4     payload length in bytes
//! 22     4*C   side info: per channel (min f16, max f16) — the paper's
//!              C*32 bits of quantizer parameters (§3.2)
//! ..     len   entropy-coded payload
//! ..     4     CRC32 over everything above
//! ```
//!
//! v2 ("striped") keeps the fixed header byte-for-byte but sets
//! version = 2 and splits the payload into K independently
//! entropy-coded stripes so encode and decode parallelize within one
//! frame (see `runtime::pool`):
//!
//! ```text
//! 0      22    fixed header as v1 (version byte = 2); the payload
//!              length field covers stripe table + stripe payloads
//! 22     2     K (stripe count, 1..=stripe units)
//! 24     4*C   side info (as v1)
//! ..     8*K   stripe table: per stripe (len u32, crc32-of-payload u32)
//! ..     ..    K concatenated stripe payloads
//! ..     4     CRC32 over everything above
//! ```
//!
//! A stripe covers a contiguous run of *stripe units* — rows of channel
//! tiles for image codecs (so each stripe is a full-width horizontal
//! band of the tiled image) or whole channels for TLC-IC. Each stripe is
//! a complete standalone stream of its codec: entropy-model state never
//! crosses a stripe boundary, which is what makes stripes independently
//! decodable. The cost is K-1 model restarts worth of adaptation; for
//! frame-sized tensors and small K this is well under 1% of the payload
//! (bench_codec measures it).

use super::scratch::ScratchPool;
use super::{CodecKind, Error, ImageMeta, Result, MAX_DECODED_SAMPLES};
use crate::quant::{ChannelRange, QuantizedTensor};
use crate::runtime::pool::WorkerPool;
use crate::tile::{grid_for, tile, tile_with_buffer, untile_into, TiledImage};
use crate::util::f16::{f16_bits_to_f32, f32_to_f16_bits};

pub const MAGIC: &[u8; 4] = b"BAFT";
pub const VERSION: u8 = 1;
/// The striped frame layout.
pub const VERSION2: u8 = 2;
pub const HEADER_LEN: usize = 22;

/// One stripe's payload range within [`Frame::payload`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct StripeInfo {
    pub offset: usize,
    pub len: usize,
}

/// A decoded frame header + payload view.
#[derive(Debug, Clone)]
pub struct Frame {
    /// Container version the frame was parsed from (1 or 2).
    pub version: u8,
    pub codec: CodecKind,
    pub n: u8,
    pub qp: u8,
    pub channels: usize,
    pub tile_w: usize,
    pub tile_h: usize,
    pub cols: usize,
    pub rows: usize,
    pub ranges: Vec<ChannelRange>,
    /// Stripe ranges into `payload`. v1 frames parse as one stripe
    /// covering the whole payload, so the decode path is uniform.
    pub stripes: Vec<StripeInfo>,
    pub payload: Vec<u8>,
}

impl Frame {
    pub fn image_meta(&self) -> ImageMeta {
        ImageMeta {
            width: self.cols * self.tile_w,
            height: self.rows * self.tile_h,
            n: self.n,
        }
    }

    /// How many independently codeable units this frame has: rows of
    /// channel tiles for image codecs, channels for TLC-IC.
    pub fn stripe_units(&self) -> usize {
        if self.codec == CodecKind::TlcIc {
            self.channels
        } else {
            self.rows
        }
    }
}

/// The unit range `[start, end)` of stripe `i` of `k` over `total`
/// units: near-equal contiguous spans, every unit covered exactly once.
pub fn stripe_span(total: usize, k: usize, i: usize) -> (usize, usize) {
    (i * total / k, (i + 1) * total / k)
}

/// Serialize: quantized tensor -> tiled image -> codec -> framed bytes
/// (v1 single-stream layout).
pub fn pack(q: &QuantizedTensor, codec: CodecKind, qp: u8) -> Vec<u8> {
    let img = tile(q);
    // TLC-IC codes the channel-plane sequence directly (inter-channel
    // prediction needs plane structure); other codecs get the tiled image.
    let payload = if codec == CodecKind::TlcIc {
        super::tlc_ic::encode_planes(&q.bins, q.c, q.h, q.w, q.n)
    } else {
        codec.encode_image(&img.samples, img.width, img.height, q.n, qp)
    };
    let mut out = Vec::with_capacity(HEADER_LEN + 4 * q.c + payload.len() + 4);
    out.extend_from_slice(MAGIC);
    out.push(VERSION);
    out.push(codec as u8);
    out.push(q.n);
    out.push(qp);
    out.extend_from_slice(&(q.c as u16).to_le_bytes());
    out.extend_from_slice(&(img.tile_w as u16).to_le_bytes());
    out.extend_from_slice(&(img.tile_h as u16).to_le_bytes());
    out.extend_from_slice(&(img.cols as u16).to_le_bytes());
    out.extend_from_slice(&(img.rows as u16).to_le_bytes());
    out.extend_from_slice(&(payload.len() as u32).to_le_bytes());
    for r in &q.ranges {
        out.extend_from_slice(&f32_to_f16_bits(r.min).to_le_bytes());
        out.extend_from_slice(&f32_to_f16_bits(r.max).to_le_bytes());
    }
    out.extend_from_slice(&payload);
    let crc = crc32fast::hash(&out);
    out.extend_from_slice(&crc.to_le_bytes());
    out
}

/// [`pack_v2_with`] on a private single-thread pool and throwaway
/// scratch — for tools and tests that don't hold long-lived state.
pub fn pack_v2(q: &QuantizedTensor, codec: CodecKind, qp: u8, k: usize) -> Vec<u8> {
    pack_v2_with(q, codec, qp, k, &WorkerPool::new(1), &ScratchPool::new())
}

/// Serialize a striped v2 frame: the tensor is split into `k` stripes
/// (clamped to the available units), each entropy-coded independently —
/// concurrently across `pool` — with working buffers drawn from
/// `scratch` so steady-state encoding does not allocate.
pub fn pack_v2_with(
    q: &QuantizedTensor,
    codec: CodecKind,
    qp: u8,
    k: usize,
    pool: &WorkerPool,
    scratch: &ScratchPool,
) -> Vec<u8> {
    let (cols, rows) = grid_for(q.c);
    let (tile_w, tile_h) = (q.w, q.h);
    let units = if codec == CodecKind::TlcIc { q.c } else { rows };
    let k = k.clamp(1, units.max(1));
    let plane = tile_h * tile_w;

    // encode each stripe into its own pooled buffer; jobs own disjoint
    // input slices so the fan-out is borrow-checked, not unsafe
    struct EncJob<'a> {
        samples: &'a [u16],
        width: usize,
        height: usize,
        channels: usize,
        out: Vec<u8>,
    }
    let payloads: Vec<Vec<u8>> = if codec == CodecKind::TlcIc {
        let mut jobs: Vec<EncJob> = (0..k)
            .map(|i| {
                let (c0, c1) = stripe_span(units, k, i);
                EncJob {
                    samples: &q.bins[c0 * plane..c1 * plane],
                    width: tile_w,
                    height: tile_h,
                    channels: c1 - c0,
                    out: scratch.take_u8(0),
                }
            })
            .collect();
        pool.for_each_mut(&mut jobs, |_, job| {
            super::tlc_ic::encode_planes_into(
                job.samples,
                job.channels,
                job.height,
                job.width,
                q.n,
                &mut job.out,
            );
        });
        jobs.into_iter().map(|j| j.out).collect()
    } else {
        let img = tile_with_buffer(q, scratch.take_u16(cols * tile_w * rows * tile_h));
        let width = img.width;
        let mut jobs: Vec<EncJob> = (0..k)
            .map(|i| {
                let (r0, r1) = stripe_span(units, k, i);
                EncJob {
                    samples: &img.samples[r0 * tile_h * width..r1 * tile_h * width],
                    width,
                    height: (r1 - r0) * tile_h,
                    channels: q.c,
                    out: scratch.take_u8(0),
                }
            })
            .collect();
        pool.for_each_mut(&mut jobs, |_, job| {
            codec.encode_image_into(
                job.samples,
                job.width,
                job.height,
                q.n,
                qp,
                scratch,
                &mut job.out,
            );
        });
        let payloads = jobs.into_iter().map(|j| j.out).collect();
        scratch.put_u16(img.samples);
        payloads
    };

    let payload_len = 8 * k + payloads.iter().map(Vec::len).sum::<usize>();
    assert!(payload_len <= u32::MAX as usize, "payload too large for container");
    let mut out = scratch.take_u8(HEADER_LEN + 2 + 4 * q.c + payload_len + 4);
    out.extend_from_slice(MAGIC);
    out.push(VERSION2);
    out.push(codec as u8);
    out.push(q.n);
    out.push(qp);
    out.extend_from_slice(&(q.c as u16).to_le_bytes());
    out.extend_from_slice(&(tile_w as u16).to_le_bytes());
    out.extend_from_slice(&(tile_h as u16).to_le_bytes());
    out.extend_from_slice(&(cols as u16).to_le_bytes());
    out.extend_from_slice(&(rows as u16).to_le_bytes());
    out.extend_from_slice(&(payload_len as u32).to_le_bytes());
    out.extend_from_slice(&(k as u16).to_le_bytes());
    for r in &q.ranges {
        out.extend_from_slice(&f32_to_f16_bits(r.min).to_le_bytes());
        out.extend_from_slice(&f32_to_f16_bits(r.max).to_le_bytes());
    }
    for p in &payloads {
        out.extend_from_slice(&(p.len() as u32).to_le_bytes());
        out.extend_from_slice(&crc32fast::hash(p).to_le_bytes());
    }
    for p in payloads {
        out.extend_from_slice(&p);
        scratch.put_u8(p);
    }
    let crc = crc32fast::hash(&out);
    out.extend_from_slice(&crc.to_le_bytes());
    out
}

/// Parse, validate, and CRC-check a frame (v1 or v2).
///
/// Total: every field is validated before it drives an allocation or an
/// index — short input is [`Error::Truncated`], bad magic / CRC /
/// geometry / stripe table is [`Error::Corrupt`], future versions and
/// unknown codec ids are [`Error::Unsupported`], and a header whose
/// geometry implies more than [`MAX_DECODED_SAMPLES`] is
/// [`Error::LimitExceeded`]. v2 stripe payloads each carry their own
/// CRC32, verified here, so a corrupt stripe is localized before decode.
pub fn parse(bytes: &[u8]) -> Result<Frame> {
    if bytes.len() < HEADER_LEN + 4 {
        return Err(Error::Truncated {
            what: "container frame",
            needed: HEADER_LEN + 4,
            got: bytes.len(),
        });
    }
    let body_len = bytes.len().checked_sub(4).ok_or(Error::Truncated {
        what: "container frame",
        needed: HEADER_LEN + 4,
        got: bytes.len(),
    })?;
    let (body, crc_bytes) = bytes.split_at(body_len);
    let want = u32::from_le_bytes([crc_bytes[0], crc_bytes[1], crc_bytes[2], crc_bytes[3]]);
    let got = crc32fast::hash(body);
    if want != got {
        return Err(Error::Corrupt(format!(
            "CRC mismatch: stored {want:#010x}, computed {got:#010x}"
        )));
    }
    if &body[0..4] != MAGIC {
        return Err(Error::Corrupt(format!(
            "bad magic {:02x?} (want {MAGIC:02x?})",
            &body[0..4]
        )));
    }
    let version = body[4];
    if version != VERSION && version != VERSION2 {
        return Err(Error::Unsupported(format!(
            "container version {version} (this build reads {VERSION} and {VERSION2})"
        )));
    }
    let codec = CodecKind::from_u8(body[5])?;
    let n = body[6];
    let qp = body[7];
    if !(1..=16).contains(&n) {
        return Err(Error::Corrupt(format!("bit depth {n} outside 1..=16")));
    }
    let rd16 = |off: usize| -> Result<usize> {
        match body.get(off..off + 2) {
            Some(b) => Ok(u16::from_le_bytes([b[0], b[1]]) as usize),
            None => Err(Error::Truncated {
                what: "container header",
                needed: off + 2,
                got: body.len(),
            }),
        }
    };
    let channels = rd16(8)?;
    let tile_w = rd16(10)?;
    let tile_h = rd16(12)?;
    let cols = rd16(14)?;
    let rows = rd16(16)?;
    let payload_len =
        u32::from_le_bytes([body[18], body[19], body[20], body[21]]) as usize;
    if channels == 0 || tile_w == 0 || tile_h == 0 || cols == 0 || rows == 0 {
        return Err(Error::Corrupt(format!(
            "zero dimension: C={channels} tile {tile_w}x{tile_h} grid {cols}x{rows}"
        )));
    }
    if cols * rows < channels {
        return Err(Error::Corrupt(format!(
            "inconsistent geometry: C={channels} > grid {cols}x{rows}"
        )));
    }
    // all five fields are u16, so this product fits in u64 with room to
    // spare; cap it before any decoder sizes a buffer from it
    let total_samples = (cols * tile_w) as u64 * (rows * tile_h) as u64;
    if total_samples > MAX_DECODED_SAMPLES as u64 {
        return Err(Error::LimitExceeded {
            what: "frame samples",
            requested: total_samples as usize,
            limit: MAX_DECODED_SAMPLES,
        });
    }
    // v2 carries the stripe count right after the fixed header
    let (k, side_off) = if version == VERSION2 {
        if body.len() < HEADER_LEN + 2 {
            return Err(Error::Truncated {
                what: "container stripe count",
                needed: HEADER_LEN + 2,
                got: body.len(),
            });
        }
        (rd16(HEADER_LEN)?, HEADER_LEN + 2)
    } else {
        (1usize, HEADER_LEN)
    };
    let side_len = 4 * channels;
    // header fields are u16/u32, so these sums cannot overflow usize on
    // any supported target — but keep the arithmetic checked anyway: a
    // hostile header must never wrap a length computation
    let payload_off = side_off
        .checked_add(side_len)
        .ok_or_else(|| Error::Corrupt("side-info length overflow".into()))?;
    let expect = payload_off
        .checked_add(payload_len)
        .ok_or_else(|| Error::Corrupt("header length overflow".into()))?;
    if body.len() < expect {
        return Err(Error::Truncated {
            what: "container body",
            needed: expect,
            got: body.len(),
        });
    }
    if body.len() > expect {
        return Err(Error::Corrupt(format!(
            "length mismatch: header says {expect}, body is {}",
            body.len()
        )));
    }
    let side = body.get(side_off..payload_off).ok_or(Error::Truncated {
        what: "container side info",
        needed: payload_off,
        got: body.len(),
    })?;
    let mut ranges = Vec::with_capacity(channels);
    for quad in side.chunks_exact(4) {
        let min = f16_bits_to_f32(u16::from_le_bytes([quad[0], quad[1]]));
        let max = f16_bits_to_f32(u16::from_le_bytes([quad[2], quad[3]]));
        if !(min.is_finite() && max.is_finite()) || max < min {
            return Err(Error::Corrupt(format!("bad channel range [{min}, {max}]")));
        }
        ranges.push(ChannelRange { min, max });
    }
    let tail = body.get(payload_off..).ok_or(Error::Truncated {
        what: "container payload",
        needed: expect,
        got: body.len(),
    })?;
    if version != VERSION2 {
        let payload = tail.to_vec();
        return Ok(Frame {
            version,
            codec,
            n,
            qp,
            channels,
            tile_w,
            tile_h,
            cols,
            rows,
            ranges,
            stripes: vec![StripeInfo { offset: 0, len: payload_len }],
            payload,
        });
    }
    // v2: validate the stripe table before trusting any range in it
    let units = if codec == CodecKind::TlcIc { channels } else { rows };
    if k == 0 || k > units {
        return Err(Error::Corrupt(format!(
            "stripe count {k} outside 1..={units}"
        )));
    }
    if payload_len < 8 * k {
        return Err(Error::Truncated {
            what: "stripe table",
            needed: 8 * k,
            got: payload_len,
        });
    }
    let table = tail.get(..8 * k).ok_or(Error::Truncated {
        what: "stripe table",
        needed: 8 * k,
        got: tail.len(),
    })?;
    let data = tail.get(8 * k..).ok_or(Error::Truncated {
        what: "stripe payloads",
        needed: 8 * k,
        got: tail.len(),
    })?;
    let mut stripes = Vec::with_capacity(k);
    let mut off = 0usize;
    for (i, e) in table.chunks_exact(8).enumerate() {
        let len = u32::from_le_bytes([e[0], e[1], e[2], e[3]]) as usize;
        let want = u32::from_le_bytes([e[4], e[5], e[6], e[7]]);
        let end = off.checked_add(len).filter(|&end| end <= data.len()).ok_or_else(|| {
            Error::Corrupt(format!("stripe {i} range {off}+{len} outside payload"))
        })?;
        let stripe = data.get(off..end).ok_or_else(|| {
            Error::Corrupt(format!("stripe {i} range {off}+{len} outside payload"))
        })?;
        let got = crc32fast::hash(stripe);
        if got != want {
            return Err(Error::Corrupt(format!(
                "stripe {i} CRC mismatch: stored {want:#010x}, computed {got:#010x}"
            )));
        }
        stripes.push(StripeInfo { offset: off, len });
        off = end;
    }
    if off != data.len() {
        return Err(Error::Corrupt(format!(
            "stripe lengths sum to {off}, payload holds {}",
            data.len()
        )));
    }
    Ok(Frame {
        version,
        codec,
        n,
        qp,
        channels,
        tile_w,
        tile_h,
        cols,
        rows,
        ranges,
        stripes,
        payload: data.to_vec(),
    })
}

/// Decode a parsed frame back to a `QuantizedTensor`. Total: decode
/// failures in the payload codec propagate as typed errors.
pub fn unpack(frame: &Frame) -> Result<QuantizedTensor> {
    unpack_with(frame, &WorkerPool::new(1), &ScratchPool::new())
}

/// [`unpack`] with stripes decoded concurrently across `pool` and all
/// working buffers (including the returned tensor's bins) drawn from
/// `scratch` — hand `QuantizedTensor::bins` back via
/// [`ScratchPool::put_u16`] once consumed to close the reuse loop.
///
/// v1 frames are one stripe, so the same walk decodes both versions.
pub fn unpack_with(
    frame: &Frame,
    pool: &WorkerPool,
    scratch: &ScratchPool,
) -> Result<QuantizedTensor> {
    let k = frame.stripes.len();
    let units = frame.stripe_units();
    if k == 0 || units == 0 || k > units {
        return Err(Error::Corrupt(format!(
            "stripe count {k} outside 1..={units}"
        )));
    }
    let plane = frame.tile_h * frame.tile_w;

    struct DecJob<'a> {
        payload: &'a [u8],
        out: &'a mut [u16],
        meta: ImageMeta,
        channels: usize,
        res: Result<()>,
    }
    // carve the payload into per-stripe slices (validated at parse; a
    // hand-built Frame with bad ranges errors instead of panicking)
    let mut slices = Vec::with_capacity(k);
    for (i, si) in frame.stripes.iter().enumerate() {
        let s = si
            .offset
            .checked_add(si.len)
            .and_then(|end| frame.payload.get(si.offset..end))
            .ok_or_else(|| {
                Error::Corrupt(format!(
                    "stripe {i} range {}+{} outside payload",
                    si.offset, si.len
                ))
            })?;
        slices.push(s);
    }

    if frame.codec == CodecKind::TlcIc {
        let total = frame
            .channels
            .checked_mul(plane)
            .filter(|&t| t <= MAX_DECODED_SAMPLES)
            .ok_or(Error::LimitExceeded {
                what: "decoded samples",
                requested: usize::MAX,
                limit: MAX_DECODED_SAMPLES,
            })?;
        let mut bins = scratch.take_u16(total);
        bins.resize(total, 0);
        // disjoint per-stripe output spans: stripe i owns channels
        // [i*C/k, (i+1)*C/k) — the spans tile `bins` exactly
        let mut jobs: Vec<DecJob> = Vec::with_capacity(k);
        let mut rest: &mut [u16] = &mut bins;
        for (i, payload) in slices.into_iter().enumerate() {
            let (c0, c1) = stripe_span(units, k, i);
            let (cur, r) = rest.split_at_mut((c1 - c0) * plane);
            rest = r;
            jobs.push(DecJob {
                payload,
                out: cur,
                meta: ImageMeta { width: frame.tile_w, height: frame.tile_h, n: frame.n },
                channels: c1 - c0,
                res: Ok(()),
            });
        }
        pool.for_each_mut(&mut jobs, |_, job| {
            job.res = super::tlc_ic::decode_planes_into(
                job.payload,
                job.channels,
                frame.tile_h,
                frame.tile_w,
                frame.n,
                job.out,
            );
        });
        let err = jobs.iter().find_map(|j| j.res.as_ref().err().cloned());
        drop(jobs);
        if let Some(e) = err {
            scratch.put_u16(bins);
            return Err(e);
        }
        return Ok(QuantizedTensor {
            bins,
            c: frame.channels,
            h: frame.tile_h,
            w: frame.tile_w,
            n: frame.n,
            ranges: frame.ranges.clone(),
        });
    }

    // image codecs: each stripe is a full-width horizontal band of the
    // tiled plane — bands are disjoint, so split_at_mut carves them
    let meta = frame.image_meta();
    let total = meta.checked_samples()?;
    let mut samples = scratch.take_u16(total);
    samples.resize(total, 0);
    let band = frame.tile_h * meta.width;
    let mut jobs: Vec<DecJob> = Vec::with_capacity(k);
    let mut rest: &mut [u16] = &mut samples;
    for (i, payload) in slices.into_iter().enumerate() {
        let (r0, r1) = stripe_span(units, k, i);
        let (cur, r) = rest.split_at_mut((r1 - r0) * band);
        rest = r;
        jobs.push(DecJob {
            payload,
            out: cur,
            meta: ImageMeta {
                width: meta.width,
                height: (r1 - r0) * frame.tile_h,
                n: frame.n,
            },
            channels: frame.channels,
            res: Ok(()),
        });
    }
    pool.for_each_mut(&mut jobs, |_, job| {
        job.res = frame
            .codec
            .decode_image_into(job.payload, &job.meta, frame.qp, scratch, job.out);
    });
    let err = jobs.iter().find_map(|j| j.res.as_ref().err().cloned());
    drop(jobs);
    if let Some(e) = err {
        scratch.put_u16(samples);
        return Err(e);
    }
    let img = TiledImage {
        width: meta.width,
        height: meta.height,
        samples,
        n: frame.n,
        cols: frame.cols,
        rows: frame.rows,
        tile_w: frame.tile_w,
        tile_h: frame.tile_h,
        channels: frame.channels,
    };
    let mut bins = scratch.take_u16(frame.channels * plane);
    bins.resize(frame.channels * plane, 0);
    untile_into(&img, &mut bins);
    scratch.put_u16(img.samples);
    Ok(QuantizedTensor {
        bins,
        c: frame.channels,
        h: frame.tile_h,
        w: frame.tile_w,
        n: frame.n,
        ranges: frame.ranges.clone(),
    })
}

/// Recompute the trailing CRC32 of a (possibly mutated) frame in place.
/// Used by the fault-injection harness to exercise header validation
/// behind the checksum; a frame shorter than the CRC field is returned
/// unchanged.
pub fn refresh_crc(frame: &mut [u8]) {
    if frame.len() < 4 {
        return;
    }
    let body_len = frame.len() - 4;
    let crc = crc32fast::hash(&frame[..body_len]);
    frame[body_len..].copy_from_slice(&crc.to_le_bytes());
}

#[cfg(test)]
mod tests {
    #![allow(clippy::unwrap_used, clippy::expect_used)]

    use super::*;
    use crate::quant::quantize;
    use crate::tensor::Tensor;
    use crate::util::SplitMix64;

    fn random_quant(c: usize, n: u8, seed: u64) -> QuantizedTensor {
        let mut r = SplitMix64::new(seed);
        let z = Tensor::from_vec(
            &[c, 16, 16],
            (0..c * 256).map(|_| r.next_f32() * 5.0 - 2.5).collect(),
        );
        quantize(&z, n)
    }

    #[test]
    fn pack_parse_unpack_lossless_roundtrip() {
        for codec in [
            CodecKind::Tlc,
            CodecKind::PngLike,
            CodecKind::ZstdRaw,
            CodecKind::TlcIc,
        ] {
            let q = random_quant(16, 8, 1);
            let bytes = pack(&q, codec, 0);
            let frame = parse(&bytes).unwrap();
            assert_eq!(frame.n, 8);
            assert_eq!(frame.channels, 16);
            assert_eq!(frame.version, VERSION);
            assert_eq!(frame.stripes.len(), 1);
            let q2 = unpack(&frame).unwrap();
            assert_eq!(q2.bins, q.bins, "{codec:?}");
            // ranges roundtrip exactly (already f16-rounded by quantize)
            for (a, b) in q.ranges.iter().zip(&q2.ranges) {
                assert_eq!(a, b);
            }
        }
    }

    #[test]
    fn striped_pack_roundtrips_all_codecs_and_stripe_counts() {
        for codec in [
            CodecKind::Tlc,
            CodecKind::PngLike,
            CodecKind::ZstdRaw,
            CodecKind::TlcIc,
        ] {
            let q = random_quant(16, 8, 6);
            // grid for C=16 is 4x4 -> 4 tile rows; K=9 and K=999 clamp
            for k in [1usize, 2, 3, 4, 9, 999] {
                let bytes = pack_v2(&q, codec, 0, k);
                let frame = parse(&bytes).unwrap();
                assert_eq!(frame.version, VERSION2);
                assert!(frame.stripes.len() <= frame.stripe_units());
                let q2 = unpack(&frame).unwrap();
                assert_eq!(q2.bins, q.bins, "{codec:?} k={k}");
                for (a, b) in q.ranges.iter().zip(&q2.ranges) {
                    assert_eq!(a, b);
                }
            }
        }
    }

    #[test]
    fn striped_and_parallel_decodes_agree() {
        let pool = WorkerPool::new(4);
        let scratch = ScratchPool::new();
        for codec in [CodecKind::Tlc, CodecKind::TlcIc] {
            let q = random_quant(16, 6, 12);
            let bytes = pack_v2_with(&q, codec, 0, 4, &pool, &scratch);
            let frame = parse(&bytes).unwrap();
            let seq = unpack(&frame).unwrap();
            let par = unpack_with(&frame, &pool, &scratch).unwrap();
            assert_eq!(seq.bins, par.bins, "{codec:?}");
            assert_eq!(seq.bins, q.bins, "{codec:?}");
        }
        let st = scratch.stats();
        assert!(st.returned > 0, "scratch pool must see traffic: {st:?}");
    }

    #[test]
    fn stripe_k1_payload_matches_v1_exactly() {
        // one stripe = one uninterrupted model pass = v1's byte stream
        let q = random_quant(8, 8, 13);
        let v1 = pack(&q, CodecKind::Tlc, 0);
        let v2 = pack_v2(&q, CodecKind::Tlc, 0, 1);
        let f1 = parse(&v1).unwrap();
        let f2 = parse(&v2).unwrap();
        assert_eq!(f1.payload, f2.payload);
        // v2 overhead at K=1 is exactly K field + one table entry
        assert_eq!(v2.len(), v1.len() + 2 + 8);
    }

    #[test]
    fn stripe_span_partitions_units() {
        for total in [1usize, 3, 4, 7, 64, 65] {
            for k in 1..=total {
                let mut covered = 0;
                for i in 0..k {
                    let (a, b) = stripe_span(total, k, i);
                    assert_eq!(a, covered, "total={total} k={k} i={i}");
                    assert!(b > a, "empty stripe: total={total} k={k} i={i}");
                    covered = b;
                }
                assert_eq!(covered, total);
            }
        }
    }

    #[test]
    fn lossy_roundtrip_preserves_geometry() {
        let q = random_quant(8, 8, 2);
        let bytes = pack(&q, CodecKind::Mic, 20);
        let frame = parse(&bytes).unwrap();
        let q2 = unpack(&frame).unwrap();
        assert_eq!((q2.c, q2.h, q2.w, q2.n), (q.c, q.h, q.w, q.n));
        // lossy codecs stripe too (each band is its own DCT pass)
        let bytes = pack_v2(&q, CodecKind::Mic, 20, 2);
        let q2 = unpack(&parse(&bytes).unwrap()).unwrap();
        assert_eq!((q2.c, q2.h, q2.w, q2.n), (q.c, q.h, q.w, q.n));
    }

    #[test]
    fn corrupt_stripe_table_rejected() {
        let q = random_quant(16, 8, 14);
        let good = pack_v2(&q, CodecKind::Tlc, 0, 4);
        let frame = parse(&good).unwrap();
        let table_off = HEADER_LEN + 2 + 4 * frame.channels;
        // stripe count of zero
        let mut bad = good.clone();
        bad[HEADER_LEN] = 0;
        bad[HEADER_LEN + 1] = 0;
        refresh_crc(&mut bad);
        assert!(matches!(parse(&bad), Err(Error::Corrupt(_))));
        // stripe count beyond the unit count
        let mut bad = good.clone();
        bad[HEADER_LEN] = 0xFF;
        bad[HEADER_LEN + 1] = 0xFF;
        refresh_crc(&mut bad);
        assert!(parse(&bad).is_err());
        // first stripe length inflated: sum check must catch it
        let mut bad = good.clone();
        bad[table_off] = bad[table_off].wrapping_add(1);
        refresh_crc(&mut bad);
        assert!(matches!(parse(&bad), Err(Error::Corrupt(_))));
        // stripe payload corrupted: per-stripe CRC catches it even with
        // the frame CRC refreshed
        let mut bad = good.clone();
        let payload_start = table_off + 8 * frame.stripes.len();
        bad[payload_start + 2] ^= 0x10;
        refresh_crc(&mut bad);
        assert!(matches!(parse(&bad), Err(Error::Corrupt(_))));
    }

    #[test]
    fn hand_built_frame_with_bad_stripes_errors_not_panics() {
        let q = random_quant(4, 6, 15);
        let mut frame = parse(&pack_v2(&q, CodecKind::Tlc, 0, 2)).unwrap();
        // no stripes
        let saved = std::mem::take(&mut frame.stripes);
        assert!(unpack(&frame).is_err());
        // stripe range past the payload
        frame.stripes = vec![StripeInfo { offset: usize::MAX, len: 2 }];
        assert!(unpack(&frame).is_err());
        frame.stripes = vec![StripeInfo { offset: 0, len: frame.payload.len() + 1 }];
        assert!(unpack(&frame).is_err());
        // more stripes than units
        frame.stripes = (0..99).map(|_| StripeInfo { offset: 0, len: 1 }).collect();
        assert!(unpack(&frame).is_err());
        frame.stripes = saved;
        assert!(unpack(&frame).is_ok());
    }

    #[test]
    fn mismatched_magic_and_version_rejected_behind_valid_crc() {
        let q = random_quant(4, 6, 7);
        let good = pack(&q, CodecKind::Tlc, 0);
        // wrong magic, CRC refreshed so only the magic check can catch it
        let mut bad = good.clone();
        bad[0] = b'X';
        refresh_crc(&mut bad);
        assert!(matches!(parse(&bad), Err(Error::Corrupt(_))));
        // future version (2 is the striped layout now, so jump far ahead)
        let mut bad = good.clone();
        bad[4] = 0x7F;
        refresh_crc(&mut bad);
        assert!(matches!(parse(&bad), Err(Error::Unsupported(_))));
        // a v1 frame relabelled v2 must fail (its body is 2 bytes short
        // of where v2 puts the side info), not misparse
        let mut bad = good.clone();
        bad[4] = VERSION2;
        refresh_crc(&mut bad);
        assert!(parse(&bad).is_err());
        // unknown codec id
        let mut bad = good.clone();
        bad[5] = 0xEE;
        refresh_crc(&mut bad);
        assert!(matches!(parse(&bad), Err(Error::Unsupported(_))));
        // zero tile width: must be rejected, not divide/index by zero
        let mut bad = good.clone();
        bad[10] = 0;
        bad[11] = 0;
        refresh_crc(&mut bad);
        assert!(matches!(parse(&bad), Err(Error::Corrupt(_))));
        // absurd geometry: rejected by the sample cap before allocation
        let mut bad = good;
        for off in [10, 12, 14, 16] {
            bad[off] = 0xFF;
            bad[off + 1] = 0xFF;
        }
        refresh_crc(&mut bad);
        assert!(matches!(parse(&bad), Err(Error::LimitExceeded { .. })));
    }

    #[test]
    fn crc_detects_corruption() {
        let q = random_quant(4, 6, 3);
        let mut bytes = pack(&q, CodecKind::Tlc, 0);
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0x40;
        assert!(parse(&bytes).is_err());
    }

    #[test]
    fn truncation_rejected() {
        let q = random_quant(4, 6, 4);
        for bytes in [pack(&q, CodecKind::Tlc, 0), pack_v2(&q, CodecKind::Tlc, 0, 2)] {
            for cut in [0, 5, HEADER_LEN, bytes.len() - 5] {
                assert!(parse(&bytes[..cut]).is_err(), "cut at {cut}");
            }
        }
    }

    #[test]
    fn header_overhead_matches_paper_accounting() {
        // side info = C * 32 bits, exactly the paper's accounting
        let q = random_quant(32, 8, 5);
        let bytes = pack(&q, CodecKind::Tlc, 0);
        let frame = parse(&bytes).unwrap();
        let side_bits = 32 * frame.channels;
        let fixed_bits = (HEADER_LEN + 4) * 8;
        assert_eq!(
            bytes.len() * 8,
            fixed_bits + side_bits + frame.payload.len() * 8
        );
        // v2 adds exactly 2 bytes (K) + 8 per stripe
        let k = 4;
        let bytes2 = pack_v2(&q, CodecKind::Tlc, 0, k);
        let frame2 = parse(&bytes2).unwrap();
        assert_eq!(
            bytes2.len() * 8,
            fixed_bits + 16 + side_bits + 64 * k + frame2.payload.len() * 8
        );
    }
}
