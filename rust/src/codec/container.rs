//! The BaF bitstream container — what actually travels edge -> cloud.
//!
//! Layout (all integers little-endian):
//!
//! ```text
//! offset size  field
//! 0      4     magic "BAFT"
//! 4      1     version (1)
//! 5      1     codec id (CodecKind)
//! 6      1     n  (sample bit depth)
//! 7      1     qp (lossy codecs only; 0 otherwise)
//! 8      2     C  (number of channels)
//! 10     2     tile_w
//! 12     2     tile_h
//! 14     2     cols
//! 16     2     rows
//! 18     4     payload length in bytes
//! 22     4*C   side info: per channel (min f16, max f16) — the paper's
//!              C*32 bits of quantizer parameters (§3.2)
//! ..     len   entropy-coded payload
//! ..     4     CRC32 over everything above
//! ```

use super::{CodecKind, Error, ImageMeta, Result, MAX_DECODED_SAMPLES};
use crate::quant::{ChannelRange, QuantizedTensor};
use crate::tile::{tile, untile, TiledImage};
use crate::util::f16::{f16_bits_to_f32, f32_to_f16_bits};

pub const MAGIC: &[u8; 4] = b"BAFT";
pub const VERSION: u8 = 1;
pub const HEADER_LEN: usize = 22;

/// A decoded frame header + payload view.
#[derive(Debug, Clone)]
pub struct Frame {
    pub codec: CodecKind,
    pub n: u8,
    pub qp: u8,
    pub channels: usize,
    pub tile_w: usize,
    pub tile_h: usize,
    pub cols: usize,
    pub rows: usize,
    pub ranges: Vec<ChannelRange>,
    pub payload: Vec<u8>,
}

impl Frame {
    pub fn image_meta(&self) -> ImageMeta {
        ImageMeta {
            width: self.cols * self.tile_w,
            height: self.rows * self.tile_h,
            n: self.n,
        }
    }
}

/// Serialize: quantized tensor -> tiled image -> codec -> framed bytes.
pub fn pack(q: &QuantizedTensor, codec: CodecKind, qp: u8) -> Vec<u8> {
    let img = tile(q);
    // TLC-IC codes the channel-plane sequence directly (inter-channel
    // prediction needs plane structure); other codecs get the tiled image.
    let payload = if codec == CodecKind::TlcIc {
        super::tlc_ic::encode_planes(&q.bins, q.c, q.h, q.w, q.n)
    } else {
        codec.encode_image(&img.samples, img.width, img.height, q.n, qp)
    };
    let mut out = Vec::with_capacity(HEADER_LEN + 4 * q.c + payload.len() + 4);
    out.extend_from_slice(MAGIC);
    out.push(VERSION);
    out.push(codec as u8);
    out.push(q.n);
    out.push(qp);
    out.extend_from_slice(&(q.c as u16).to_le_bytes());
    out.extend_from_slice(&(img.tile_w as u16).to_le_bytes());
    out.extend_from_slice(&(img.tile_h as u16).to_le_bytes());
    out.extend_from_slice(&(img.cols as u16).to_le_bytes());
    out.extend_from_slice(&(img.rows as u16).to_le_bytes());
    out.extend_from_slice(&(payload.len() as u32).to_le_bytes());
    for r in &q.ranges {
        out.extend_from_slice(&f32_to_f16_bits(r.min).to_le_bytes());
        out.extend_from_slice(&f32_to_f16_bits(r.max).to_le_bytes());
    }
    out.extend_from_slice(&payload);
    let crc = crc32fast::hash(&out);
    out.extend_from_slice(&crc.to_le_bytes());
    out
}

/// Parse, validate, and CRC-check a frame.
///
/// Total: every field is validated before it drives an allocation or an
/// index — short input is [`Error::Truncated`], bad magic / CRC /
/// geometry is [`Error::Corrupt`], future versions and unknown codec ids
/// are [`Error::Unsupported`], and a header whose geometry implies more
/// than [`MAX_DECODED_SAMPLES`] is [`Error::LimitExceeded`].
pub fn parse(bytes: &[u8]) -> Result<Frame> {
    if bytes.len() < HEADER_LEN + 4 {
        return Err(Error::Truncated {
            what: "container frame",
            needed: HEADER_LEN + 4,
            got: bytes.len(),
        });
    }
    let (body, crc_bytes) = bytes.split_at(bytes.len() - 4);
    let want = u32::from_le_bytes([crc_bytes[0], crc_bytes[1], crc_bytes[2], crc_bytes[3]]);
    let got = crc32fast::hash(body);
    if want != got {
        return Err(Error::Corrupt(format!(
            "CRC mismatch: stored {want:#010x}, computed {got:#010x}"
        )));
    }
    if &body[0..4] != MAGIC {
        return Err(Error::Corrupt(format!(
            "bad magic {:02x?} (want {MAGIC:02x?})",
            &body[0..4]
        )));
    }
    if body[4] != VERSION {
        return Err(Error::Unsupported(format!(
            "container version {} (this build reads {VERSION})",
            body[4]
        )));
    }
    let codec = CodecKind::from_u8(body[5])?;
    let n = body[6];
    let qp = body[7];
    if !(1..=16).contains(&n) {
        return Err(Error::Corrupt(format!("bit depth {n} outside 1..=16")));
    }
    let rd16 = |off: usize| u16::from_le_bytes([body[off], body[off + 1]]) as usize;
    let channels = rd16(8);
    let tile_w = rd16(10);
    let tile_h = rd16(12);
    let cols = rd16(14);
    let rows = rd16(16);
    let payload_len =
        u32::from_le_bytes([body[18], body[19], body[20], body[21]]) as usize;
    if channels == 0 || tile_w == 0 || tile_h == 0 || cols == 0 || rows == 0 {
        return Err(Error::Corrupt(format!(
            "zero dimension: C={channels} tile {tile_w}x{tile_h} grid {cols}x{rows}"
        )));
    }
    if cols * rows < channels {
        return Err(Error::Corrupt(format!(
            "inconsistent geometry: C={channels} > grid {cols}x{rows}"
        )));
    }
    // all five fields are u16, so this product fits in u64 with room to
    // spare; cap it before any decoder sizes a buffer from it
    let total_samples = (cols * tile_w) as u64 * (rows * tile_h) as u64;
    if total_samples > MAX_DECODED_SAMPLES as u64 {
        return Err(Error::LimitExceeded {
            what: "frame samples",
            requested: total_samples as usize,
            limit: MAX_DECODED_SAMPLES,
        });
    }
    let side_len = 4 * channels;
    let expect = HEADER_LEN + side_len + payload_len;
    if body.len() < expect {
        return Err(Error::Truncated {
            what: "container body",
            needed: expect,
            got: body.len(),
        });
    }
    if body.len() > expect {
        return Err(Error::Corrupt(format!(
            "length mismatch: header says {expect}, body is {}",
            body.len()
        )));
    }
    let mut ranges = Vec::with_capacity(channels);
    for ch in 0..channels {
        let off = HEADER_LEN + 4 * ch;
        let min = f16_bits_to_f32(u16::from_le_bytes([body[off], body[off + 1]]));
        let max = f16_bits_to_f32(u16::from_le_bytes([body[off + 2], body[off + 3]]));
        if !(min.is_finite() && max.is_finite()) || max < min {
            return Err(Error::Corrupt(format!("bad channel range [{min}, {max}]")));
        }
        ranges.push(ChannelRange { min, max });
    }
    let payload = body[HEADER_LEN + side_len..].to_vec();
    Ok(Frame { codec, n, qp, channels, tile_w, tile_h, cols, rows, ranges, payload })
}

/// Decode a parsed frame back to a `QuantizedTensor`. Total: decode
/// failures in the payload codec propagate as typed errors.
pub fn unpack(frame: &Frame) -> Result<QuantizedTensor> {
    let meta = frame.image_meta();
    if frame.codec == CodecKind::TlcIc {
        return Ok(QuantizedTensor {
            bins: super::tlc_ic::decode_planes(
                &frame.payload,
                frame.channels,
                frame.tile_h,
                frame.tile_w,
                frame.n,
            )?,
            c: frame.channels,
            h: frame.tile_h,
            w: frame.tile_w,
            n: frame.n,
            ranges: frame.ranges.clone(),
        });
    }
    let samples = frame.codec.decode_image(&frame.payload, &meta, frame.qp)?;
    let img = TiledImage {
        width: meta.width,
        height: meta.height,
        samples,
        n: frame.n,
        cols: frame.cols,
        rows: frame.rows,
        tile_w: frame.tile_w,
        tile_h: frame.tile_h,
        channels: frame.channels,
    };
    Ok(QuantizedTensor {
        bins: untile(&img),
        c: frame.channels,
        h: frame.tile_h,
        w: frame.tile_w,
        n: frame.n,
        ranges: frame.ranges.clone(),
    })
}

/// Recompute the trailing CRC32 of a (possibly mutated) frame in place.
/// Used by the fault-injection harness to exercise header validation
/// behind the checksum; a frame shorter than the CRC field is returned
/// unchanged.
pub fn refresh_crc(frame: &mut [u8]) {
    if frame.len() < 4 {
        return;
    }
    let body_len = frame.len() - 4;
    let crc = crc32fast::hash(&frame[..body_len]);
    frame[body_len..].copy_from_slice(&crc.to_le_bytes());
}

#[cfg(test)]
mod tests {
    #![allow(clippy::unwrap_used, clippy::expect_used)]

    use super::*;
    use crate::quant::quantize;
    use crate::tensor::Tensor;
    use crate::util::SplitMix64;

    fn random_quant(c: usize, n: u8, seed: u64) -> QuantizedTensor {
        let mut r = SplitMix64::new(seed);
        let z = Tensor::from_vec(
            &[c, 16, 16],
            (0..c * 256).map(|_| r.next_f32() * 5.0 - 2.5).collect(),
        );
        quantize(&z, n)
    }

    #[test]
    fn pack_parse_unpack_lossless_roundtrip() {
        for codec in [
            CodecKind::Tlc,
            CodecKind::PngLike,
            CodecKind::ZstdRaw,
            CodecKind::TlcIc,
        ] {
            let q = random_quant(16, 8, 1);
            let bytes = pack(&q, codec, 0);
            let frame = parse(&bytes).unwrap();
            assert_eq!(frame.n, 8);
            assert_eq!(frame.channels, 16);
            let q2 = unpack(&frame).unwrap();
            assert_eq!(q2.bins, q.bins, "{codec:?}");
            // ranges roundtrip exactly (already f16-rounded by quantize)
            for (a, b) in q.ranges.iter().zip(&q2.ranges) {
                assert_eq!(a, b);
            }
        }
    }

    #[test]
    fn lossy_roundtrip_preserves_geometry() {
        let q = random_quant(8, 8, 2);
        let bytes = pack(&q, CodecKind::Mic, 20);
        let frame = parse(&bytes).unwrap();
        let q2 = unpack(&frame).unwrap();
        assert_eq!((q2.c, q2.h, q2.w, q2.n), (q.c, q.h, q.w, q.n));
    }

    #[test]
    fn mismatched_magic_and_version_rejected_behind_valid_crc() {
        let q = random_quant(4, 6, 7);
        let good = pack(&q, CodecKind::Tlc, 0);
        // wrong magic, CRC refreshed so only the magic check can catch it
        let mut bad = good.clone();
        bad[0] = b'X';
        refresh_crc(&mut bad);
        assert!(matches!(parse(&bad), Err(Error::Corrupt(_))));
        // future version
        let mut bad = good.clone();
        bad[4] = VERSION + 1;
        refresh_crc(&mut bad);
        assert!(matches!(parse(&bad), Err(Error::Unsupported(_))));
        // unknown codec id
        let mut bad = good.clone();
        bad[5] = 0xEE;
        refresh_crc(&mut bad);
        assert!(matches!(parse(&bad), Err(Error::Unsupported(_))));
        // zero tile width: must be rejected, not divide/index by zero
        let mut bad = good.clone();
        bad[10] = 0;
        bad[11] = 0;
        refresh_crc(&mut bad);
        assert!(matches!(parse(&bad), Err(Error::Corrupt(_))));
        // absurd geometry: rejected by the sample cap before allocation
        let mut bad = good;
        for off in [10, 12, 14, 16] {
            bad[off] = 0xFF;
            bad[off + 1] = 0xFF;
        }
        refresh_crc(&mut bad);
        assert!(matches!(parse(&bad), Err(Error::LimitExceeded { .. })));
    }

    #[test]
    fn crc_detects_corruption() {
        let q = random_quant(4, 6, 3);
        let mut bytes = pack(&q, CodecKind::Tlc, 0);
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0x40;
        assert!(parse(&bytes).is_err());
    }

    #[test]
    fn truncation_rejected() {
        let q = random_quant(4, 6, 4);
        let bytes = pack(&q, CodecKind::Tlc, 0);
        for cut in [0, 5, HEADER_LEN, bytes.len() - 5] {
            assert!(parse(&bytes[..cut]).is_err(), "cut at {cut}");
        }
    }

    #[test]
    fn header_overhead_matches_paper_accounting() {
        // side info = C * 32 bits, exactly the paper's accounting
        let q = random_quant(32, 8, 5);
        let bytes = pack(&q, CodecKind::Tlc, 0);
        let frame = parse(&bytes).unwrap();
        let side_bits = 32 * frame.channels;
        let fixed_bits = (HEADER_LEN + 4) * 8;
        assert_eq!(
            bytes.len() * 8,
            fixed_bits + side_bits + frame.payload.len() * 8
        );
    }
}
