//! The BaF bitstream container — what actually travels edge -> cloud.
//!
//! Layout (all integers little-endian):
//!
//! ```text
//! offset size  field
//! 0      4     magic "BAFT"
//! 4      1     version (1)
//! 5      1     codec id (CodecKind)
//! 6      1     n  (sample bit depth)
//! 7      1     qp (lossy codecs only; 0 otherwise)
//! 8      2     C  (number of channels)
//! 10     2     tile_w
//! 12     2     tile_h
//! 14     2     cols
//! 16     2     rows
//! 18     4     payload length in bytes
//! 22     4*C   side info: per channel (min f16, max f16) — the paper's
//!              C*32 bits of quantizer parameters (§3.2)
//! ..     len   entropy-coded payload
//! ..     4     CRC32 over everything above
//! ```

use super::{CodecKind, ImageMeta};
use crate::quant::{ChannelRange, QuantizedTensor};
use crate::tile::{tile, untile, TiledImage};
use crate::util::f16::{f16_bits_to_f32, f32_to_f16_bits};
use anyhow::{bail, Result};

pub const MAGIC: &[u8; 4] = b"BAFT";
pub const VERSION: u8 = 1;
const HEADER_LEN: usize = 22;

/// A decoded frame header + payload view.
#[derive(Debug, Clone)]
pub struct Frame {
    pub codec: CodecKind,
    pub n: u8,
    pub qp: u8,
    pub channels: usize,
    pub tile_w: usize,
    pub tile_h: usize,
    pub cols: usize,
    pub rows: usize,
    pub ranges: Vec<ChannelRange>,
    pub payload: Vec<u8>,
}

impl Frame {
    pub fn image_meta(&self) -> ImageMeta {
        ImageMeta {
            width: self.cols * self.tile_w,
            height: self.rows * self.tile_h,
            n: self.n,
        }
    }
}

/// Serialize: quantized tensor -> tiled image -> codec -> framed bytes.
pub fn pack(q: &QuantizedTensor, codec: CodecKind, qp: u8) -> Vec<u8> {
    let img = tile(q);
    // TLC-IC codes the channel-plane sequence directly (inter-channel
    // prediction needs plane structure); other codecs get the tiled image.
    let payload = if codec == CodecKind::TlcIc {
        super::tlc_ic::encode_planes(&q.bins, q.c, q.h, q.w, q.n)
    } else {
        codec.encode_image(&img.samples, img.width, img.height, q.n, qp)
    };
    let mut out = Vec::with_capacity(HEADER_LEN + 4 * q.c + payload.len() + 4);
    out.extend_from_slice(MAGIC);
    out.push(VERSION);
    out.push(codec as u8);
    out.push(q.n);
    out.push(qp);
    out.extend_from_slice(&(q.c as u16).to_le_bytes());
    out.extend_from_slice(&(img.tile_w as u16).to_le_bytes());
    out.extend_from_slice(&(img.tile_h as u16).to_le_bytes());
    out.extend_from_slice(&(img.cols as u16).to_le_bytes());
    out.extend_from_slice(&(img.rows as u16).to_le_bytes());
    out.extend_from_slice(&(payload.len() as u32).to_le_bytes());
    for r in &q.ranges {
        out.extend_from_slice(&f32_to_f16_bits(r.min).to_le_bytes());
        out.extend_from_slice(&f32_to_f16_bits(r.max).to_le_bytes());
    }
    out.extend_from_slice(&payload);
    let crc = crc32fast::hash(&out);
    out.extend_from_slice(&crc.to_le_bytes());
    out
}

/// Parse and CRC-check a frame.
pub fn parse(bytes: &[u8]) -> Result<Frame> {
    if bytes.len() < HEADER_LEN + 4 {
        bail!("frame too short ({} bytes)", bytes.len());
    }
    let (body, crc_bytes) = bytes.split_at(bytes.len() - 4);
    let want = u32::from_le_bytes(crc_bytes.try_into().unwrap());
    let got = crc32fast::hash(body);
    if want != got {
        bail!("CRC mismatch: stored {want:#010x}, computed {got:#010x}");
    }
    if &body[0..4] != MAGIC {
        bail!("bad magic");
    }
    if body[4] != VERSION {
        bail!("unsupported version {}", body[4]);
    }
    let codec = CodecKind::from_u8(body[5])?;
    let n = body[6];
    let qp = body[7];
    if !(2..=16).contains(&n) {
        bail!("bad bit depth {n}");
    }
    let rd16 = |off: usize| u16::from_le_bytes([body[off], body[off + 1]]) as usize;
    let channels = rd16(8);
    let tile_w = rd16(10);
    let tile_h = rd16(12);
    let cols = rd16(14);
    let rows = rd16(16);
    let payload_len =
        u32::from_le_bytes([body[18], body[19], body[20], body[21]]) as usize;
    if channels == 0 || cols * rows < channels {
        bail!("inconsistent geometry: C={channels}, grid {cols}x{rows}");
    }
    let side_len = 4 * channels;
    if body.len() != HEADER_LEN + side_len + payload_len {
        bail!(
            "length mismatch: header says {} body is {}",
            HEADER_LEN + side_len + payload_len,
            body.len()
        );
    }
    let mut ranges = Vec::with_capacity(channels);
    for ch in 0..channels {
        let off = HEADER_LEN + 4 * ch;
        let min = f16_bits_to_f32(u16::from_le_bytes([body[off], body[off + 1]]));
        let max = f16_bits_to_f32(u16::from_le_bytes([body[off + 2], body[off + 3]]));
        if !(min.is_finite() && max.is_finite()) || max < min {
            bail!("bad channel range [{min}, {max}]");
        }
        ranges.push(ChannelRange { min, max });
    }
    let payload = body[HEADER_LEN + side_len..].to_vec();
    Ok(Frame { codec, n, qp, channels, tile_w, tile_h, cols, rows, ranges, payload })
}

/// Decode a parsed frame back to a `QuantizedTensor`.
pub fn unpack(frame: &Frame) -> QuantizedTensor {
    let meta = frame.image_meta();
    if frame.codec == CodecKind::TlcIc {
        return QuantizedTensor {
            bins: super::tlc_ic::decode_planes(
                &frame.payload,
                frame.channels,
                frame.tile_h,
                frame.tile_w,
                frame.n,
            ),
            c: frame.channels,
            h: frame.tile_h,
            w: frame.tile_w,
            n: frame.n,
            ranges: frame.ranges.clone(),
        };
    }
    let samples = frame.codec.decode_image(&frame.payload, &meta, frame.qp);
    let img = TiledImage {
        width: meta.width,
        height: meta.height,
        samples,
        n: frame.n,
        cols: frame.cols,
        rows: frame.rows,
        tile_w: frame.tile_w,
        tile_h: frame.tile_h,
        channels: frame.channels,
    };
    QuantizedTensor {
        bins: untile(&img),
        c: frame.channels,
        h: frame.tile_h,
        w: frame.tile_w,
        n: frame.n,
        ranges: frame.ranges.clone(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::quantize;
    use crate::tensor::Tensor;
    use crate::util::SplitMix64;

    fn random_quant(c: usize, n: u8, seed: u64) -> QuantizedTensor {
        let mut r = SplitMix64::new(seed);
        let z = Tensor::from_vec(
            &[c, 16, 16],
            (0..c * 256).map(|_| r.next_f32() * 5.0 - 2.5).collect(),
        );
        quantize(&z, n)
    }

    #[test]
    fn pack_parse_unpack_lossless_roundtrip() {
        for codec in [
            CodecKind::Tlc,
            CodecKind::PngLike,
            CodecKind::ZstdRaw,
            CodecKind::TlcIc,
        ] {
            let q = random_quant(16, 8, 1);
            let bytes = pack(&q, codec, 0);
            let frame = parse(&bytes).unwrap();
            assert_eq!(frame.n, 8);
            assert_eq!(frame.channels, 16);
            let q2 = unpack(&frame);
            assert_eq!(q2.bins, q.bins, "{codec:?}");
            // ranges roundtrip exactly (already f16-rounded by quantize)
            for (a, b) in q.ranges.iter().zip(&q2.ranges) {
                assert_eq!(a, b);
            }
        }
    }

    #[test]
    fn lossy_roundtrip_preserves_geometry() {
        let q = random_quant(8, 8, 2);
        let bytes = pack(&q, CodecKind::Mic, 20);
        let frame = parse(&bytes).unwrap();
        let q2 = unpack(&frame);
        assert_eq!((q2.c, q2.h, q2.w, q2.n), (q.c, q.h, q.w, q.n));
    }

    #[test]
    fn crc_detects_corruption() {
        let q = random_quant(4, 6, 3);
        let mut bytes = pack(&q, CodecKind::Tlc, 0);
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0x40;
        assert!(parse(&bytes).is_err());
    }

    #[test]
    fn truncation_rejected() {
        let q = random_quant(4, 6, 4);
        let bytes = pack(&q, CodecKind::Tlc, 0);
        for cut in [0, 5, HEADER_LEN, bytes.len() - 5] {
            assert!(parse(&bytes[..cut]).is_err(), "cut at {cut}");
        }
    }

    #[test]
    fn header_overhead_matches_paper_accounting() {
        // side info = C * 32 bits, exactly the paper's accounting
        let q = random_quant(32, 8, 5);
        let bytes = pack(&q, CodecKind::Tlc, 0);
        let frame = parse(&bytes).unwrap();
        let side_bits = 32 * frame.channels;
        let fixed_bits = (HEADER_LEN + 4) * 8;
        assert_eq!(
            bytes.len() * 8,
            fixed_bits + side_bits + frame.payload.len() * 8
        );
    }
}
