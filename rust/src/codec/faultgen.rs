//! Fault generation for decode-robustness testing.
//!
//! The no-panic contract (see the [`crate::codec`] module docs) is only
//! as good as the adversarial inputs it has been exercised against. This
//! module generates them deterministically:
//!
//! * [`all_truncations`] — every prefix of a valid stream (the "frame
//!   cut mid-flight" failure mode);
//! * [`all_bit_flips`] — every single-bit flip (the "one flipped bit on
//!   the wire" failure mode; for container frames the CRC must catch
//!   every one of these);
//! * [`header_mutations`] — targeted header-field corruption with the
//!   CRC refreshed, so validation logic behind the checksum is reached;
//! * [`stripe_table_mutations`] — v2-specific corruption of the stripe
//!   count and stripe table (lengths and per-stripe CRCs), again with
//!   the frame CRC refreshed;
//! * [`wire_mutations`] — transport-message corruption (truncated
//!   length prefix, hostile `frame_len`, header bit flips) for the
//!   loopback TCP fault suite in `tests/transport_robustness.rs`;
//!   version-aware: v2 messages (with the sequence field) get their
//!   shifted length prefix targeted;
//! * [`verdict_faults`] — the misbehaving-*receiver* schedule (garbage
//!   verdict byte, truncated verdict, verdict-then-reset) the sender
//!   must survive with a typed error and bounded retransmission;
//! * [`Corruptor`] — a seeded random fault source for end-to-end runs
//!   (the E5 server's `--corrupt-rate` injection).
//!
//! `tests/decode_robustness.rs` drives all of these against every codec:
//! truncations and bit flips of CRC-protected frames must yield `Err` or
//! the exact original tensor; CRC-refreshed header mutations and raw
//! payload corruption must yield `Err` or a bounded, shape-consistent
//! result. No input may panic or over-allocate.

use super::container;
use crate::util::SplitMix64;

/// One deterministic fault applied to a byte stream.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Fault {
    /// Keep only the first `len` bytes.
    Truncate { len: usize },
    /// XOR bit `bit` (0..8) of byte `pos`.
    BitFlip { pos: usize, bit: u8 },
    /// Overwrite byte `pos` with `value`.
    SetByte { pos: usize, value: u8 },
}

impl Fault {
    /// Apply the fault, returning the corrupted copy. Out-of-range
    /// positions return the input unchanged (so generators can be sloppy
    /// about stream length).
    pub fn apply(&self, bytes: &[u8]) -> Vec<u8> {
        let mut out = bytes.to_vec();
        match *self {
            Fault::Truncate { len } => out.truncate(len),
            Fault::BitFlip { pos, bit } => {
                if let Some(b) = out.get_mut(pos) {
                    *b ^= 1 << (bit & 7);
                }
            }
            Fault::SetByte { pos, value } => {
                if let Some(b) = out.get_mut(pos) {
                    *b = value;
                }
            }
        }
        out
    }
}

/// Every 1-byte-granular truncation of a stream: lengths 0..len.
pub fn all_truncations(len: usize) -> impl Iterator<Item = Fault> {
    (0..len).map(|len| Fault::Truncate { len })
}

/// Every single-bit flip of a stream.
pub fn all_bit_flips(len: usize) -> impl Iterator<Item = Fault> {
    (0..len).flat_map(|pos| (0..8).map(move |bit| Fault::BitFlip { pos, bit }))
}

/// Targeted corruptions of a container frame's fixed header, with the
/// trailing CRC refreshed so parsing reaches the validation logic the
/// checksum would otherwise shadow. Returns complete corrupted frames.
pub fn header_mutations(frame: &[u8]) -> Vec<Vec<u8>> {
    let mut out = Vec::new();
    let header = container::HEADER_LEN.min(frame.len());
    for pos in 0..header {
        for value in [0x00, 0x01, 0x7F, 0xFF] {
            let mut bad = Fault::SetByte { pos, value }.apply(frame);
            container::refresh_crc(&mut bad);
            out.push(bad);
        }
    }
    out
}

/// Targeted corruptions of a v2 (striped) frame's stripe-count field and
/// stripe table, with the trailing CRC refreshed — the table drives
/// payload slicing in `container::parse`, so this reaches the
/// length-sum, range, and per-stripe CRC validation paths directly.
/// Returns an empty vec for non-v2 frames (nothing stripe-shaped to hit).
pub fn stripe_table_mutations(frame: &[u8]) -> Vec<Vec<u8>> {
    let mut out = Vec::new();
    if frame.len() <= container::HEADER_LEN + 2 || frame[4] != container::VERSION2 {
        return out;
    }
    let rd16 = |off: usize| u16::from_le_bytes([frame[off], frame[off + 1]]) as usize;
    let channels = rd16(8);
    let k = rd16(container::HEADER_LEN);
    let table_off = container::HEADER_LEN + 2 + 4 * channels;
    let table_end = (table_off + 8 * k).min(frame.len());
    let targets = (container::HEADER_LEN..container::HEADER_LEN + 2)
        .chain(table_off..table_end);
    for pos in targets {
        for value in [0x00, 0x01, 0xFF] {
            let mut bad = Fault::SetByte { pos, value }.apply(frame);
            container::refresh_crc(&mut bad);
            out.push(bad);
        }
    }
    out
}

/// Wire-level corruptions of a complete transport message (see
/// [`crate::net::wire`] for the layout): header truncations (cut length
/// prefix), a few payload cuts, hostile `frame_len` overwrites (zero,
/// short, just past [`crate::net::wire::MAX_FRAME_LEN`], `u32::MAX`)
/// with the message CRC refreshed so the length check itself is
/// reached, and every single-bit flip of the 9-byte header (CRC left
/// stale — the receiver must catch these by checksum or field
/// validation). `tests/transport_robustness.rs` replays each returned
/// byte string over a loopback socket and requires a typed
/// `net::Error` or byte-identical delivery — never a panic, never an
/// allocation beyond the wire cap.
pub fn wire_mutations(msg: &[u8]) -> Vec<Vec<u8>> {
    use crate::net::wire;

    let mut out = Vec::new();
    // v2 messages carry the 8-byte sequence field, which shifts both
    // the header end and the length prefix; target whichever layout
    // the message actually uses
    let hdr_len = if msg.get(4) == Some(&wire::VERSION2) {
        wire::HEADER_V2_LEN
    } else {
        wire::HEADER_LEN
    };
    let len_off = hdr_len - 4;
    // truncations: every header prefix, then a few cuts inside the body
    let header = hdr_len.min(msg.len());
    for len in 0..header {
        out.push(Fault::Truncate { len }.apply(msg));
    }
    if msg.len() > hdr_len + wire::CRC_LEN {
        for len in [hdr_len + 1, (hdr_len + msg.len()) / 2, msg.len() - 1] {
            out.push(Fault::Truncate { len }.apply(msg));
        }
    }
    // hostile length prefixes, CRC refreshed so validation is reached
    if msg.len() >= hdr_len + wire::CRC_LEN {
        for len in [0u32, 1, (wire::MAX_FRAME_LEN as u32) + 1, u32::MAX] {
            let mut bad = msg.to_vec();
            bad[len_off..len_off + 4].copy_from_slice(&len.to_le_bytes());
            wire::refresh_msg_crc(&mut bad);
            out.push(bad);
        }
    }
    // every single-bit flip of the header, CRC left stale on purpose
    for f in all_bit_flips(header) {
        out.push(f.apply(msg));
    }
    out
}

/// One way a misbehaving *receiver* can mangle the verdict byte the
/// sender blocks on. The complement of [`wire_mutations`]: that covers
/// the edge→cloud direction, this covers cloud→edge. The sender must
/// turn each of these into a typed [`crate::net::Error`] with bounded
/// retransmission — never a panic, never an unbounded retry loop.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum VerdictFault {
    /// Answer a byte that is none of ACK / NACK / BUSY.
    Garbage(u8),
    /// Close the connection without answering at all (the verdict is
    /// truncated to zero bytes).
    Truncated,
    /// Answer ACK, then immediately reset the connection — the message
    /// *was* delivered, so the sender must report success and the next
    /// send must survive the dead socket.
    AckThenReset,
    /// Answer NACK, then immediately reset the connection.
    NackThenReset,
}

/// The deterministic verdict-fault schedule for
/// `tests/transport_robustness.rs`.
pub fn verdict_faults() -> Vec<VerdictFault> {
    vec![
        VerdictFault::Garbage(0x00),
        VerdictFault::Garbage(0xFF),
        // one bit off ACK: nearly-right garbage must not pass
        VerdictFault::Garbage(0xA4),
        VerdictFault::Truncated,
        VerdictFault::AckThenReset,
        VerdictFault::NackThenReset,
    ]
}

/// Seeded random fault source for end-to-end corruption injection.
///
/// Mirrors a lossy transport: each corrupted frame gets one of
/// truncation, a burst of bit flips, or random garbage of similar
/// length. Deterministic given the seed.
#[derive(Debug)]
pub struct Corruptor {
    rng: SplitMix64,
}

impl Corruptor {
    pub fn new(seed: u64) -> Self {
        Self { rng: SplitMix64::new(seed) }
    }

    /// Return a corrupted copy of `frame`.
    pub fn corrupt(&mut self, frame: &[u8]) -> Vec<u8> {
        if frame.is_empty() {
            return vec![0xAA];
        }
        match self.rng.next_u64() % 3 {
            0 => {
                // truncate somewhere strictly inside the frame
                let len = (self.rng.next_u64() as usize) % frame.len();
                Fault::Truncate { len }.apply(frame)
            }
            1 => {
                // 1..=8 random bit flips
                let mut out = frame.to_vec();
                let flips = self.rng.next_u64() % 8 + 1;
                for _ in 0..flips {
                    let pos = (self.rng.next_u64() as usize) % out.len();
                    let bit = (self.rng.next_u64() % 8) as u8;
                    out[pos] ^= 1 << bit;
                }
                out
            }
            _ => {
                // random garbage, same order of magnitude in length
                let len = (self.rng.next_u64() as usize) % (frame.len() + 1) + 1;
                (0..len).map(|_| self.rng.next_u64() as u8).collect()
            }
        }
    }
}

#[cfg(test)]
mod tests {
    #![allow(clippy::unwrap_used, clippy::expect_used)]

    use super::*;

    #[test]
    fn fault_application_is_local_and_total() {
        let data = vec![0u8; 16];
        assert_eq!(Fault::Truncate { len: 4 }.apply(&data).len(), 4);
        let flipped = Fault::BitFlip { pos: 3, bit: 2 }.apply(&data);
        assert_eq!(flipped[3], 0b100);
        assert_eq!(flipped.iter().filter(|&&b| b != 0).count(), 1);
        // out-of-range faults are no-ops, not panics
        assert_eq!(Fault::BitFlip { pos: 99, bit: 0 }.apply(&data), data);
        assert_eq!(Fault::SetByte { pos: 99, value: 1 }.apply(&data), data);
    }

    #[test]
    fn generators_cover_the_stream() {
        assert_eq!(all_truncations(10).count(), 10);
        assert_eq!(all_bit_flips(10).count(), 80);
        // every bit position appears exactly once
        let mut seen = [[false; 8]; 10];
        for f in all_bit_flips(10) {
            if let Fault::BitFlip { pos, bit } = f {
                assert!(!seen[pos][bit as usize]);
                seen[pos][bit as usize] = true;
            }
        }
    }

    #[test]
    fn stripe_mutations_target_v2_frames_only() {
        use crate::codec::CodecKind;
        use crate::quant::quantize;
        use crate::tensor::Tensor;

        let mut r = SplitMix64::new(21);
        let z = Tensor::from_vec(
            &[8, 8, 8],
            (0..512).map(|_| r.next_f32() * 2.0 - 1.0).collect(),
        );
        let q = quantize(&z, 6);
        let v1 = container::pack(&q, CodecKind::Tlc, 0);
        assert!(stripe_table_mutations(&v1).is_empty());
        let v2 = container::pack_v2(&q, CodecKind::Tlc, 0, 2);
        let muts = stripe_table_mutations(&v2);
        // 2 stripe-count bytes + 16 table bytes, 3 values each
        assert_eq!(muts.len(), (2 + 16) * 3);
        for bad in &muts {
            assert_eq!(bad.len(), v2.len(), "SetByte never resizes");
        }
        // mutated frames must parse to Err or reproduce the original
        // tensor exactly — never panic (the CRC is refreshed, so parse
        // reaches the table validation itself)
        let mut rejected = 0;
        for bad in &muts {
            match container::parse(bad).and_then(|f| container::unpack(&f)) {
                Ok(q2) => assert_eq!(q2.bins, q.bins),
                Err(_) => rejected += 1,
            }
        }
        assert!(rejected > 0, "some mutation must invalidate the table");
    }

    #[test]
    fn wire_mutations_cover_truncation_length_and_bitflips() {
        use crate::net::wire;

        let msg = wire::encode_msg(&[5u8; 40]);
        let muts = wire_mutations(&msg);
        // 9 header truncations + 3 body cuts + 4 length overwrites
        // + 72 header bit flips
        assert_eq!(muts.len(), wire::HEADER_LEN + 3 + 4 + 8 * wire::HEADER_LEN);
        assert!(muts.iter().all(|m| m != &msg), "every mutation differs");
        // the hostile-length mutations carry a *valid* message CRC, so
        // they exercise the length validation rather than the checksum
        let oversize = muts
            .iter()
            .filter(|m| m.len() == msg.len())
            .filter(|m| {
                let body = &m[..m.len() - wire::CRC_LEN];
                let mut t = [0u8; wire::CRC_LEN];
                t.copy_from_slice(&m[m.len() - wire::CRC_LEN..]);
                wire::check_crc(body, &t).is_ok()
                    && u32::from_le_bytes([m[5], m[6], m[7], m[8]]) as usize
                        > wire::MAX_FRAME_LEN
            })
            .count();
        assert_eq!(oversize, 2, "MAX+1 and u32::MAX variants present");
    }

    #[test]
    fn wire_mutations_target_the_v2_layout() {
        use crate::net::wire;

        let msg = wire::encode_msg_v2(&[5u8; 40], 42);
        let muts = wire_mutations(&msg);
        // 17 header truncations + 3 body cuts + 4 length overwrites
        // + 136 header bit flips
        assert_eq!(
            muts.len(),
            wire::HEADER_V2_LEN + 3 + 4 + 8 * wire::HEADER_V2_LEN
        );
        assert!(muts.iter().all(|m| m != &msg), "every mutation differs");
        // the length overwrites must hit the *v2* length prefix (bytes
        // 13..17), not the seq field: each carries a refreshed CRC and a
        // parseable header whose frame_len is the hostile value
        let hostile = muts
            .iter()
            .filter(|m| m.len() == msg.len())
            .filter(|m| {
                let body = &m[..m.len() - wire::CRC_LEN];
                let mut t = [0u8; wire::CRC_LEN];
                t.copy_from_slice(&m[m.len() - wire::CRC_LEN..]);
                wire::check_crc(body, &t).is_ok()
                    && u32::from_le_bytes([m[13], m[14], m[15], m[16]]) as usize != 40
            })
            .count();
        assert_eq!(hostile, 4, "all four length overwrites land on 13..17");
    }

    #[test]
    fn verdict_fault_schedule_is_garbage_only() {
        use crate::net::wire;

        let faults = verdict_faults();
        assert!(faults.len() >= 4, "garbage, truncated, and reset variants");
        for f in &faults {
            if let VerdictFault::Garbage(b) = f {
                assert!(
                    *b != wire::ACK && *b != wire::NACK && *b != wire::BUSY,
                    "0x{b:02X} is a legitimate verdict, not garbage"
                );
            }
        }
    }

    #[test]
    fn corruptor_is_deterministic_and_always_changes_something() {
        let frame: Vec<u8> = (0..64u8).collect();
        let mut a = Corruptor::new(7);
        let mut b = Corruptor::new(7);
        for _ in 0..50 {
            let ca = a.corrupt(&frame);
            assert_eq!(ca, b.corrupt(&frame), "same seed must reproduce");
            assert_ne!(ca, frame, "corruption must alter the frame");
        }
    }
}
