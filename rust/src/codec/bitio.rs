//! Plain MSB-first bit I/O (used by tests and the container; the entropy
//! coders use the range coder in `rc.rs` instead).
//!
//! The reader keeps zero-padding semantics past the end of the buffer
//! (writers pad the final byte with zeros, so decoders must tolerate a
//! few phantom zero bits) but records the fact via [`BitReader::past_end`]
//! so callers can distinguish a clean tail from a truncated stream and
//! return [`crate::codec::Error::Truncated`].

/// MSB-first bit writer.
#[derive(Debug, Default)]
pub struct BitWriter {
    buf: Vec<u8>,
    cur: u8,
    nbits: u8,
}

impl BitWriter {
    pub fn new() -> Self {
        Self::default()
    }

    /// Like [`BitWriter::new`] but writing into a recycled buffer: `buf`
    /// is cleared and its capacity reused, so steady-state packing does
    /// not allocate (see [`crate::codec::scratch`]).
    pub fn with_buffer(mut buf: Vec<u8>) -> Self {
        buf.clear();
        Self { buf, cur: 0, nbits: 0 }
    }

    #[inline]
    pub fn put_bit(&mut self, bit: bool) {
        self.cur = (self.cur << 1) | bit as u8;
        self.nbits += 1;
        if self.nbits == 8 {
            self.buf.push(self.cur);
            self.cur = 0;
            self.nbits = 0;
        }
    }

    /// Write the low `n` bits of `v`, MSB first.
    pub fn put_bits(&mut self, v: u32, n: u8) {
        debug_assert!(n <= 32);
        for i in (0..n).rev() {
            self.put_bit((v >> i) & 1 == 1);
        }
    }

    /// Pad with zeros to a byte boundary and return the buffer.
    pub fn finish(mut self) -> Vec<u8> {
        if self.nbits > 0 {
            self.cur <<= 8 - self.nbits;
            self.buf.push(self.cur);
        }
        self.buf
    }

    pub fn bit_len(&self) -> usize {
        self.buf.len() * 8 + self.nbits as usize
    }
}

/// MSB-first bit reader.
#[derive(Debug)]
pub struct BitReader<'a> {
    buf: &'a [u8],
    pos: usize,
    cur: u8,
    nbits: u8,
    past_end: bool,
}

impl<'a> BitReader<'a> {
    pub fn new(buf: &'a [u8]) -> Self {
        Self { buf, pos: 0, cur: 0, nbits: 0, past_end: false }
    }

    /// Read one bit; returns false past the end (zero padding semantics)
    /// and latches [`Self::past_end`].
    #[inline]
    pub fn get_bit(&mut self) -> bool {
        if self.nbits == 0 {
            match self.buf.get(self.pos) {
                Some(&b) => self.cur = b,
                None => {
                    self.cur = 0;
                    self.past_end = true;
                }
            }
            self.pos += 1;
            self.nbits = 8;
        }
        self.nbits -= 1;
        (self.cur >> self.nbits) & 1 == 1
    }

    pub fn get_bits(&mut self, n: u8) -> u32 {
        let mut v = 0u32;
        for _ in 0..n {
            v = (v << 1) | self.get_bit() as u32;
        }
        v
    }

    /// True once any read has consumed a byte beyond the buffer. A valid
    /// stream never trips this: writers emit whole (zero-padded) bytes,
    /// so every real bit lives inside the buffer.
    #[inline]
    pub fn past_end(&self) -> bool {
        self.past_end
    }

    /// Byte offset the reader has fetched up to (may exceed `byte_len`
    /// once past the end).
    pub fn byte_pos(&self) -> usize {
        self.pos
    }

    pub fn byte_len(&self) -> usize {
        self.buf.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::SplitMix64;

    #[test]
    fn roundtrip_random_bit_patterns() {
        let mut r = SplitMix64::new(3);
        let values: Vec<(u32, u8)> =
            (0..500).map(|_| {
                let n = (r.next_u64() % 24 + 1) as u8;
                ((r.next_u64() as u32) & ((1u32 << n) - 1), n)
            }).collect();
        let mut w = BitWriter::new();
        for &(v, n) in &values {
            w.put_bits(v, n);
        }
        let bytes = w.finish();
        let mut rd = BitReader::new(&bytes);
        for &(v, n) in &values {
            assert_eq!(rd.get_bits(n), v);
        }
        assert!(!rd.past_end(), "valid stream must not read past end");
    }

    #[test]
    fn bit_len_counts_partial_bytes() {
        let mut w = BitWriter::new();
        w.put_bits(0b101, 3);
        assert_eq!(w.bit_len(), 3);
        w.put_bits(0xffff, 16);
        assert_eq!(w.bit_len(), 19);
        assert_eq!(w.finish().len(), 3);
    }

    #[test]
    fn reading_past_end_returns_zero() {
        let mut rd = BitReader::new(&[0xff]);
        assert_eq!(rd.get_bits(8), 0xff);
        assert!(!rd.past_end());
        assert_eq!(rd.get_bits(8), 0);
        assert!(rd.past_end(), "overrun must be latched");
    }
}
