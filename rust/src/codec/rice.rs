//! Golomb–Rice coding with adaptive parameter estimation.
//!
//! Used by TLC-IC's fast path for near-geometric residual distributions
//! (and independently testable as a baseline entropy coder). Residuals
//! are zigzag-mapped to unsigned, then coded as quotient (unary) +
//! remainder (k raw bits); k tracks the running mean per context, the
//! JPEG-LS style `A/N` estimator.

use super::bitio::{BitReader, BitWriter};
use super::{Error, Result};

/// Map a signed residual to unsigned (zigzag): 0,-1,1,-2,2 -> 0,1,2,3,4.
#[inline]
pub fn zigzag(v: i32) -> u32 {
    ((v << 1) ^ (v >> 31)) as u32
}

/// Inverse zigzag.
#[inline]
pub fn unzigzag(u: u32) -> i32 {
    ((u >> 1) as i32) ^ -((u & 1) as i32)
}

/// JPEG-LS style adaptive Rice parameter state for one context.
#[derive(Debug, Clone)]
pub struct RiceState {
    /// Sum of coded magnitudes.
    a: u64,
    /// Number of coded symbols.
    n: u64,
}

impl Default for RiceState {
    fn default() -> Self {
        // start at k ~ 2 to avoid pathological unary runs early on
        RiceState { a: 4, n: 1 }
    }
}

impl RiceState {
    /// Current Rice parameter: smallest k with N << k >= A.
    #[inline]
    pub fn k(&self) -> u32 {
        let mut k = 0;
        while (self.n << k) < self.a && k < 24 {
            k += 1;
        }
        k
    }

    #[inline]
    fn update(&mut self, u: u32) {
        self.a += u as u64;
        self.n += 1;
        // periodic halving keeps the estimator adaptive (JPEG-LS reset)
        if self.n >= 64 {
            self.a >>= 1;
            self.n >>= 1;
        }
    }
}

/// Encode one value with the state's current k, then update the state.
pub fn encode(w: &mut BitWriter, st: &mut RiceState, u: u32) {
    let k = st.k();
    let q = u >> k;
    const ESCAPE: u32 = 24;
    if q < ESCAPE {
        for _ in 0..q {
            w.put_bit(true);
        }
        w.put_bit(false);
        if k > 0 {
            w.put_bits(u & ((1 << k) - 1), k as u8);
        }
    } else {
        // escape: 24 ones then the raw 32-bit value
        for _ in 0..ESCAPE {
            w.put_bit(true);
        }
        w.put_bit(false);
        w.put_bits(u, 32);
    }
    st.update(u);
}

/// Decode one value and update the state (must mirror `encode`).
///
/// Returns [`Error::Truncated`] if the stream ran out mid-symbol; never
/// panics. Valid streams end on a byte boundary (the writer zero-pads),
/// so a clean decode never reads past the buffer.
pub fn decode(r: &mut BitReader, st: &mut RiceState) -> Result<u32> {
    let k = st.k();
    const ESCAPE: u32 = 24;
    let mut q = 0u32;
    while r.get_bit() {
        q += 1;
        if q == ESCAPE {
            break;
        }
    }
    let u = if q == ESCAPE {
        // consume the terminating 0 of the escape marker, then raw value
        // (encode wrote ESCAPE ones + one zero + 32 bits)
        let _ = r.get_bit();
        r.get_bits(32)
    } else if k > 0 {
        (q << k) | r.get_bits(k as u8)
    } else {
        q
    };
    if r.past_end() {
        return Err(Error::Truncated {
            what: "rice-coded stream",
            needed: r.byte_pos(),
            got: r.byte_len(),
        });
    }
    st.update(u);
    Ok(u)
}

/// Encode a block of values with one shared adaptive state — the
/// stripe-sized unit of work in the parallel codec path (each stripe
/// owns its own writer and state, so blocks are re-entrant by
/// construction).
pub fn encode_block(w: &mut BitWriter, st: &mut RiceState, vals: &[u32]) {
    for &u in vals {
        encode(w, st, u);
    }
}

/// Decode a block into a caller-owned slice (mirrors [`encode_block`]).
pub fn decode_block_into(r: &mut BitReader, st: &mut RiceState, out: &mut [u32]) -> Result<()> {
    for slot in out.iter_mut() {
        *slot = decode(r, st)?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    #![allow(clippy::unwrap_used, clippy::expect_used)]

    use super::*;
    use crate::util::SplitMix64;

    #[test]
    fn zigzag_bijection() {
        for v in -1000..=1000 {
            assert_eq!(unzigzag(zigzag(v)), v);
        }
        assert_eq!(zigzag(0), 0);
        assert_eq!(zigzag(-1), 1);
        assert_eq!(zigzag(1), 2);
    }

    #[test]
    fn roundtrip_geometric_residuals() {
        let mut r = SplitMix64::new(11);
        // geometric-ish: product of uniforms
        let vals: Vec<u32> = (0..20_000)
            .map(|_| (r.next_f64() * r.next_f64() * 60.0) as u32)
            .collect();
        let mut w = BitWriter::new();
        let mut st = RiceState::default();
        for &v in &vals {
            encode(&mut w, &mut st, v);
        }
        let bytes = w.finish();
        let mut rd = BitReader::new(&bytes);
        let mut st = RiceState::default();
        for &v in &vals {
            assert_eq!(decode(&mut rd, &mut st).unwrap(), v);
        }
        // should beat raw 6-bit packing on this skewed source
        assert!(bytes.len() * 8 < vals.len() * 6, "{} bits", bytes.len() * 8);
    }

    #[test]
    fn truncation_yields_error_not_garbage() {
        let vals = [700u32, 900, 12, 65_000, 3];
        let mut w = BitWriter::new();
        let mut st = RiceState::default();
        for &v in &vals {
            encode(&mut w, &mut st, v);
        }
        let bytes = w.finish();
        // cut the stream short: some symbol must report Truncated
        let cut = &bytes[..bytes.len() / 2];
        let mut rd = BitReader::new(cut);
        let mut st = RiceState::default();
        let mut saw_err = false;
        for _ in &vals {
            if decode(&mut rd, &mut st).is_err() {
                saw_err = true;
                break;
            }
        }
        assert!(saw_err, "truncated stream decoded without error");
    }

    #[test]
    fn escape_path_handles_outliers() {
        let vals = [0u32, 1, 2, u32::MAX, 5, 1_000_000, 0];
        let mut w = BitWriter::new();
        let mut st = RiceState::default();
        for &v in &vals {
            encode(&mut w, &mut st, v);
        }
        let bytes = w.finish();
        let mut rd = BitReader::new(&bytes);
        let mut st = RiceState::default();
        for &v in &vals {
            assert_eq!(decode(&mut rd, &mut st).unwrap(), v);
        }
    }

    #[test]
    fn block_helpers_mirror_scalar_coding() {
        let mut r = SplitMix64::new(13);
        let vals: Vec<u32> = (0..2_000).map(|_| (r.next_u64() % 300) as u32).collect();
        // two independent blocks, each with its own state and writer —
        // exactly the per-stripe re-entrancy the parallel path relies on
        for chunk in vals.chunks(700) {
            let mut w = BitWriter::new();
            let mut st = RiceState::default();
            encode_block(&mut w, &mut st, chunk);
            let bytes = w.finish();
            let mut rd = BitReader::new(&bytes);
            let mut st = RiceState::default();
            let mut out = vec![0u32; chunk.len()];
            decode_block_into(&mut rd, &mut st, &mut out).unwrap();
            assert_eq!(out, chunk);
        }
    }

    #[test]
    fn k_tracks_magnitude() {
        let mut st = RiceState::default();
        for _ in 0..100 {
            st.update(1000);
        }
        assert!(st.k() >= 8, "k = {}", st.k());
        let mut st2 = RiceState::default();
        for _ in 0..100 {
            st2.update(0);
        }
        assert_eq!(st2.k(), 0);
    }
}
