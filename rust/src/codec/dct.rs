//! 8x8 orthonormal DCT-II / DCT-III for the lossy codec (MIC).
//!
//! Separable float implementation with precomputed basis; exactness
//! matters less than symmetry (encode and decode must use the same
//! basis), but the pair is inverse to ~1e-4 over the full dynamic range,
//! far below one quantizer step at any usable QP.

use once_cell::sync::Lazy;

pub const N: usize = 8;

/// cos basis[k][x] = c(k) * cos((2x+1) k pi / 16), c(0)=sqrt(1/8), else sqrt(2/8)
static BASIS: Lazy<[[f32; N]; N]> = Lazy::new(|| {
    let mut b = [[0f32; N]; N];
    for (k, row) in b.iter_mut().enumerate() {
        let ck = if k == 0 { (1.0 / N as f64).sqrt() } else { (2.0 / N as f64).sqrt() };
        for (x, v) in row.iter_mut().enumerate() {
            *v = (ck
                * ((2.0 * x as f64 + 1.0) * k as f64 * std::f64::consts::PI
                    / (2.0 * N as f64))
                    .cos()) as f32;
        }
    }
    b
});

/// Forward 2-D DCT of an 8x8 block (row-major).
pub fn forward(block: &[f32; N * N]) -> [f32; N * N] {
    let b = &*BASIS;
    let mut tmp = [0f32; N * N];
    // rows
    for y in 0..N {
        for k in 0..N {
            let mut acc = 0f32;
            for x in 0..N {
                acc += block[y * N + x] * b[k][x];
            }
            tmp[y * N + k] = acc;
        }
    }
    // cols
    let mut out = [0f32; N * N];
    for k in 0..N {
        for u in 0..N {
            let mut acc = 0f32;
            for y in 0..N {
                acc += tmp[y * N + u] * b[k][y];
            }
            out[k * N + u] = acc;
        }
    }
    out
}

/// Inverse 2-D DCT of an 8x8 coefficient block.
pub fn inverse(coef: &[f32; N * N]) -> [f32; N * N] {
    let b = &*BASIS;
    let mut tmp = [0f32; N * N];
    // cols (transpose of forward)
    for y in 0..N {
        for u in 0..N {
            let mut acc = 0f32;
            for k in 0..N {
                acc += coef[k * N + u] * b[k][y];
            }
            tmp[y * N + u] = acc;
        }
    }
    let mut out = [0f32; N * N];
    for y in 0..N {
        for x in 0..N {
            let mut acc = 0f32;
            for k in 0..N {
                acc += tmp[y * N + k] * b[k][x];
            }
            out[y * N + x] = acc;
        }
    }
    out
}

/// JPEG-style zigzag scan order for an 8x8 block.
pub static ZIGZAG: [usize; 64] = [
    0, 1, 8, 16, 9, 2, 3, 10, 17, 24, 32, 25, 18, 11, 4, 5, 12, 19, 26, 33, 40,
    48, 41, 34, 27, 20, 13, 6, 7, 14, 21, 28, 35, 42, 49, 56, 57, 50, 43, 36,
    29, 22, 15, 23, 30, 37, 44, 51, 58, 59, 52, 45, 38, 31, 39, 46, 53, 60, 61,
    54, 47, 55, 62, 63,
];

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::SplitMix64;

    #[test]
    fn inverse_of_forward_is_identity() {
        let mut r = SplitMix64::new(6);
        for _ in 0..20 {
            let mut block = [0f32; 64];
            for v in &mut block {
                *v = r.next_f32() * 510.0 - 255.0;
            }
            let rec = inverse(&forward(&block));
            for (a, b) in block.iter().zip(&rec) {
                assert!((a - b).abs() < 1e-3, "{a} vs {b}");
            }
        }
    }

    #[test]
    fn dc_is_mean_times_8() {
        let block = [32.0f32; 64];
        let coef = forward(&block);
        assert!((coef[0] - 32.0 * 8.0).abs() < 1e-3);
        for &c in &coef[1..] {
            assert!(c.abs() < 1e-3);
        }
    }

    #[test]
    fn parseval_energy_preserved() {
        let mut r = SplitMix64::new(8);
        let mut block = [0f32; 64];
        for v in &mut block {
            *v = r.next_f32() * 100.0;
        }
        let coef = forward(&block);
        let e1: f32 = block.iter().map(|v| v * v).sum();
        let e2: f32 = coef.iter().map(|v| v * v).sum();
        assert!((e1 - e2).abs() / e1 < 1e-4);
    }

    #[test]
    fn zigzag_is_permutation() {
        let mut seen = [false; 64];
        for &i in &ZIGZAG {
            assert!(!seen[i]);
            seen[i] = true;
        }
        assert_eq!(ZIGZAG[0], 0);
        assert_eq!(ZIGZAG[63], 63);
    }
}
