//! zstd baseline: bit-pack the samples, then zstd. A general-purpose
//! compressor reference point for the lossless comparison (E4) — the
//! kind of "just gzip the tensor" baseline the lossless-coding paper [5]
//! compares against.

use super::bitio::{BitReader, BitWriter};
use super::scratch::ScratchPool;
use super::{Error, ImageMeta, Result};

/// Bit-pack to ceil(n) bits/sample, then zstd level 19.
pub fn encode(samples: &[u16], width: usize, height: usize, n: u8) -> Vec<u8> {
    let scratch = ScratchPool::new();
    let mut out = Vec::new();
    encode_into(samples, width, height, n, &scratch, &mut out);
    out
}

/// Re-entrant [`encode`]: the bit-packed intermediate comes from
/// `scratch` and goes back when done; the compressed stream lands in
/// `out`. zstd's context and output buffer are its own allocations —
/// documented exception to the zero-alloc claim (see `codec::scratch`).
// baf-lint: allow(panic-macro) -- encoder contract (ROADMAP): trusted in-memory zstd compress, a failure is a bug, not an input
pub fn encode_into(
    samples: &[u16],
    _width: usize,
    _height: usize,
    n: u8,
    scratch: &ScratchPool,
    out: &mut Vec<u8>,
) {
    let packed_cap = (samples.len() * n as usize).div_ceil(8);
    let mut w = BitWriter::with_buffer(scratch.take_u8(packed_cap));
    for &s in samples {
        w.put_bits(s as u32, n);
    }
    let packed = w.finish();
    // in-memory compression of a sane buffer cannot fail; a failure here
    // is a programming error, not an input error
    let compressed = match zstd::bulk::compress(&packed, 19) {
        Ok(c) => c,
        Err(e) => panic!("zstd compress failed: {e}"),
    };
    scratch.put_u8(packed);
    out.clear();
    out.extend_from_slice(&compressed);
}

/// Inverse of `encode`.
///
/// Total: the decompression capacity is bounded by the validated
/// geometry (so a zstd bomb cannot over-allocate), malformed frames map
/// to [`Error::Corrupt`], and short unpacked payloads to
/// [`Error::Truncated`].
pub fn decode(bytes: &[u8], meta: &ImageMeta) -> Result<Vec<u16>> {
    let count = meta.checked_samples()?;
    let mut samples = vec![0u16; count];
    decode_into(bytes, meta, &mut samples)?;
    Ok(samples)
}

/// Re-entrant [`decode`]: writes into a caller-owned slice of exactly
/// `width * height` samples (a mismatch is [`Error::Corrupt`]).
pub fn decode_into(bytes: &[u8], meta: &ImageMeta, samples: &mut [u16]) -> Result<()> {
    let count = meta.checked_samples()?;
    if samples.len() != count {
        return Err(Error::Corrupt(format!(
            "zstd output slice is {} samples, geometry says {count}",
            samples.len()
        )));
    }
    let packed_len = count
        .checked_mul(meta.n as usize)
        .ok_or_else(|| Error::Corrupt("zstd packed size overflow".into()))?
        .div_ceil(8);
    // `decompress` caps its output at `packed_len` bytes; an over-long
    // stream errors inside zstd rather than growing the buffer
    let raw = zstd::bulk::decompress(bytes, packed_len)
        .map_err(|e| Error::Corrupt(format!("zstd decompress failed: {e}")))?;
    if raw.len() < packed_len {
        return Err(Error::Truncated {
            what: "zstd packed payload",
            needed: packed_len,
            got: raw.len(),
        });
    }
    let mut r = BitReader::new(&raw);
    for s in samples.iter_mut() {
        *s = r.get_bits(meta.n) as u16;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    #![allow(clippy::unwrap_used, clippy::expect_used)]

    use super::*;
    use crate::util::SplitMix64;

    #[test]
    fn roundtrip_various_depths() {
        let mut r = SplitMix64::new(31);
        for n in [1u8, 2, 5, 8, 11, 16] {
            let mask = (1u32 << n) - 1;
            let samples: Vec<u16> =
                (0..50 * 20).map(|_| (r.next_u64() as u32 & mask) as u16).collect();
            let bytes = encode(&samples, 50, 20, n);
            let meta = ImageMeta { width: 50, height: 20, n };
            assert_eq!(decode(&bytes, &meta).unwrap(), samples, "n={n}");
        }
    }

    #[test]
    fn repetitive_data_compresses() {
        let samples: Vec<u16> = (0..64 * 64).map(|i| (i % 7) as u16).collect();
        let bytes = encode(&samples, 64, 64, 8);
        assert!(bytes.len() < 300);
    }

    #[test]
    fn garbage_and_truncation_are_rejected() {
        let samples: Vec<u16> = (0..32 * 32).map(|i| (i & 63) as u16).collect();
        let bytes = encode(&samples, 32, 32, 6);
        let meta = ImageMeta { width: 32, height: 32, n: 6 };
        assert!(decode(&[], &meta).is_err());
        assert!(decode(&[1, 2, 3, 4, 5], &meta).is_err());
        assert!(decode(&bytes[..bytes.len() - 1], &meta).is_err());
        // frame that decompresses smaller than the geometry requires
        let tiny = ImageMeta { width: 64, height: 64, n: 6 };
        assert!(decode(&bytes, &tiny).is_err());
    }
}
