//! zstd baseline: bit-pack the samples, then zstd. A general-purpose
//! compressor reference point for the lossless comparison (E4) — the
//! kind of "just gzip the tensor" baseline the lossless-coding paper [5]
//! compares against.

use super::bitio::{BitReader, BitWriter};
use super::ImageMeta;

/// Bit-pack to ceil(n) bits/sample, then zstd level 19.
pub fn encode(samples: &[u16], _width: usize, _height: usize, n: u8) -> Vec<u8> {
    let mut w = BitWriter::new();
    for &s in samples {
        w.put_bits(s as u32, n);
    }
    zstd::bulk::compress(&w.finish(), 19).expect("zstd compress")
}

/// Inverse of `encode`.
pub fn decode(bytes: &[u8], meta: &ImageMeta) -> Vec<u16> {
    let count = meta.width * meta.height;
    let packed_len = (count * meta.n as usize).div_ceil(8);
    let raw = zstd::bulk::decompress(bytes, packed_len).expect("zstd decompress");
    let mut r = BitReader::new(&raw);
    (0..count).map(|_| r.get_bits(meta.n) as u16).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::SplitMix64;

    #[test]
    fn roundtrip_various_depths() {
        let mut r = SplitMix64::new(31);
        for n in [2u8, 5, 8, 11, 16] {
            let mask = (1u32 << n) - 1;
            let samples: Vec<u16> =
                (0..50 * 20).map(|_| (r.next_u64() as u32 & mask) as u16).collect();
            let bytes = encode(&samples, 50, 20, n);
            let meta = ImageMeta { width: 50, height: 20, n };
            assert_eq!(decode(&bytes, &meta), samples, "n={n}");
        }
    }

    #[test]
    fn repetitive_data_compresses() {
        let samples: Vec<u16> = (0..64 * 64).map(|i| (i % 7) as u16).collect();
        let bytes = encode(&samples, 64, 64, 8);
        assert!(bytes.len() < 300);
    }
}
