//! MIC — Mini Intra Codec, the HEVC-intra stand-in (DESIGN.md §2).
//!
//! Transform-codes a single-plane image of n-bit samples: 8x8 blocks,
//! orthonormal DCT, HEVC-style quantizer step `Qstep = 2^((QP-4)/6)`
//! (scaled to bit depth), zigzag scan, and a context-coded symbol stream
//! (DC predicted from the previous block; per-band zero/sign/magnitude
//! models) through the range coder.
//!
//! Used for the paper's two lossy curves: the [4] baseline that codes
//! ALL channels at 8 bits over a QP sweep, and the "quantize to 6 bits
//! then lossy-code" variant (Fig. 4, purple).

use super::dct::{self, ZIGZAG};
use super::rc::{BitModel, BitTree, Decoder, Encoder};
use super::{Error, ImageMeta, Result};

/// Frequency band of a zigzag position (context grouping for AC models).
#[inline]
fn band(pos: usize) -> usize {
    match pos {
        1..=5 => 0,
        6..=20 => 1,
        _ => 2,
    }
}

/// HEVC-style quantizer step for a QP, normalized so that QP has the
/// same *relative* meaning at any bit depth (QP 0 ~ near-lossless at 8
/// bits).
pub fn qstep(qp: u8, n: u8) -> f32 {
    let base = 2f32.powf((qp as f32 - 4.0) / 6.0);
    // scale with dynamic range relative to 8-bit
    base * 2f32.powi(n as i32 - 8)
}

struct Models {
    dc: BitTree,           // DC residual magnitude class
    dc_sign: BitModel,
    last: BitTree,         // index of last significant coefficient
    zero: [BitModel; 3],   // per-band significance
    sign: [BitModel; 3],
    exp: [[BitModel; 14]; 3],
}

impl Models {
    fn new() -> Self {
        Models {
            dc: BitTree::new(5),
            dc_sign: BitModel::default(),
            last: BitTree::new(7),
            zero: [BitModel::default(); 3],
            sign: [BitModel::default(); 3],
            exp: [[BitModel::default(); 14]; 3],
        }
    }
}

fn encode_mag(enc: &mut Encoder, exp: &mut [BitModel; 14], mag: u32) {
    debug_assert!(mag >= 1);
    let k = (31 - mag.leading_zeros()).min(13);
    for i in 0..k {
        enc.encode(&mut exp[i as usize], 1);
    }
    if k < 13 {
        enc.encode(&mut exp[k as usize], 0);
    }
    if k > 0 {
        enc.encode_direct(mag & ((1 << k) - 1), k);
    }
}

fn decode_mag(dec: &mut Decoder, exp: &mut [BitModel; 14]) -> u32 {
    let mut k = 0u32;
    while k < 13 && dec.decode(&mut exp[k as usize]) == 1 {
        k += 1;
    }
    let mantissa = if k > 0 { dec.decode_direct(k) } else { 0 };
    (1 << k) | mantissa
}

/// Encode. Returns the bitstream; decoding requires the same (w, h, n, qp).
pub fn encode(samples: &[u16], width: usize, height: usize, n: u8, qp: u8) -> Vec<u8> {
    assert_eq!(samples.len(), width * height);
    let bw = width.div_ceil(8);
    let bh = height.div_ceil(8);
    let step = qstep(qp, n);
    let center = (1i32 << (n - 1)) as f32;
    let mut enc = Encoder::new();
    let mut m = Models::new();
    let mut prev_dc = 0i32;
    for by in 0..bh {
        for bx in 0..bw {
            // gather block with edge replication
            let mut block = [0f32; 64];
            for y in 0..8 {
                for x in 0..8 {
                    let sy = (by * 8 + y).min(height - 1);
                    let sx = (bx * 8 + x).min(width - 1);
                    block[y * 8 + x] = samples[sy * width + sx] as f32 - center;
                }
            }
            let coef = dct::forward(&block);
            // quantize
            let mut q = [0i32; 64];
            for (i, &c) in coef.iter().enumerate() {
                q[i] = (c / step).round() as i32;
            }
            // DC: differential vs previous block
            let ddc = q[0] - prev_dc;
            prev_dc = q[0];
            let (dsign, dmag) = (ddc < 0, ddc.unsigned_abs());
            if dmag == 0 {
                m.dc.encode(&mut enc, 0);
            } else {
                let k = (32 - dmag.leading_zeros()).min(31); // 1..=31 -> class
                m.dc.encode(&mut enc, k);
                enc.encode(&mut m.dc_sign, dsign as u32);
                if k > 1 {
                    enc.encode_direct(dmag & ((1 << (k - 1)) - 1), k - 1);
                }
            }
            // AC: last significant position in zigzag order
            let mut last = 0usize;
            for pos in (1..64).rev() {
                if q[ZIGZAG[pos]] != 0 {
                    last = pos;
                    break;
                }
            }
            m.last.encode(&mut enc, last as u32);
            for pos in 1..=last {
                let v = q[ZIGZAG[pos]];
                let b = band(pos);
                if v == 0 {
                    enc.encode(&mut m.zero[b], 0);
                    continue;
                }
                enc.encode(&mut m.zero[b], 1);
                enc.encode(&mut m.sign[b], (v < 0) as u32);
                encode_mag(&mut enc, &mut m.exp[b], v.unsigned_abs());
            }
        }
    }
    enc.finish()
}

/// Decode back to (lossy) samples.
///
/// Total: the `last`-position symbol is validated against the 64-entry
/// zigzag table (the bit tree is 7 bits wide, so corrupt streams can
/// produce 64..127), DC accumulation saturates instead of wrapping, and
/// truncation surfaces via the range decoder's overrun counter.
// baf-lint: allow(raw-index) -- 8x8 block tables: pos<=last<64 indexes ZIGZAG/q/coef (all 64-long), sy<height/sx<width guard the plane write
pub fn decode(bytes: &[u8], meta: &ImageMeta, qp: u8) -> Result<Vec<u16>> {
    let samples_len = meta.checked_samples()?;
    let (width, height, n) = (meta.width, meta.height, meta.n);
    let bw = width.div_ceil(8);
    let bh = height.div_ceil(8);
    let step = qstep(qp, n);
    let center = (1i32 << (n - 1)) as f32;
    let maxv = (1i32 << n) - 1;
    let mut dec = Decoder::new(bytes);
    let mut m = Models::new();
    let mut out = vec![0u16; samples_len];
    let mut prev_dc = 0i32;
    for by in 0..bh {
        for bx in 0..bw {
            let mut q = [0i32; 64];
            // DC
            let k = m.dc.decode(&mut dec);
            let ddc = if k == 0 {
                0
            } else {
                let neg = dec.decode(&mut m.dc_sign) == 1;
                let mag = if k > 1 {
                    (1u32 << (k - 1)) | dec.decode_direct(k - 1)
                } else {
                    1
                };
                if neg {
                    -(mag as i32)
                } else {
                    mag as i32
                }
            };
            // saturate: a corrupt stream can feed extreme deltas forever
            prev_dc = prev_dc.saturating_add(ddc);
            q[0] = prev_dc;
            // AC
            let last = m.last.decode(&mut dec) as usize;
            if last >= 64 {
                return Err(Error::Corrupt(format!(
                    "last-coefficient index {last} outside 8x8 block"
                )));
            }
            for pos in 1..=last {
                let b = band(pos);
                if dec.decode(&mut m.zero[b]) == 0 {
                    continue;
                }
                let neg = dec.decode(&mut m.sign[b]) == 1;
                let mag = decode_mag(&mut dec, &mut m.exp[b]) as i32;
                q[ZIGZAG[pos]] = if neg { -mag } else { mag };
            }
            // reconstruct
            let mut coef = [0f32; 64];
            for i in 0..64 {
                coef[i] = q[i] as f32 * step;
            }
            let block = dct::inverse(&coef);
            for y in 0..8 {
                for x in 0..8 {
                    let sy = by * 8 + y;
                    let sx = bx * 8 + x;
                    if sy < height && sx < width {
                        let v = (block[y * 8 + x] + center).round() as i32;
                        out[sy * width + sx] = v.clamp(0, maxv) as u16;
                    }
                }
            }
        }
    }
    if dec.overrun() > 0 {
        return Err(Error::Truncated {
            what: "mic range-coded stream",
            needed: dec.byte_pos(),
            got: dec.byte_len(),
        });
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    #![allow(clippy::unwrap_used, clippy::expect_used)]

    use super::*;
    use crate::util::SplitMix64;

    fn psnr(a: &[u16], b: &[u16], n: u8) -> f64 {
        let peak = ((1u32 << n) - 1) as f64;
        let mse: f64 = a
            .iter()
            .zip(b)
            .map(|(&x, &y)| {
                let d = x as f64 - y as f64;
                d * d
            })
            .sum::<f64>()
            / a.len() as f64;
        if mse == 0.0 {
            f64::INFINITY
        } else {
            10.0 * (peak * peak / mse).log10()
        }
    }

    fn smooth_image(w: usize, h: usize, n: u8, seed: u64) -> Vec<u16> {
        let mut r = SplitMix64::new(seed);
        let maxv = (1u32 << n) - 1;
        (0..w * h)
            .map(|i| {
                let x = (i % w) as f32 / w as f32;
                let y = (i / w) as f32 / h as f32;
                let v = (0.5 + 0.3 * (6.0 * x).sin() * (4.0 * y).cos()
                    + 0.05 * (r.next_f32() - 0.5)) as f32;
                ((v.clamp(0.0, 1.0)) * maxv as f32) as u16
            })
            .collect()
    }

    #[test]
    fn quality_degrades_with_qp_and_rate_shrinks() {
        let img = smooth_image(64, 64, 8, 1);
        let meta = ImageMeta { width: 64, height: 64, n: 8 };
        let mut prev_size = usize::MAX;
        let mut prev_psnr = f64::INFINITY;
        for qp in [4u8, 16, 28, 40] {
            let bytes = encode(&img, 64, 64, 8, qp);
            let rec = decode(&bytes, &meta, qp).unwrap();
            let p = psnr(&img, &rec, 8);
            assert!(bytes.len() < prev_size, "rate must shrink with QP");
            assert!(p <= prev_psnr + 0.5, "psnr must not improve with QP");
            prev_size = bytes.len();
            prev_psnr = p;
        }
    }

    #[test]
    fn low_qp_is_near_lossless() {
        let img = smooth_image(48, 40, 8, 3);
        let meta = ImageMeta { width: 48, height: 40, n: 8 };
        let bytes = encode(&img, 48, 40, 8, 0);
        let rec = decode(&bytes, &meta, 0).unwrap();
        assert!(psnr(&img, &rec, 8) > 48.0);
    }

    #[test]
    fn non_multiple_of_8_dimensions() {
        let img = smooth_image(37, 29, 8, 9);
        let meta = ImageMeta { width: 37, height: 29, n: 8 };
        let bytes = encode(&img, 37, 29, 8, 12);
        let rec = decode(&bytes, &meta, 12).unwrap();
        assert_eq!(rec.len(), 37 * 29);
        assert!(psnr(&img, &rec, 8) > 25.0);
    }

    #[test]
    fn works_at_low_bit_depth() {
        let img = smooth_image(32, 32, 6, 4);
        let meta = ImageMeta { width: 32, height: 32, n: 6 };
        for qp in [0u8, 10, 20] {
            let bytes = encode(&img, 32, 32, 6, qp);
            let rec = decode(&bytes, &meta, qp).unwrap();
            assert!(rec.iter().all(|&v| v < 64));
            assert!(psnr(&img, &rec, 6) > 20.0, "qp={qp}");
        }
    }
}
