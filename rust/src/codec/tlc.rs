//! TLC — Tensor Lossless Codec, the FLIF stand-in (DESIGN.md §2).
//!
//! A context-adaptive lossless coder for single-plane images of 2..16-bit
//! samples (the tiled quantized tensors of §3.2). Pipeline per sample:
//!
//!   1. MED prediction from the causal neighborhood (left/top/top-left);
//!   2. gradient-activity context selection (8 buckets);
//!   3. residual coded as zero-flag + sign + adaptive Elias-gamma
//!      (unary exponent over per-context bit models, direct mantissa)
//!      through the binary range coder.
//!
//! Like FLIF, rate scales with the true sample precision: a 2-bit tensor
//! costs a fraction of an 8-bit one, which is exactly the property the
//! paper's Fig. 4 n-sweep depends on.

use super::predict::{activity_context, med, NUM_CONTEXTS};
use super::rc::{BitModel, Decoder, Encoder};
use super::{Error, ImageMeta, Result};

const MAX_EXP: usize = 17;

struct Models {
    zero: [BitModel; NUM_CONTEXTS],
    sign: [BitModel; NUM_CONTEXTS],
    exp: [[BitModel; MAX_EXP]; NUM_CONTEXTS],
}

impl Models {
    fn new() -> Self {
        Models {
            zero: [BitModel::default(); NUM_CONTEXTS],
            sign: [BitModel::default(); NUM_CONTEXTS],
            exp: [[BitModel::default(); MAX_EXP]; NUM_CONTEXTS],
        }
    }
}

#[inline]
fn neighborhood(samples: &[u16], width: usize, x: usize, y: usize, half: i32) -> (i32, i32, i32) {
    let at = |xx: usize, yy: usize| samples[yy * width + xx] as i32;
    match (x, y) {
        (0, 0) => (half, half, half),
        (_, 0) => {
            let a = at(x - 1, 0);
            (a, a, a)
        }
        (0, _) => {
            let b = at(0, y - 1);
            (b, b, b)
        }
        _ => (at(x - 1, y), at(x, y - 1), at(x - 1, y - 1)),
    }
}

#[inline(always)]
fn encode_residual(enc: &mut Encoder, models: &mut Models, ctx: usize, r: i32) {
    if r == 0 {
        enc.encode(&mut models.zero[ctx], 0);
        return;
    }
    enc.encode(&mut models.zero[ctx], 1);
    enc.encode(&mut models.sign[ctx], (r < 0) as u32);
    let mag = r.unsigned_abs(); // >= 1
    let k = 31 - mag.leading_zeros(); // floor(log2(mag))
    // unary exponent over adaptive models
    for i in 0..k {
        enc.encode(&mut models.exp[ctx][i as usize], 1);
    }
    enc.encode(&mut models.exp[ctx][k as usize], 0);
    // mantissa: the k bits below the leading 1
    if k > 0 {
        enc.encode_direct(mag & ((1 << k) - 1), k);
    }
}

/// Encode a single-plane image losslessly. `n` is the sample bit depth.
///
/// §Perf: the interior (x >= 1, y >= 1) runs a specialized loop that
/// reads the three causal neighbours from two hoisted row slices with no
/// border branches — the per-pixel `neighborhood` dispatch only runs on
/// the first row/column (~1.5% of a 128x128 plane). Measured on the
/// 128x128 micro-bench: ~15% encode speedup at n=4, within noise at n=8
/// (the adaptive range coder dominates there) — EXPERIMENTS.md §Perf.
pub fn encode(samples: &[u16], width: usize, height: usize, n: u8) -> Vec<u8> {
    let mut out = Vec::new();
    encode_into(samples, width, height, n, &mut out);
    out
}

/// Re-entrant [`encode`]: writes the stream into `out` (cleared first),
/// reusing its capacity so steady-state encoding does not allocate. The
/// stripe fan-out runs one of these per stripe on its own scratch buffer.
pub fn encode_into(samples: &[u16], width: usize, height: usize, n: u8, out: &mut Vec<u8>) {
    assert_eq!(samples.len(), width * height);
    let mut enc = Encoder::with_buffer(std::mem::take(out));
    let mut models = Models::new();
    let half = 1i32 << (n - 1);
    // first row (and the y=0 corner) via the general path
    for x in 0..width {
        let (a, b, c) = neighborhood(samples, width, x, 0, half);
        let ctx = activity_context(a, b, c, n);
        encode_residual(&mut enc, &mut models, ctx, samples[x] as i32 - med(a, b, c));
    }
    for y in 1..height {
        let (prev_row, cur_rows) = samples.split_at(y * width);
        let prev_row = &prev_row[(y - 1) * width..];
        let cur_row = &cur_rows[..width];
        // x = 0 border
        {
            let b0 = prev_row[0] as i32;
            let ctx = activity_context(b0, b0, b0, n);
            encode_residual(&mut enc, &mut models, ctx, cur_row[0] as i32 - b0);
        }
        // interior: branch-free neighbour fetch
        for x in 1..width {
            let a = cur_row[x - 1] as i32;
            let b = prev_row[x] as i32;
            let c = prev_row[x - 1] as i32;
            let ctx = activity_context(a, b, c, n);
            encode_residual(&mut enc, &mut models, ctx, cur_row[x] as i32 - med(a, b, c));
        }
    }
    *out = enc.finish();
}

/// Decode a TLC stream back to samples.
///
/// Total: corrupt bytes decode to clamped garbage (the range coder has no
/// internal redundancy — integrity is the container CRC's job) but
/// truncation is detected via the decoder's overrun counter, and no input
/// panics or allocates beyond the validated geometry.
pub fn decode(bytes: &[u8], meta: &ImageMeta) -> Result<Vec<u16>> {
    let samples_len = meta.checked_samples()?;
    let mut samples = vec![0u16; samples_len];
    decode_into(bytes, meta, &mut samples)?;
    Ok(samples)
}

/// Re-entrant [`decode`]: writes into a caller-owned slice of exactly
/// `meta.width * meta.height` samples (a mismatch is [`Error::Corrupt`],
/// keeping the total-decode contract — no panic on bad plumbing either).
// baf-lint: allow(raw-index) -- per-pixel prediction loop: x<width and y<height index the exactly-sized sample plane
pub fn decode_into(bytes: &[u8], meta: &ImageMeta, samples: &mut [u16]) -> Result<()> {
    let samples_len = meta.checked_samples()?;
    if samples.len() != samples_len {
        return Err(Error::Corrupt(format!(
            "tlc output slice is {} samples, geometry says {samples_len}",
            samples.len()
        )));
    }
    let (width, height, n) = (meta.width, meta.height, meta.n);
    let mut dec = Decoder::new(bytes);
    let mut models = Models::new();
    let half = 1i32 << (n - 1);
    let maxv = (1i32 << n) - 1;
    let mut decode_at = |dec: &mut Decoder,
                         models: &mut Models,
                         a: i32,
                         b: i32,
                         c: i32| {
        let pred = med(a, b, c);
        let ctx = activity_context(a, b, c, n);
        let v = if dec.decode(&mut models.zero[ctx]) == 0 {
            pred
        } else {
            let neg = dec.decode(&mut models.sign[ctx]) == 1;
            let mut k = 0usize;
            while k < MAX_EXP - 1 && dec.decode(&mut models.exp[ctx][k]) == 1 {
                k += 1;
            }
            let mantissa = if k > 0 { dec.decode_direct(k as u32) } else { 0 };
            let mag = ((1u32 << k) | mantissa) as i32;
            pred + if neg { -mag } else { mag }
        };
        // a valid stream always lands in range; clamp defends against
        // corrupt input without UB
        v.clamp(0, maxv) as u16
    };
    // first row via the general neighbourhood
    for x in 0..width {
        let (a, b, c) = neighborhood(&samples, width, x, 0, half);
        samples[x] = decode_at(&mut dec, &mut models, a, b, c);
    }
    // interior: mirror the encoder's specialized loop
    for y in 1..height {
        let b0 = samples[(y - 1) * width] as i32;
        samples[y * width] = decode_at(&mut dec, &mut models, b0, b0, b0);
        for x in 1..width {
            let a = samples[y * width + x - 1] as i32;
            let b = samples[(y - 1) * width + x] as i32;
            let c = samples[(y - 1) * width + x - 1] as i32;
            samples[y * width + x] = decode_at(&mut dec, &mut models, a, b, c);
        }
    }
    if dec.overrun() > 0 {
        return Err(Error::Truncated {
            what: "tlc range-coded stream",
            needed: dec.byte_pos(),
            got: dec.byte_len(),
        });
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    #![allow(clippy::unwrap_used, clippy::expect_used)]

    use super::*;
    use crate::util::SplitMix64;

    fn roundtrip(samples: &[u16], w: usize, h: usize, n: u8) -> usize {
        let bytes = encode(samples, w, h, n);
        let meta = ImageMeta { width: w, height: h, n };
        assert_eq!(decode(&bytes, &meta).unwrap(), samples, "w={w} h={h} n={n}");
        bytes.len()
    }

    #[test]
    fn roundtrip_random_all_depths() {
        let mut r = SplitMix64::new(10);
        for n in [1u8, 2, 3, 4, 6, 8, 10, 12, 16] {
            let mask = (1u32 << n) - 1;
            let samples: Vec<u16> =
                (0..64 * 48).map(|_| (r.next_u64() as u32 & mask) as u16).collect();
            roundtrip(&samples, 64, 48, n);
        }
    }

    #[test]
    fn smooth_images_compress_hard() {
        // gradient image: MED predicts perfectly except at boundaries
        let w = 128;
        let h = 64;
        let samples: Vec<u16> =
            (0..w * h).map(|i| (((i % w) + (i / w)) / 2) as u16).collect();
        let bytes = roundtrip(&samples, w, h, 8);
        assert!(bytes < w * h / 20, "smooth image: {} bytes for {} samples", bytes, w * h);
    }

    #[test]
    fn constant_image_is_tiny() {
        let samples = vec![37u16; 64 * 64];
        let bytes = roundtrip(&samples, 64, 64, 8);
        assert!(bytes < 64, "constant image took {bytes} bytes");
    }

    #[test]
    fn low_precision_costs_less_than_high() {
        // the FLIF property the paper relies on (Fig. 4): same signal,
        // fewer bits per sample -> fewer coded bits
        let mut r = SplitMix64::new(77);
        let noise: Vec<f32> = (0..96 * 96).map(|_| r.next_f32()).collect();
        let mut sizes = Vec::new();
        for n in [2u8, 4, 6, 8] {
            let levels = (1u32 << n) - 1;
            let samples: Vec<u16> =
                noise.iter().map(|&f| (f * levels as f32).round() as u16).collect();
            sizes.push(encode(&samples, 96, 96, n).len());
        }
        assert!(sizes[0] < sizes[1] && sizes[1] < sizes[2] && sizes[2] < sizes[3], "{sizes:?}");
    }

    #[test]
    fn single_row_and_column_edge_cases() {
        let mut r = SplitMix64::new(5);
        let row: Vec<u16> = (0..97).map(|_| (r.next_u64() & 255) as u16).collect();
        roundtrip(&row, 97, 1, 8);
        roundtrip(&row, 1, 97, 8);
        roundtrip(&[7u16], 1, 1, 8);
    }

    #[test]
    fn extreme_values_roundtrip() {
        // alternating min/max stresses the exponent path
        let samples: Vec<u16> =
            (0..32 * 32).map(|i| if i % 2 == 0 { 0 } else { 65535 }).collect();
        roundtrip(&samples, 32, 32, 16);
    }

    #[test]
    fn truncation_reports_error() {
        let mut r = SplitMix64::new(42);
        let samples: Vec<u16> = (0..32 * 32).map(|_| (r.next_u64() & 255) as u16).collect();
        let bytes = encode(&samples, 32, 32, 8);
        let meta = ImageMeta { width: 32, height: 32, n: 8 };
        for cut in [0, 1, bytes.len() / 2, bytes.len() - 1] {
            assert!(
                matches!(decode(&bytes[..cut], &meta), Err(Error::Truncated { .. })),
                "cut at {cut} not reported"
            );
        }
    }

    #[test]
    fn into_apis_reuse_buffers_and_check_lengths() {
        let mut r = SplitMix64::new(8);
        let samples: Vec<u16> = (0..24 * 24).map(|_| (r.next_u64() & 255) as u16).collect();
        let meta = ImageMeta { width: 24, height: 24, n: 8 };
        let mut bytes = Vec::new();
        encode_into(&samples, 24, 24, 8, &mut bytes);
        let cap = bytes.capacity();
        let mut out = vec![0u16; 24 * 24];
        decode_into(&bytes, &meta, &mut out).unwrap();
        assert_eq!(out, samples);
        // wrong-size slice is a typed error, not a panic
        let mut short = vec![0u16; 10];
        assert!(matches!(decode_into(&bytes, &meta, &mut short), Err(Error::Corrupt(_))));
        // re-encoding into the same buffer reuses its capacity exactly
        encode_into(&samples, 24, 24, 8, &mut bytes);
        assert_eq!(bytes.capacity(), cap);
        decode_into(&bytes, &meta, &mut out).unwrap();
        assert_eq!(out, samples);
    }

    #[test]
    fn oversized_geometry_rejected_before_allocation() {
        let meta = ImageMeta { width: 1 << 20, height: 1 << 20, n: 8 };
        assert!(matches!(decode(&[], &meta), Err(Error::LimitExceeded { .. })));
    }
}
