//! Reusable scratch-buffer pool: zero per-frame heap allocation in the
//! codec layer at steady state.
//!
//! Entropy coding a frame needs a handful of working buffers — the tiled
//! sample plane, per-stripe bitstreams, filter/pack intermediates, the
//! decoded sample plane. Their sizes are stable across frames of one
//! stream, so instead of `vec![0; ..]` per frame, callers `take_*` a
//! buffer here (cleared, with at least the requested capacity) and
//! `put_*` it back when done. After a short warmup every take is a hit
//! and the codec layer stops allocating.
//!
//! The pool is `Sync` (internally `Mutex`ed) so one instance can be
//! shared by the edge encoder, the decode workers, and the stripe
//! fan-out threads. Reuse is observable through [`ScratchPool::stats`]
//! — the bench and the steady-state test assert misses stay flat once
//! warm, which is the "zero allocations per frame" acceptance check.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

/// Keep at most this many parked buffers per type; beyond it, returned
/// buffers are dropped (bounds worst-case memory if a caller leaks takes
/// and puts asymmetrically).
const MAX_POOLED: usize = 64;

/// Reuse counters for one pool (monotonic since construction).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ScratchStats {
    /// Takes satisfied by a parked buffer of sufficient capacity.
    pub hits: u64,
    /// Takes that had to allocate (or grow a smaller parked buffer).
    pub misses: u64,
    /// Buffers handed back via `put_*`.
    pub returned: u64,
}

/// A shared pool of `Vec<u16>` / `Vec<u8>` working buffers.
#[derive(Debug, Default)]
pub struct ScratchPool {
    u16s: Mutex<Vec<Vec<u16>>>,
    u8s: Mutex<Vec<Vec<u8>>>,
    hits: AtomicU64,
    misses: AtomicU64,
    returned: AtomicU64,
}

fn lock<T>(m: &Mutex<T>) -> std::sync::MutexGuard<'_, T> {
    // a panicked taker cannot corrupt a Vec-of-Vecs; recover and go on
    m.lock().unwrap_or_else(|poisoned| poisoned.into_inner())
}

/// Pop the best-fitting parked buffer: the smallest one with at least
/// `min_cap` capacity, else the largest available (which will be grown
/// by the caller-side `reserve`, counting as a miss).
fn take_best<T>(pool: &mut Vec<Vec<T>>, min_cap: usize) -> Option<(Vec<T>, bool)> {
    if pool.is_empty() {
        return None;
    }
    let mut best: Option<(usize, usize)> = None; // (index, capacity)
    let mut largest = (0usize, 0usize);
    for (i, b) in pool.iter().enumerate() {
        let cap = b.capacity();
        if cap >= min_cap && best.map_or(true, |(_, c)| cap < c) {
            best = Some((i, cap));
        }
        if cap >= largest.1 {
            largest = (i, cap);
        }
    }
    let (idx, fits) = match best {
        Some((i, _)) => (i, true),
        None => (largest.0, false),
    };
    Some((pool.swap_remove(idx), fits))
}

impl ScratchPool {
    pub fn new() -> Self {
        Self::default()
    }

    /// An empty `Vec<u16>` with capacity at least `min_cap`.
    pub fn take_u16(&self, min_cap: usize) -> Vec<u16> {
        match take_best(&mut lock(&self.u16s), min_cap) {
            Some((mut buf, fits)) => {
                buf.clear();
                if fits {
                    self.hits.fetch_add(1, Ordering::Relaxed);
                } else {
                    buf.reserve(min_cap);
                    self.misses.fetch_add(1, Ordering::Relaxed);
                }
                buf
            }
            None => {
                self.misses.fetch_add(1, Ordering::Relaxed);
                Vec::with_capacity(min_cap)
            }
        }
    }

    /// An empty `Vec<u8>` with capacity at least `min_cap`.
    pub fn take_u8(&self, min_cap: usize) -> Vec<u8> {
        match take_best(&mut lock(&self.u8s), min_cap) {
            Some((mut buf, fits)) => {
                buf.clear();
                if fits {
                    self.hits.fetch_add(1, Ordering::Relaxed);
                } else {
                    buf.reserve(min_cap);
                    self.misses.fetch_add(1, Ordering::Relaxed);
                }
                buf
            }
            None => {
                self.misses.fetch_add(1, Ordering::Relaxed);
                Vec::with_capacity(min_cap)
            }
        }
    }

    /// Park a buffer for reuse (its contents are discarded).
    pub fn put_u16(&self, mut buf: Vec<u16>) {
        if buf.capacity() == 0 {
            return;
        }
        buf.clear();
        let mut pool = lock(&self.u16s);
        if pool.len() < MAX_POOLED {
            pool.push(buf);
            self.returned.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// Park a buffer for reuse (its contents are discarded).
    pub fn put_u8(&self, mut buf: Vec<u8>) {
        if buf.capacity() == 0 {
            return;
        }
        buf.clear();
        let mut pool = lock(&self.u8s);
        if pool.len() < MAX_POOLED {
            pool.push(buf);
            self.returned.fetch_add(1, Ordering::Relaxed);
        }
    }

    pub fn stats(&self) -> ScratchStats {
        ScratchStats {
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            returned: self.returned.load(Ordering::Relaxed),
        }
    }
}

#[cfg(test)]
mod tests {
    #![allow(clippy::unwrap_used, clippy::expect_used)]

    use super::*;

    #[test]
    fn first_take_misses_then_reuse_hits() {
        let pool = ScratchPool::new();
        let buf = pool.take_u16(100);
        assert!(buf.capacity() >= 100 && buf.is_empty());
        assert_eq!(pool.stats().misses, 1);
        pool.put_u16(buf);
        let buf = pool.take_u16(80);
        assert!(buf.capacity() >= 80);
        assert_eq!(pool.stats(), ScratchStats { hits: 1, misses: 1, returned: 1 });
    }

    #[test]
    fn returned_buffers_come_back_cleared() {
        let pool = ScratchPool::new();
        let mut buf = pool.take_u8(8);
        buf.extend_from_slice(&[1, 2, 3]);
        pool.put_u8(buf);
        assert!(pool.take_u8(4).is_empty());
    }

    #[test]
    fn best_fit_prefers_smallest_adequate_buffer() {
        let pool = ScratchPool::new();
        pool.put_u16(Vec::with_capacity(1000));
        pool.put_u16(Vec::with_capacity(100));
        let buf = pool.take_u16(50);
        assert!(buf.capacity() >= 50 && buf.capacity() < 1000, "{}", buf.capacity());
        // the big one is still parked for big requests
        assert!(pool.take_u16(900).capacity() >= 900);
        assert_eq!(pool.stats().hits, 2);
    }

    #[test]
    fn undersized_buffer_is_grown_and_counted_as_miss() {
        let pool = ScratchPool::new();
        pool.put_u8(Vec::with_capacity(16));
        let buf = pool.take_u8(4096);
        assert!(buf.capacity() >= 4096);
        assert_eq!(pool.stats().misses, 1);
    }

    #[test]
    fn zero_capacity_buffers_are_not_pooled() {
        let pool = ScratchPool::new();
        pool.put_u8(Vec::new());
        assert_eq!(pool.stats().returned, 0);
    }

    #[test]
    fn pool_size_is_bounded() {
        let pool = ScratchPool::new();
        for _ in 0..(MAX_POOLED + 10) {
            pool.put_u16(Vec::with_capacity(8));
        }
        assert_eq!(pool.stats().returned, MAX_POOLED as u64);
    }

    #[test]
    fn shared_across_threads() {
        let pool = std::sync::Arc::new(ScratchPool::new());
        std::thread::scope(|s| {
            for _ in 0..4 {
                let pool = std::sync::Arc::clone(&pool);
                s.spawn(move || {
                    for _ in 0..50 {
                        let b = pool.take_u16(256);
                        pool.put_u16(b);
                    }
                });
            }
        });
        let st = pool.stats();
        assert_eq!(st.hits + st.misses, 200);
        // once each thread has seeded a buffer, everything is a hit
        assert!(st.misses <= 4, "misses = {}", st.misses);
    }
}
