//! The codec substrate: lossless (TLC / PNG-like / zstd) and lossy (MIC)
//! single-plane image coders plus the bitstream container.
//!
//! These stand in for FLIF and HEVC in the paper's evaluation; see
//! DESIGN.md §2 for the substitution rationale and E2/E4 for the benches
//! that compare them.

pub mod bitio;
pub mod container;
pub mod dct;
pub mod lossy;
pub mod png_like;
pub mod rice;
pub mod predict;
pub mod rc;
pub mod tlc;
pub mod tlc_ic;
pub mod zstd_raw;

use anyhow::bail;

/// Geometry a decoder needs (travels in the container header).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ImageMeta {
    pub width: usize,
    pub height: usize,
    /// Sample bit depth (2..=16).
    pub n: u8,
}

/// Registry of payload codecs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
#[repr(u8)]
pub enum CodecKind {
    /// Tensor Lossless Codec — context-adaptive range coding (FLIF stand-in).
    Tlc = 1,
    /// Paeth + DEFLATE (PNG stand-in).
    PngLike = 2,
    /// Bit-packed zstd (generic-compressor baseline).
    ZstdRaw = 3,
    /// Mini Intra Codec — lossy DCT transform coding (HEVC-intra stand-in).
    Mic = 4,
    /// Inter-channel TLC — channel-predictive lossless coding (the [5]
    /// "customized deep-feature lossless codec" analog). Codes the
    /// channel-plane sequence directly (container skips tiling).
    TlcIc = 5,
}

impl CodecKind {
    pub fn from_u8(v: u8) -> anyhow::Result<Self> {
        Ok(match v {
            1 => CodecKind::Tlc,
            2 => CodecKind::PngLike,
            3 => CodecKind::ZstdRaw,
            4 => CodecKind::Mic,
            5 => CodecKind::TlcIc,
            other => bail!("unknown codec id {other}"),
        })
    }

    pub fn from_name(name: &str) -> anyhow::Result<Self> {
        Ok(match name {
            "tlc" => CodecKind::Tlc,
            "png" | "png-like" => CodecKind::PngLike,
            "zstd" => CodecKind::ZstdRaw,
            "mic" | "lossy" => CodecKind::Mic,
            "tlc-ic" | "tlcic" => CodecKind::TlcIc,
            other => bail!("unknown codec '{other}' (tlc|tlc-ic|png|zstd|mic)"),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            CodecKind::Tlc => "tlc",
            CodecKind::PngLike => "png-like",
            CodecKind::ZstdRaw => "zstd",
            CodecKind::Mic => "mic",
            CodecKind::TlcIc => "tlc-ic",
        }
    }

    pub fn is_lossless(&self) -> bool {
        !matches!(self, CodecKind::Mic)
    }

    /// Encode one plane. `qp` is only meaningful for lossy codecs.
    pub fn encode_image(
        &self,
        samples: &[u16],
        width: usize,
        height: usize,
        n: u8,
        qp: u8,
    ) -> Vec<u8> {
        match self {
            CodecKind::Tlc => tlc::encode(samples, width, height, n),
            CodecKind::PngLike => png_like::encode(samples, width, height, n),
            CodecKind::ZstdRaw => zstd_raw::encode(samples, width, height, n),
            CodecKind::Mic => lossy::encode(samples, width, height, n, qp),
            // single-plane fallback (the container codes planes directly)
            CodecKind::TlcIc => tlc_ic::encode_planes(samples, 1, height, width, n),
        }
    }

    /// Decode one plane.
    pub fn decode_image(&self, bytes: &[u8], meta: &ImageMeta, qp: u8) -> Vec<u16> {
        match self {
            CodecKind::Tlc => tlc::decode(bytes, meta),
            CodecKind::PngLike => png_like::decode(bytes, meta),
            CodecKind::ZstdRaw => zstd_raw::decode(bytes, meta),
            CodecKind::Mic => lossy::decode(bytes, meta, qp),
            CodecKind::TlcIc => {
                tlc_ic::decode_planes(bytes, 1, meta.height, meta.width, meta.n)
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kind_roundtrips_through_ids_and_names() {
        for k in [
            CodecKind::Tlc,
            CodecKind::PngLike,
            CodecKind::ZstdRaw,
            CodecKind::Mic,
            CodecKind::TlcIc,
        ] {
            assert_eq!(CodecKind::from_u8(k as u8).unwrap(), k);
            assert_eq!(CodecKind::from_name(k.name()).unwrap(), k);
        }
        assert!(CodecKind::from_u8(0).is_err());
        assert!(CodecKind::from_name("hevc").is_err());
    }

    #[test]
    fn lossless_flag() {
        assert!(CodecKind::Tlc.is_lossless());
        assert!(!CodecKind::Mic.is_lossless());
    }
}
