//! The codec substrate: lossless (TLC / PNG-like / zstd) and lossy (MIC)
//! single-plane image coders plus the bitstream container.
//!
//! These stand in for FLIF and HEVC in the paper's evaluation; see
//! DESIGN.md §2 for the substitution rationale and E2/E4 for the benches
//! that compare them.
//!
//! # Error handling & robustness
//!
//! The cloud decoder is fed bytes it does not control: frames arrive over
//! a lossy edge→cloud channel and may be truncated, bit-flipped, or
//! adversarial. The entire decode path is therefore **total** — every
//! decoder returns a typed [`Error`] instead of panicking, and no input
//! can trigger unbounded allocation:
//!
//! * [`Error::Truncated`] — the stream ended before the decoder was done.
//!   The range coder ([`rc::Decoder`]) and bit reader
//!   ([`bitio::BitReader`]) track reads past the end of the buffer, so
//!   truncation surfaces even mid-payload.
//! * [`Error::Corrupt`] — the bytes are structurally invalid (CRC
//!   mismatch, bad magic, impossible symbol, inconsistent geometry).
//! * [`Error::LimitExceeded`] — a header asks the decoder to allocate
//!   more than [`MAX_DECODED_SAMPLES`]; rejected before any allocation.
//! * [`Error::Unsupported`] — well-formed but unknown (future container
//!   version, unregistered codec id).
//!
//! Encoders keep `assert!`-style contracts: the encode side runs on
//! trusted, locally produced tensors and a violated invariant there is a
//! programming error, not an input error.
//!
//! The fault-injection harness ([`faultgen`] + `tests/decode_robustness.rs`)
//! enforces the contract: every codec's valid output is truncated at every
//! byte boundary, bit-flipped, and header-corrupted, and the decoder must
//! return `Err` or a correct tensor — never panic, never over-allocate.

pub mod bitio;
pub mod container;
pub mod dct;
pub mod faultgen;
pub mod lossy;
pub mod png_like;
pub mod rice;
pub mod predict;
pub mod rc;
pub mod scratch;
pub mod tlc;
pub mod tlc_ic;
pub mod zstd_raw;

use std::fmt;

/// Hard cap on the number of samples any decode is allowed to produce
/// (16 Mi samples = 32 MiB of `u16`). Derived limits from container
/// headers are checked against this before any payload allocation, so a
/// hostile header cannot OOM the serving process.
pub const MAX_DECODED_SAMPLES: usize = 1 << 24;

/// Typed decode-path error taxonomy. See the module docs for the
/// classification contract.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Error {
    /// The stream ended before decoding completed. `got` is the number of
    /// bytes (or, where noted, bits) available; `needed` is what the
    /// decoder required at the point it ran dry.
    Truncated {
        what: &'static str,
        needed: usize,
        got: usize,
    },
    /// Structurally invalid bytes: checksum mismatch, bad magic,
    /// impossible symbol, inconsistent geometry.
    Corrupt(String),
    /// A header-derived allocation exceeds a hard cap.
    LimitExceeded {
        what: &'static str,
        requested: usize,
        limit: usize,
    },
    /// Well-formed but not something this build decodes (future version,
    /// unknown codec id).
    Unsupported(String),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::Truncated { what, needed, got } => {
                write!(f, "truncated {what}: needed {needed}, got {got}")
            }
            Error::Corrupt(msg) => write!(f, "corrupt stream: {msg}"),
            Error::LimitExceeded { what, requested, limit } => {
                write!(f, "{what} limit exceeded: {requested} > {limit}")
            }
            Error::Unsupported(msg) => write!(f, "unsupported: {msg}"),
        }
    }
}

impl std::error::Error for Error {}

/// Decode-path result type.
pub type Result<T> = std::result::Result<T, Error>;

/// Geometry a decoder needs (travels in the container header).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ImageMeta {
    pub width: usize,
    pub height: usize,
    /// Sample bit depth (1..=16).
    pub n: u8,
}

impl ImageMeta {
    /// Validate the geometry against the decode limits; returns the
    /// number of samples a decode of this image will allocate.
    pub fn checked_samples(&self) -> Result<usize> {
        if !(1..=16).contains(&self.n) {
            return Err(Error::Corrupt(format!("bit depth {} outside 1..=16", self.n)));
        }
        let samples = self
            .width
            .checked_mul(self.height)
            .ok_or(Error::LimitExceeded {
                what: "decoded samples",
                requested: usize::MAX,
                limit: MAX_DECODED_SAMPLES,
            })?;
        if samples > MAX_DECODED_SAMPLES {
            return Err(Error::LimitExceeded {
                what: "decoded samples",
                requested: samples,
                limit: MAX_DECODED_SAMPLES,
            });
        }
        Ok(samples)
    }
}

/// Registry of payload codecs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
#[repr(u8)]
pub enum CodecKind {
    /// Tensor Lossless Codec — context-adaptive range coding (FLIF stand-in).
    Tlc = 1,
    /// Paeth + DEFLATE (PNG stand-in).
    PngLike = 2,
    /// Bit-packed zstd (generic-compressor baseline).
    ZstdRaw = 3,
    /// Mini Intra Codec — lossy DCT transform coding (HEVC-intra stand-in).
    Mic = 4,
    /// Inter-channel TLC — channel-predictive lossless coding (the [5]
    /// "customized deep-feature lossless codec" analog). Codes the
    /// channel-plane sequence directly (container skips tiling).
    TlcIc = 5,
}

/// Every registered codec, in id order (handy for sweeps and the
/// fault-injection harness).
pub const ALL_CODECS: [CodecKind; 5] = [
    CodecKind::Tlc,
    CodecKind::PngLike,
    CodecKind::ZstdRaw,
    CodecKind::Mic,
    CodecKind::TlcIc,
];

impl CodecKind {
    pub fn from_u8(v: u8) -> Result<Self> {
        Ok(match v {
            1 => CodecKind::Tlc,
            2 => CodecKind::PngLike,
            3 => CodecKind::ZstdRaw,
            4 => CodecKind::Mic,
            5 => CodecKind::TlcIc,
            other => return Err(Error::Unsupported(format!("unknown codec id {other}"))),
        })
    }

    pub fn from_name(name: &str) -> Result<Self> {
        Ok(match name {
            "tlc" => CodecKind::Tlc,
            "png" | "png-like" => CodecKind::PngLike,
            "zstd" => CodecKind::ZstdRaw,
            "mic" | "lossy" => CodecKind::Mic,
            "tlc-ic" | "tlcic" => CodecKind::TlcIc,
            other => {
                return Err(Error::Unsupported(format!(
                    "unknown codec '{other}' (tlc|tlc-ic|png|zstd|mic)"
                )))
            }
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            CodecKind::Tlc => "tlc",
            CodecKind::PngLike => "png-like",
            CodecKind::ZstdRaw => "zstd",
            CodecKind::Mic => "mic",
            CodecKind::TlcIc => "tlc-ic",
        }
    }

    pub fn is_lossless(&self) -> bool {
        !matches!(self, CodecKind::Mic)
    }

    /// Encode one plane. `qp` is only meaningful for lossy codecs.
    /// Panics on inconsistent arguments (trusted, locally produced input).
    pub fn encode_image(
        &self,
        samples: &[u16],
        width: usize,
        height: usize,
        n: u8,
        qp: u8,
    ) -> Vec<u8> {
        let pool = scratch::ScratchPool::new();
        let mut out = Vec::new();
        self.encode_image_into(samples, width, height, n, qp, &pool, &mut out);
        out
    }

    /// Re-entrant [`Self::encode_image`]: intermediates come from
    /// `scratch`, the stream lands in `out` (cleared first, capacity
    /// reused). This is the per-stripe entry point of the parallel
    /// container path — each stripe job calls it concurrently against
    /// the shared pool.
    pub fn encode_image_into(
        &self,
        samples: &[u16],
        width: usize,
        height: usize,
        n: u8,
        qp: u8,
        scratch: &scratch::ScratchPool,
        out: &mut Vec<u8>,
    ) {
        match self {
            CodecKind::Tlc => tlc::encode_into(samples, width, height, n, out),
            CodecKind::PngLike => {
                png_like::encode_into(samples, width, height, n, scratch, out)
            }
            CodecKind::ZstdRaw => {
                zstd_raw::encode_into(samples, width, height, n, scratch, out)
            }
            CodecKind::Mic => {
                out.clear();
                out.extend_from_slice(&lossy::encode(samples, width, height, n, qp));
            }
            // single-plane fallback (the container codes planes directly)
            CodecKind::TlcIc => tlc_ic::encode_planes_into(samples, 1, height, width, n, out),
        }
    }

    /// Decode one plane. Total: any byte sequence yields `Ok` with exactly
    /// `meta.width * meta.height` samples or a typed [`Error`] — never a
    /// panic, never an allocation beyond [`MAX_DECODED_SAMPLES`].
    pub fn decode_image(&self, bytes: &[u8], meta: &ImageMeta, qp: u8) -> Result<Vec<u16>> {
        let count = meta.checked_samples()?;
        let pool = scratch::ScratchPool::new();
        let mut out = vec![0u16; count];
        self.decode_image_into(bytes, meta, qp, &pool, &mut out)?;
        Ok(out)
    }

    /// Re-entrant [`Self::decode_image`]: writes into a caller-owned
    /// slice of exactly `meta.width * meta.height` samples (a mismatch
    /// is [`Error::Corrupt`], never a panic). Same totality contract.
    pub fn decode_image_into(
        &self,
        bytes: &[u8],
        meta: &ImageMeta,
        qp: u8,
        scratch: &scratch::ScratchPool,
        out: &mut [u16],
    ) -> Result<()> {
        meta.checked_samples()?;
        match self {
            CodecKind::Tlc => tlc::decode_into(bytes, meta, out),
            CodecKind::PngLike => png_like::decode_into(bytes, meta, scratch, out),
            CodecKind::ZstdRaw => zstd_raw::decode_into(bytes, meta, out),
            CodecKind::Mic => {
                let samples = lossy::decode(bytes, meta, qp)?;
                if samples.len() != out.len() {
                    return Err(Error::Corrupt(format!(
                        "mic output slice is {} samples, decode produced {}",
                        out.len(),
                        samples.len()
                    )));
                }
                out.copy_from_slice(&samples);
                Ok(())
            }
            CodecKind::TlcIc => {
                tlc_ic::decode_planes_into(bytes, 1, meta.height, meta.width, meta.n, out)
            }
        }
    }
}

#[cfg(test)]
mod tests {
    #![allow(clippy::unwrap_used, clippy::expect_used)]

    use super::*;

    #[test]
    fn kind_roundtrips_through_ids_and_names() {
        for k in ALL_CODECS {
            assert_eq!(CodecKind::from_u8(k as u8).unwrap(), k);
            assert_eq!(CodecKind::from_name(k.name()).unwrap(), k);
        }
        assert!(matches!(CodecKind::from_u8(0), Err(Error::Unsupported(_))));
        assert!(matches!(CodecKind::from_name("hevc"), Err(Error::Unsupported(_))));
    }

    #[test]
    fn lossless_flag() {
        assert!(CodecKind::Tlc.is_lossless());
        assert!(!CodecKind::Mic.is_lossless());
    }

    #[test]
    fn meta_limits_enforced() {
        let ok = ImageMeta { width: 64, height: 64, n: 8 };
        assert_eq!(ok.checked_samples().unwrap(), 4096);
        let huge = ImageMeta { width: 1 << 16, height: 1 << 16, n: 8 };
        assert!(matches!(
            huge.checked_samples(),
            Err(Error::LimitExceeded { .. })
        ));
        let bad_n = ImageMeta { width: 4, height: 4, n: 17 };
        assert!(matches!(bad_n.checked_samples(), Err(Error::Corrupt(_))));
        let zero_n = ImageMeta { width: 4, height: 4, n: 0 };
        assert!(zero_n.checked_samples().is_err());
    }

    #[test]
    fn error_display_names_the_failure() {
        let e = Error::Truncated { what: "frame", needed: 10, got: 3 };
        assert!(e.to_string().contains("needed 10"));
        let e = Error::LimitExceeded { what: "samples", requested: 99, limit: 10 };
        assert!(e.to_string().contains("99 > 10"));
    }
}
