//! Adaptive binary range coder (LZMA-style carry-less renormalization).
//!
//! The workhorse of both the lossless (TLC) and lossy (MIC) codecs.
//! Probabilities are 11-bit (0..2048) with shift-5 adaptation — the
//! classic LC/LP-free LZMA bit model. `encode_direct` codes equiprobable
//! bits without a model (used for residual mantissas and signs in flat
//! contexts).

pub const PROB_BITS: u32 = 11;
pub const PROB_ONE: u16 = 1 << PROB_BITS; // 2048
pub const PROB_INIT: u16 = PROB_ONE / 2;
const ADAPT_SHIFT: u32 = 5;
const TOP: u32 = 1 << 24;

/// One adaptive binary probability (probability that the bit is 0).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct BitModel(pub u16);

impl Default for BitModel {
    fn default() -> Self {
        BitModel(PROB_INIT)
    }
}

impl BitModel {
    #[inline]
    fn update(&mut self, bit: u32) {
        if bit == 0 {
            self.0 += (PROB_ONE - self.0) >> ADAPT_SHIFT;
        } else {
            self.0 -= self.0 >> ADAPT_SHIFT;
        }
    }
}

/// Range encoder writing to an internal buffer.
#[derive(Debug)]
pub struct Encoder {
    low: u64,
    range: u32,
    cache: u8,
    cache_size: u64,
    out: Vec<u8>,
}

impl Default for Encoder {
    fn default() -> Self {
        Self::new()
    }
}

impl Encoder {
    pub fn new() -> Self {
        Self::with_buffer(Vec::new())
    }

    /// Like [`Encoder::new`] but writing into a recycled buffer: `buf` is
    /// cleared and its capacity reused, so steady-state encoding does not
    /// allocate (see [`crate::codec::scratch`]).
    pub fn with_buffer(mut buf: Vec<u8>) -> Self {
        buf.clear();
        Self { low: 0, range: u32::MAX, cache: 0, cache_size: 1, out: buf }
    }

    #[inline]
    fn shift_low(&mut self) {
        if (self.low as u32) < 0xFF00_0000 || (self.low >> 32) != 0 {
            let carry = (self.low >> 32) as u8;
            let mut temp = self.cache;
            loop {
                self.out.push(temp.wrapping_add(carry));
                temp = 0xFF;
                self.cache_size -= 1;
                if self.cache_size == 0 {
                    break;
                }
            }
            self.cache = (self.low >> 24) as u8;
        }
        self.cache_size += 1;
        // truncate to 32 bits BEFORE shifting (LZMA: `Low = (UInt32)Low << 8`)
        self.low = ((self.low as u32) << 8) as u64;
    }

    /// Encode one bit with an adaptive model.
    #[inline]
    pub fn encode(&mut self, model: &mut BitModel, bit: u32) {
        let bound = (self.range >> PROB_BITS) * model.0 as u32;
        if bit == 0 {
            self.range = bound;
        } else {
            self.low += bound as u64;
            self.range -= bound;
        }
        model.update(bit);
        while self.range < TOP {
            self.range <<= 8;
            self.shift_low();
        }
    }

    /// Encode `n` equiprobable bits of `v`, MSB first.
    pub fn encode_direct(&mut self, v: u32, n: u32) {
        for i in (0..n).rev() {
            self.range >>= 1;
            let bit = (v >> i) & 1;
            if bit != 0 {
                self.low += self.range as u64;
            }
            while self.range < TOP {
                self.range <<= 8;
                self.shift_low();
            }
        }
    }

    /// Flush and return the compressed bytes.
    pub fn finish(mut self) -> Vec<u8> {
        for _ in 0..5 {
            self.shift_low();
        }
        self.out
    }

    pub fn len(&self) -> usize {
        self.out.len()
    }

    pub fn is_empty(&self) -> bool {
        self.out.is_empty()
    }
}

/// Range decoder reading from a byte slice.
///
/// Reads past the end of the buffer yield zero bytes (so decoding is
/// total) but are counted in [`Decoder::overrun`]. The encoder's output
/// length is exactly `renormalizations + 5` bytes and the decoder
/// consumes exactly that many on a valid stream, so `overrun() > 0` is a
/// reliable truncation signal with no false positives — codecs check it
/// after decoding and map it to [`crate::codec::Error::Truncated`].
#[derive(Debug)]
pub struct Decoder<'a> {
    code: u32,
    range: u32,
    buf: &'a [u8],
    pos: usize,
    overrun: usize,
}

impl<'a> Decoder<'a> {
    pub fn new(buf: &'a [u8]) -> Self {
        let mut d = Self { code: 0, range: u32::MAX, buf, pos: 0, overrun: 0 };
        // the first of the 5 init bytes is the encoder's leading cache
        // byte and shifts out of the 32-bit window
        for _ in 0..5 {
            d.code = (d.code << 8) | d.next_byte() as u32;
        }
        d
    }

    #[inline]
    fn next_byte(&mut self) -> u8 {
        let b = match self.buf.get(self.pos) {
            Some(&b) => b,
            None => {
                self.overrun += 1;
                0
            }
        };
        self.pos += 1;
        b
    }

    /// Number of zero bytes synthesized past the end of the buffer.
    /// Zero for every stream produced by [`Encoder::finish`].
    #[inline]
    pub fn overrun(&self) -> usize {
        self.overrun
    }

    /// Bytes consumed so far (including synthesized overrun bytes).
    pub fn byte_pos(&self) -> usize {
        self.pos
    }

    pub fn byte_len(&self) -> usize {
        self.buf.len()
    }

    /// Decode one bit with an adaptive model.
    #[inline]
    pub fn decode(&mut self, model: &mut BitModel) -> u32 {
        let bound = (self.range >> PROB_BITS) * model.0 as u32;
        let bit = if self.code < bound {
            self.range = bound;
            0
        } else {
            self.code -= bound;
            self.range -= bound;
            1
        };
        model.update(bit);
        while self.range < TOP {
            self.range <<= 8;
            self.code = (self.code << 8) | self.next_byte() as u32;
        }
        bit
    }

    /// Decode `n` equiprobable bits, MSB first.
    pub fn decode_direct(&mut self, n: u32) -> u32 {
        let mut v = 0;
        for _ in 0..n {
            self.range >>= 1;
            let bit = if self.code >= self.range {
                self.code -= self.range;
                1
            } else {
                0
            };
            v = (v << 1) | bit;
            while self.range < TOP {
                self.range <<= 8;
                self.code = (self.code << 8) | self.next_byte() as u32;
            }
        }
        v
    }
}

/// Adaptive coder for fixed-width symbols: a binary tree of bit models,
/// MSB-first (the LZMA "bit tree"). Width up to 16.
#[derive(Debug, Clone)]
pub struct BitTree {
    probs: Vec<BitModel>,
    bits: u32,
}

impl BitTree {
    pub fn new(bits: u32) -> Self {
        assert!((1..=16).contains(&bits));
        Self { probs: vec![BitModel::default(); 1 << bits], bits }
    }

    pub fn encode(&mut self, enc: &mut Encoder, symbol: u32) {
        debug_assert!(symbol < (1 << self.bits));
        let mut ctx = 1usize;
        for i in (0..self.bits).rev() {
            let bit = (symbol >> i) & 1;
            enc.encode(&mut self.probs[ctx], bit);
            ctx = (ctx << 1) | bit as usize;
        }
    }

    // baf-lint: allow(raw-index) -- ctx starts at 1 and shifts left `bits` times, staying below probs.len() == 1 << bits
    pub fn decode(&mut self, dec: &mut Decoder) -> u32 {
        let mut ctx = 1usize;
        for _ in 0..self.bits {
            let bit = dec.decode(&mut self.probs[ctx]);
            ctx = (ctx << 1) | bit as usize;
        }
        (ctx as u32) - (1 << self.bits)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::SplitMix64;

    #[test]
    fn biased_bits_roundtrip_and_compress() {
        let mut r = SplitMix64::new(1);
        let bits: Vec<u32> = (0..20_000).map(|_| (r.next_f32() < 0.05) as u32).collect();
        let mut enc = Encoder::new();
        let mut m = BitModel::default();
        for &b in &bits {
            enc.encode(&mut m, b);
        }
        let buf = enc.finish();
        // ~0.29 bits/symbol entropy -> must be far below 1 bit/symbol
        assert!(buf.len() < 20_000 / 8 / 2, "compressed to {} bytes", buf.len());
        let mut dec = Decoder::new(&buf);
        let mut m = BitModel::default();
        for &b in &bits {
            assert_eq!(dec.decode(&mut m), b);
        }
        assert_eq!(dec.overrun(), 0, "valid stream must not overrun");
    }

    #[test]
    fn valid_streams_never_overrun_truncated_ones_do() {
        // The overrun()==0 invariant for encoder-produced streams is what
        // lets the codecs use a strict truncation check; pin it across
        // many stream lengths, and check truncation does trip it.
        let mut r = SplitMix64::new(9);
        for len in [0usize, 1, 7, 100, 3000] {
            let bits: Vec<u32> = (0..len).map(|_| (r.next_u64() & 1) as u32).collect();
            let mut enc = Encoder::new();
            let mut m = BitModel::default();
            for &b in &bits {
                enc.encode(&mut m, b);
            }
            let buf = enc.finish();
            let mut dec = Decoder::new(&buf);
            let mut m = BitModel::default();
            for &b in &bits {
                assert_eq!(dec.decode(&mut m), b, "len={len}");
            }
            assert_eq!(dec.overrun(), 0, "len={len}");
            // any truncation starves the 5-byte init or a renorm read
            if !buf.is_empty() {
                let cut = &buf[..buf.len() - 1];
                let mut dec = Decoder::new(cut);
                let mut m = BitModel::default();
                for _ in &bits {
                    dec.decode(&mut m);
                }
                assert!(dec.overrun() > 0, "truncation undetected at len={len}");
            }
        }
    }

    #[test]
    fn direct_bits_roundtrip() {
        let mut r = SplitMix64::new(2);
        let vals: Vec<(u32, u32)> = (0..5_000)
            .map(|_| {
                let n = r.next_u64() % 16 + 1;
                ((r.next_u64() as u32) & ((1u32 << n) - 1), n as u32)
            })
            .collect();
        let mut enc = Encoder::new();
        for &(v, n) in &vals {
            enc.encode_direct(v, n);
        }
        let buf = enc.finish();
        let mut dec = Decoder::new(&buf);
        for &(v, n) in &vals {
            assert_eq!(dec.decode_direct(n), v);
        }
    }

    #[test]
    fn mixed_model_and_direct_roundtrip() {
        let mut r = SplitMix64::new(3);
        let mut enc = Encoder::new();
        let mut m0 = BitModel::default();
        let mut tree = BitTree::new(6);
        let script: Vec<(u32, u32)> = (0..4_000)
            .map(|_| (r.next_u64() as u32 % 3, r.next_u64() as u32 & 63))
            .collect();
        for &(kind, val) in &script {
            match kind {
                0 => enc.encode(&mut m0, val & 1),
                1 => enc.encode_direct(val, 6),
                _ => tree.encode(&mut enc, val),
            }
        }
        let buf = enc.finish();
        let mut dec = Decoder::new(&buf);
        let mut m0 = BitModel::default();
        let mut tree = BitTree::new(6);
        for &(kind, val) in &script {
            match kind {
                0 => assert_eq!(dec.decode(&mut m0), val & 1),
                1 => assert_eq!(dec.decode_direct(6), val),
                _ => assert_eq!(tree.decode(&mut dec), val),
            }
        }
    }

    #[test]
    fn skewed_tree_beats_direct_rate() {
        // symbols heavily concentrated on 0..4 of 64
        let mut r = SplitMix64::new(4);
        let syms: Vec<u32> = (0..30_000).map(|_| (r.next_f64() * r.next_f64() * 8.0) as u32 % 64).collect();
        let mut enc = Encoder::new();
        let mut tree = BitTree::new(6);
        for &s in &syms {
            tree.encode(&mut enc, s);
        }
        let adaptive = enc.finish().len();
        let direct = 30_000 * 6 / 8;
        assert!(adaptive * 10 < direct * 9, "adaptive {adaptive} vs direct {direct}");
    }

    #[test]
    fn carry_propagation_stress() {
        // Alternate extreme-probability patterns to exercise shift_low
        // carry paths.
        let mut enc = Encoder::new();
        let mut m = BitModel(PROB_ONE - 31);
        let pattern: Vec<u32> = (0..10_000).map(|i| (i % 97 == 0) as u32).collect();
        for &b in &pattern {
            enc.encode(&mut m, b);
        }
        let buf = enc.finish();
        let mut dec = Decoder::new(&buf);
        let mut m = BitModel(PROB_ONE - 31);
        for &b in &pattern {
            assert_eq!(dec.decode(&mut m), b);
        }
    }
}
