//! TLC-IC — inter-channel tensor lossless codec (the [5] analog).
//!
//! The paper's lossless comparison point [5] ("Near-lossless deep feature
//! compression", MMSP'18) customizes a codec around the *statistics of
//! deep feature tensors*: neighbouring channels of a BN output are
//! correlated, so the previous channel plane is a useful predictor in
//! addition to the spatial neighbourhood.
//!
//! Per sample, TLC-IC picks between two predictors:
//!   * spatial MED (as TLC), and
//!   * inter-channel: previous plane's co-located sample plus the local
//!     spatial gradient correction `med(a,b,c) - med(pa,pb,pc)`;
//! the chosen predictor is the one that performed better on the causal
//! neighbourhood (backward-adaptive, so no side info), and residuals are
//! coded with the same context-adaptive range-coded scheme as TLC, with
//! the context extended by the predictor choice.
//!
//! It operates on the *channel-plane sequence* (the untiled tensor),
//! which is where inter-channel structure lives; the container carries
//! the geometry. On BN-output tensors with correlation-ordered channels
//! this beats plane-independent TLC (see bench_codec E4).

use super::predict::{activity_context, med, NUM_CONTEXTS};
use super::rc::{BitModel, Decoder, Encoder};
use super::{Error, Result, MAX_DECODED_SAMPLES};

const MAX_EXP: usize = 17;

struct Models {
    zero: Vec<BitModel>,
    sign: Vec<BitModel>,
    exp: Vec<[BitModel; MAX_EXP]>,
}

impl Models {
    fn new() -> Self {
        // contexts x 2 predictor choices
        let n = NUM_CONTEXTS * 2;
        Models {
            zero: vec![BitModel::default(); n],
            sign: vec![BitModel::default(); n],
            exp: vec![[BitModel::default(); MAX_EXP]; n],
        }
    }
}

/// Causal neighbourhood of (x, y) in a plane.
#[inline]
fn nbhd(plane: &[u16], w: usize, x: usize, y: usize, half: i32) -> (i32, i32, i32) {
    let at = |xx: usize, yy: usize| plane[yy * w + xx] as i32;
    match (x, y) {
        (0, 0) => (half, half, half),
        (_, 0) => {
            let a = at(x - 1, 0);
            (a, a, a)
        }
        (0, _) => {
            let b = at(0, y - 1);
            (b, b, b)
        }
        _ => (at(x - 1, y), at(x, y - 1), at(x - 1, y - 1)),
    }
}

/// Backward-adaptive predictor switch: compare how well each predictor
/// did on the left and top neighbours (no side information needed).
#[inline]
fn choose_inter(
    cur: &[u16],
    prev: &[u16],
    w: usize,
    x: usize,
    y: usize,
) -> bool {
    let mut err_sp = 0i64;
    let mut err_ic = 0i64;
    let mut count = 0;
    let half = 0; // unused by callees below
    let _ = half;
    for (nx, ny) in [(x.wrapping_sub(1), y), (x, y.wrapping_sub(1))] {
        if nx >= w || ny > y || (ny == y && nx >= x) || nx == usize::MAX || ny == usize::MAX {
            continue;
        }
        let actual = cur[ny * w + nx] as i32;
        let (a, b, c) = nbhd(cur, w, nx, ny, 0);
        err_sp += (actual - med(a, b, c)).abs() as i64;
        err_ic += (actual - prev[ny * w + nx] as i32).abs() as i64;
        count += 1;
    }
    count > 0 && err_ic < err_sp
}

fn code_plane_enc(
    enc: &mut Encoder,
    models: &mut Models,
    cur: &[u16],
    prev: Option<&[u16]>,
    w: usize,
    h: usize,
    n: u8,
) {
    let half = 1i32 << (n - 1);
    for y in 0..h {
        for x in 0..w {
            let (a, b, c) = nbhd(cur, w, x, y, half);
            let spatial = med(a, b, c);
            let (pred, which) = match prev {
                Some(p) if choose_inter(cur, p, w, x, y) => {
                    (p[y * w + x] as i32, 1usize)
                }
                _ => (spatial, 0usize),
            };
            let ctx = activity_context(a, b, c, n) + which * NUM_CONTEXTS;
            let r = cur[y * w + x] as i32 - pred;
            if r == 0 {
                enc.encode(&mut models.zero[ctx], 0);
                continue;
            }
            enc.encode(&mut models.zero[ctx], 1);
            enc.encode(&mut models.sign[ctx], (r < 0) as u32);
            let mag = r.unsigned_abs();
            let k = 31 - mag.leading_zeros();
            for i in 0..k {
                enc.encode(&mut models.exp[ctx][i as usize], 1);
            }
            enc.encode(&mut models.exp[ctx][k as usize], 0);
            if k > 0 {
                enc.encode_direct(mag & ((1 << k) - 1), k);
            }
        }
    }
}

fn code_plane_dec(
    dec: &mut Decoder,
    models: &mut Models,
    cur: &mut [u16],
    prev: Option<&[u16]>,
    w: usize,
    h: usize,
    n: u8,
) {
    let half = 1i32 << (n - 1);
    let maxv = (1i32 << n) - 1;
    for y in 0..h {
        for x in 0..w {
            let (a, b, c) = nbhd(cur, w, x, y, half);
            let spatial = med(a, b, c);
            let (pred, which) = match prev {
                Some(p) if choose_inter(cur, p, w, x, y) => {
                    (p[y * w + x] as i32, 1usize)
                }
                _ => (spatial, 0usize),
            };
            let ctx = activity_context(a, b, c, n) + which * NUM_CONTEXTS;
            let v = if dec.decode(&mut models.zero[ctx]) == 0 {
                pred
            } else {
                let neg = dec.decode(&mut models.sign[ctx]) == 1;
                let mut k = 0usize;
                while k < MAX_EXP - 1 && dec.decode(&mut models.exp[ctx][k]) == 1 {
                    k += 1;
                }
                let mantissa = if k > 0 { dec.decode_direct(k as u32) } else { 0 };
                let mag = ((1u32 << k) | mantissa) as i32;
                pred + if neg { -mag } else { mag }
            };
            cur[y * w + x] = v.clamp(0, maxv) as u16;
        }
    }
}

/// Encode C channel planes of (h, w) samples at depth n.
pub fn encode_planes(bins: &[u16], c: usize, h: usize, w: usize, n: u8) -> Vec<u8> {
    let mut out = Vec::new();
    encode_planes_into(bins, c, h, w, n, &mut out);
    out
}

/// Re-entrant [`encode_planes`]: writes the stream into `out` (cleared
/// first), reusing its capacity. One of these runs per stripe of
/// channels in the striped container path.
pub fn encode_planes_into(bins: &[u16], c: usize, h: usize, w: usize, n: u8, out: &mut Vec<u8>) {
    assert_eq!(bins.len(), c * h * w);
    let mut enc = Encoder::with_buffer(std::mem::take(out));
    let mut models = Models::new();
    for ch in 0..c {
        let cur = &bins[ch * h * w..(ch + 1) * h * w];
        let prev = if ch > 0 {
            Some(&bins[(ch - 1) * h * w..ch * h * w])
        } else {
            None
        };
        code_plane_enc(&mut enc, &mut models, cur, prev, w, h, n);
    }
    *out = enc.finish();
}

/// Decode C channel planes.
///
/// Total: geometry is validated against [`MAX_DECODED_SAMPLES`] before
/// allocation and truncation surfaces via the range decoder's overrun
/// counter; corrupt (non-truncated) bytes decode to clamped garbage —
/// integrity is the container CRC's job.
pub fn decode_planes(bytes: &[u8], c: usize, h: usize, w: usize, n: u8) -> Result<Vec<u16>> {
    let total = checked_total(c, h, w, n)?;
    let mut out = vec![0u16; total];
    decode_planes_into(bytes, c, h, w, n, &mut out)?;
    Ok(out)
}

fn checked_total(c: usize, h: usize, w: usize, n: u8) -> Result<usize> {
    if !(1..=16).contains(&n) {
        return Err(Error::Corrupt(format!("bit depth {n} outside 1..=16")));
    }
    c.checked_mul(h)
        .and_then(|v| v.checked_mul(w))
        .filter(|&v| v <= MAX_DECODED_SAMPLES)
        .ok_or(Error::LimitExceeded {
            what: "decoded samples",
            requested: usize::MAX,
            limit: MAX_DECODED_SAMPLES,
        })
}

/// Re-entrant [`decode_planes`]: writes into a caller-owned slice of
/// exactly `c * h * w` samples (a mismatch is [`Error::Corrupt`]).
// baf-lint: allow(raw-index) -- plane windows: ch<c and checked_total keep every h*w span inside `out`
pub fn decode_planes_into(
    bytes: &[u8],
    c: usize,
    h: usize,
    w: usize,
    n: u8,
    out: &mut [u16],
) -> Result<()> {
    let total = checked_total(c, h, w, n)?;
    if out.len() != total {
        return Err(Error::Corrupt(format!(
            "tlc-ic output slice is {} samples, geometry says {total}",
            out.len()
        )));
    }
    let mut dec = Decoder::new(bytes);
    let mut models = Models::new();
    for ch in 0..c {
        let (done, rest) = out.split_at_mut(ch * h * w);
        let cur = &mut rest[..h * w];
        let prev = if ch > 0 {
            Some(&done[(ch - 1) * h * w..])
        } else {
            None
        };
        code_plane_dec(&mut dec, &mut models, cur, prev, w, h, n);
    }
    if dec.overrun() > 0 {
        return Err(Error::Truncated {
            what: "tlc-ic range-coded stream",
            needed: dec.byte_pos(),
            got: dec.byte_len(),
        });
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    #![allow(clippy::unwrap_used, clippy::expect_used)]

    use super::*;
    use crate::util::SplitMix64;

    fn roundtrip(bins: &[u16], c: usize, h: usize, w: usize, n: u8) -> usize {
        let bytes = encode_planes(bins, c, h, w, n);
        assert_eq!(
            decode_planes(&bytes, c, h, w, n).unwrap(),
            bins,
            "c={c} h={h} w={w} n={n}"
        );
        bytes.len()
    }

    #[test]
    fn truncation_and_oversize_rejected() {
        let mut r = SplitMix64::new(21);
        let bins: Vec<u16> = (0..4 * 8 * 8).map(|_| (r.next_u64() & 63) as u16).collect();
        let bytes = encode_planes(&bins, 4, 8, 8, 6);
        for cut in [0, bytes.len() / 3, bytes.len() - 1] {
            assert!(
                matches!(
                    decode_planes(&bytes[..cut], 4, 8, 8, 6),
                    Err(Error::Truncated { .. })
                ),
                "cut {cut}"
            );
        }
        assert!(matches!(
            decode_planes(&bytes, usize::MAX, 2, 2, 6),
            Err(Error::LimitExceeded { .. })
        ));
        assert!(matches!(
            decode_planes(&bytes, 4, 8, 8, 0),
            Err(Error::Corrupt(_))
        ));
    }

    #[test]
    fn roundtrip_random_planes() {
        let mut r = SplitMix64::new(3);
        for n in [2u8, 4, 8, 12] {
            let mask = (1u32 << n) - 1;
            let bins: Vec<u16> =
                (0..6 * 16 * 16).map(|_| (r.next_u64() as u32 & mask) as u16).collect();
            roundtrip(&bins, 6, 16, 16, n);
        }
    }

    #[test]
    fn correlated_channels_beat_independent_tlc() {
        // channel k = smooth base + small per-channel delta: strong
        // inter-channel structure that plane-independent TLC cannot see
        let (c, h, w) = (16usize, 16usize, 16usize);
        let mut r = SplitMix64::new(9);
        let base: Vec<i32> = (0..h * w)
            .map(|i| (((i % w) * 3 + (i / w) * 5) % 200) as i32)
            .collect();
        let mut bins = vec![0u16; c * h * w];
        for ch in 0..c {
            for i in 0..h * w {
                let v = base[i] + ch as i32 * 2 + (r.next_u64() % 3) as i32;
                bins[ch * h * w + i] = v.clamp(0, 255) as u16;
            }
        }
        let ic = roundtrip(&bins, c, h, w, 8);
        // plane-by-plane TLC for comparison
        let mut tlc_total = 0usize;
        for ch in 0..c {
            tlc_total +=
                super::super::tlc::encode(&bins[ch * h * w..(ch + 1) * h * w], w, h, 8)
                    .len();
        }
        assert!(
            ic < tlc_total,
            "inter-channel ({ic}) should beat per-plane TLC ({tlc_total})"
        );
    }

    #[test]
    fn single_channel_matches_spatial_only() {
        // with one plane there is no inter-channel path; must still work
        let mut r = SplitMix64::new(4);
        let bins: Vec<u16> = (0..12 * 12).map(|_| (r.next_u64() & 63) as u16).collect();
        roundtrip(&bins, 1, 12, 12, 6);
    }

    #[test]
    fn constant_tensor_is_tiny() {
        let bins = vec![9u16; 8 * 16 * 16];
        let bytes = roundtrip(&bins, 8, 16, 16, 8);
        assert!(bytes < 80, "{bytes}");
    }
}
