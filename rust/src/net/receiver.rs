//! The cloud side of the transport: accept, read one validated message
//! at a time, ACK good frames, NACK-and-drop on wire corruption,
//! suppress wire-v2 retransmits via the dedup window, and answer BUSY
//! when the caller's admission check sheds under overload.

use super::{dedup::DedupWindow, wire, Error, NetConfig, NetStats, Result};
use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::time::{Duration, Instant};

/// One successfully received frame, with the receive-side timestamps
/// the serving collector folds into its latency percentiles (so e2e
/// latency in TCP mode *includes* transport time).
#[derive(Debug)]
pub struct Received {
    /// The container frame bytes, verbatim as sent.
    pub frame: Vec<u8>,
    /// The wire-v2 sequence number (`None` for a v1 message).
    pub seq: Option<u64>,
    /// When the first header byte of this message was read.
    pub t_first_byte: Instant,
    /// When the message was fully read and validated.
    pub t_done: Instant,
}

/// Receives container frames from a [`super::FrameSender`].
///
/// [`Self::recv`] blocks for one message: it accepts a connection if
/// none is live (bounded by `accept_timeout`), reads and validates one
/// wire message (bounded by `read_timeout`), and answers ACK, NACK, or
/// BUSY. Error policy:
///
/// * idle timeouts (no connection, or a live but silent connection)
///   keep the connection and return [`Error::Timeout`];
/// * a clean close between messages drops the connection and returns
///   [`Error::ConnClosed`] — the next `recv` re-accepts, which is what
///   lets a sender reconnect mid-run;
/// * wire corruption ([`Error::Protocol`] / [`Error::TooLarge`]) and
///   mid-message truncation NACK (best effort) and drop the connection:
///   after a bad message the stream's framing cannot be trusted;
/// * a v2 message whose sequence number the [`DedupWindow`] already
///   holds is a retransmit whose ACK got lost: it is ACKed again (so
///   the sender stops resending) but never returned to the caller —
///   `recv` silently keeps reading, which is what makes delivery
///   exactly-once at the pipeline;
/// * when the admission check passed to [`Self::recv_admit`] refuses a
///   frame, the receiver answers BUSY, keeps the connection, and
///   returns [`Error::Busy`]. The sequence number is deliberately *not*
///   recorded, so a retransmit after the overload clears is fresh.
#[derive(Debug)]
pub struct FrameReceiver {
    listener: TcpListener,
    conn: Option<TcpStream>,
    cfg: NetConfig,
    stats: NetStats,
    dedup: DedupWindow,
}

/// Outcome of an exact read: how many bytes landed before the error.
// baf-lint: allow(raw-index) -- `filled < buf.len()` is the loop condition, so the slice start is always in range
fn read_full(stream: &mut TcpStream, buf: &mut [u8], what: &'static str) -> (usize, Option<Error>) {
    let mut filled = 0usize;
    while filled < buf.len() {
        match stream.read(&mut buf[filled..]) {
            Ok(0) => return (filled, Some(Error::ConnClosed { what })),
            Ok(n) => filled += n,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
            Err(e) => return (filled, Some(super::classify_io(what, &e))),
        }
    }
    (filled, None)
}

impl FrameReceiver {
    /// Bind the listening socket (use port 0 for an ephemeral port; see
    /// [`Self::local_addr`]).
    pub fn bind(addr: &str, cfg: NetConfig) -> Result<Self> {
        let listener = TcpListener::bind(addr)
            .map_err(|e| Error::Io(format!("binding {addr}: {e}")))?;
        listener
            .set_nonblocking(true)
            .map_err(|e| Error::Io(format!("listener options: {e}")))?;
        let dedup = DedupWindow::new(cfg.dedup_window);
        Ok(FrameReceiver { listener, conn: None, cfg, stats: NetStats::default(), dedup })
    }

    /// The bound address (resolves ephemeral ports).
    pub fn local_addr(&self) -> Result<SocketAddr> {
        self.listener
            .local_addr()
            .map_err(|e| Error::Io(format!("local_addr: {e}")))
    }

    /// Counter snapshot (frames/bytes in, rejects, timeouts).
    pub fn stats(&self) -> NetStats {
        self.stats
    }

    /// Drop the live connection (the next [`Self::recv`] re-accepts).
    /// Tests use this to force a sender into its reconnect path.
    pub fn disconnect(&mut self) {
        self.conn = None;
    }

    /// Poll-accept until a connection arrives or `accept_timeout` runs
    /// out. The listener stays non-blocking so shutdown never hangs in
    /// the kernel.
    fn accept(&mut self) -> Result<()> {
        let deadline = Instant::now() + self.cfg.accept_timeout;
        loop {
            match self.listener.accept() {
                Ok((stream, _peer)) => {
                    stream
                        .set_read_timeout(Some(self.cfg.read_timeout))
                        .and_then(|()| stream.set_write_timeout(Some(self.cfg.write_timeout)))
                        .and_then(|()| stream.set_nodelay(true))
                        .map_err(|e| Error::Io(format!("socket options: {e}")))?;
                    self.conn = Some(stream);
                    return Ok(());
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    if Instant::now() >= deadline {
                        self.stats.timeouts += 1;
                        return Err(Error::Timeout { what: "accept" });
                    }
                    std::thread::sleep(Duration::from_millis(5));
                }
                Err(e) => return Err(Error::Io(format!("accept: {e}"))),
            }
        }
    }

    /// Best-effort verdict byte; failures are ignored (the connection is
    /// being dropped anyway on NACK, and an unreadable ACK is the
    /// sender's timeout to handle).
    fn verdict(conn: &mut TcpStream, byte: u8) {
        let _ = conn.write_all(&[byte]);
    }

    /// Receive one frame (always admitted). See the type-level docs for
    /// the error policy.
    pub fn recv(&mut self) -> Result<Received> {
        self.recv_admit(&mut |_| true)
    }

    /// Receive one frame, consulting `admit` before accepting it: a
    /// refused frame is answered BUSY and surfaced as [`Error::Busy`]
    /// (the connection survives). The server's ingress queue is the
    /// admission check in TCP serving mode. Retransmitted duplicates
    /// are consumed (and ACKed) internally without being offered to
    /// `admit` — only fresh frames reach it.
    pub fn recv_admit(
        &mut self,
        admit: &mut dyn FnMut(&Received) -> bool,
    ) -> Result<Received> {
        loop {
            if self.conn.is_none() {
                self.accept()?;
            }
            let Some(mut conn) = self.conn.take() else {
                return Err(Error::ConnClosed { what: "no connection" });
            };
            match self.read_one(&mut conn) {
                Ok(r) => {
                    if let Some(seq) = r.seq {
                        if self.dedup.contains(seq) {
                            // retransmit of a frame already delivered:
                            // re-ACK so the sender stops resending, but
                            // never deliver it twice
                            Self::verdict(&mut conn, wire::ACK);
                            self.conn = Some(conn);
                            self.stats.duplicates += 1;
                            continue;
                        }
                    }
                    if !admit(&r) {
                        // overload: shed at admission. The seq was not
                        // observed, so a retransmit stays fresh.
                        Self::verdict(&mut conn, wire::BUSY);
                        self.conn = Some(conn);
                        self.stats.busy += 1;
                        return Err(Error::Busy);
                    }
                    if let Some(seq) = r.seq {
                        self.dedup.observe(seq);
                    }
                    Self::verdict(&mut conn, wire::ACK);
                    self.conn = Some(conn);
                    self.stats.frames += 1;
                    let hdr_len = if r.seq.is_some() {
                        wire::HEADER_V2_LEN
                    } else {
                        wire::HEADER_LEN
                    };
                    self.stats.bytes +=
                        (hdr_len + wire::CRC_LEN) as u64 + r.frame.len() as u64;
                    return Ok(r);
                }
                Err(e) => {
                    match &e {
                        // idle is benign: keep the connection for the next call
                        Error::Timeout { what } if *what == "message header" => {
                            self.stats.timeouts += 1;
                            self.conn = Some(conn);
                        }
                        Error::Timeout { .. } => {
                            // mid-message stall: framing lost, drop the conn
                            self.stats.timeouts += 1;
                            Self::verdict(&mut conn, wire::NACK);
                        }
                        Error::Protocol(_) | Error::TooLarge { .. } => {
                            self.stats.rejected += 1;
                            Self::verdict(&mut conn, wire::NACK);
                        }
                        // closed (cleanly or mid-message): nothing to answer
                        _ => {}
                    }
                    return Err(e);
                }
            }
        }
    }

    /// Read and validate exactly one wire message (either version) from
    /// `conn`.
    fn read_one(&mut self, conn: &mut TcpStream) -> Result<Received> {
        let mut hdr = [0u8; wire::HEADER_V2_LEN];
        // the version-independent prefix first; the version byte then
        // says how much more header follows
        let mut prefix = [0u8; wire::PREFIX_LEN];
        match read_full(conn, &mut prefix, "message header") {
            (_, None) => {}
            // zero bytes read: the connection was merely idle (benign
            // timeout) or closed cleanly between messages
            (0, Some(Error::ConnClosed { .. })) => {
                return Err(Error::ConnClosed { what: "between messages" });
            }
            (0, Some(Error::Timeout { .. })) => {
                return Err(Error::Timeout { what: "message header" });
            }
            // a partial header means framing is lost: recv() must drop
            // the connection, so these must NOT look like idle errors
            (_, Some(Error::ConnClosed { .. })) => {
                return Err(Error::ConnClosed { what: "mid-message" });
            }
            (_, Some(Error::Timeout { .. })) => {
                return Err(Error::Timeout { what: "mid-header" });
            }
            (_, Some(e)) => return Err(e),
        }
        // the prefix is in hand just now: this timestamps the start of
        // the message for the transport-inclusive latency accounting
        let t_first_byte = Instant::now();
        let version = wire::validate_prefix(&prefix)?;
        let hdr_len = wire::header_len_for(version);
        hdr[..wire::PREFIX_LEN].copy_from_slice(&prefix);
        let tail = hdr
            .get_mut(wire::PREFIX_LEN..hdr_len)
            .ok_or(Error::ConnClosed { what: "impossible header length" })?;
        if let (_, Some(e)) = read_full(conn, tail, "header tail") {
            return Err(match e {
                Error::ConnClosed { .. } => Error::ConnClosed { what: "mid-message" },
                Error::Timeout { .. } => Error::Timeout { what: "mid-header" },
                other => other,
            });
        }
        let head = hdr
            .get(..hdr_len)
            .ok_or(Error::ConnClosed { what: "impossible header length" })?;
        let (seq, len) = wire::parse_header(head)?;
        // bounded by MAX_FRAME_LEN (parse_header) before this alloc
        let mut payload = vec![0u8; len];
        if let (_, Some(e)) = read_full(conn, &mut payload, "message payload") {
            return Err(match e {
                Error::ConnClosed { .. } => Error::ConnClosed { what: "mid-message" },
                Error::Timeout { .. } => Error::Timeout { what: "message payload" },
                other => other,
            });
        }
        let mut trailer = [0u8; wire::CRC_LEN];
        if let (_, Some(e)) = read_full(conn, &mut trailer, "message crc") {
            return Err(match e {
                Error::ConnClosed { .. } => Error::ConnClosed { what: "mid-message" },
                Error::Timeout { .. } => Error::Timeout { what: "message crc" },
                other => other,
            });
        }
        // the wire CRC covers header + payload; hash the two pieces in
        // sequence instead of concatenating them (one copy fewer)
        wire::check_crc_parts(head, &payload, &trailer)?;
        Ok(Received { frame: payload, seq, t_first_byte, t_done: Instant::now() })
    }

    /// [`Self::recv`] plus container parsing: the typed
    /// [`Error::Codec`] path for callers that want the frame validated
    /// end to end. A codec-level failure does *not* drop the connection
    /// (the wire framing was intact), so streaming continues.
    pub fn recv_parsed(&mut self) -> Result<(Received, crate::codec::container::Frame)> {
        let r = self.recv()?;
        let frame = crate::codec::container::parse(&r.frame)?;
        Ok((r, frame))
    }
}

#[cfg(test)]
mod tests {
    #![allow(clippy::unwrap_used, clippy::expect_used)]

    use super::*;
    use crate::net::FrameSender;

    fn fast_cfg() -> NetConfig {
        NetConfig {
            connect_timeout: Duration::from_millis(200),
            read_timeout: Duration::from_millis(200),
            write_timeout: Duration::from_millis(200),
            accept_timeout: Duration::from_millis(200),
            max_reconnects: 2,
            backoff_base: Duration::from_millis(5),
            backoff_max: Duration::from_millis(20),
            seed: 3,
            breaker_threshold: 0,
            breaker_cooldown: Duration::from_millis(100),
            dedup_window: 64,
        }
    }

    #[test]
    fn accept_timeout_is_typed_and_keeps_listening() {
        let mut rx = FrameReceiver::bind("127.0.0.1:0", fast_cfg()).unwrap();
        let err = rx.recv().unwrap_err();
        assert!(matches!(err, Error::Timeout { what: "accept" }), "{err}");
        assert_eq!(rx.stats().timeouts, 1);
        // the listener is still usable afterwards
        assert!(rx.local_addr().is_ok());
    }

    #[test]
    fn one_frame_roundtrip_with_timestamps() {
        let mut rx = FrameReceiver::bind("127.0.0.1:0", fast_cfg()).unwrap();
        let addr = rx.local_addr().unwrap().to_string();
        let payload: Vec<u8> = (0..200u8).collect();
        let sent = payload.clone();
        let tx_thread = std::thread::spawn(move || {
            let mut tx = FrameSender::connect(&addr, fast_cfg()).unwrap();
            tx.send(&sent).unwrap();
            tx.stats()
        });
        let got = rx.recv().unwrap();
        assert_eq!(got.frame, payload);
        assert!(got.t_done >= got.t_first_byte);
        let st = tx_thread.join().unwrap();
        assert_eq!(st.frames, 1);
        assert_eq!(rx.stats().frames, 1);
        assert_eq!(rx.stats().bytes, st.bytes, "both sides count the same wire bytes");
    }

    #[test]
    fn idle_connection_timeout_does_not_drop_the_conn() {
        let mut rx = FrameReceiver::bind("127.0.0.1:0", fast_cfg()).unwrap();
        let addr = rx.local_addr().unwrap().to_string();
        let (stop_tx, stop_rx) = std::sync::mpsc::channel::<()>();
        let tx_thread = std::thread::spawn(move || {
            let mut tx = FrameSender::connect(&addr, fast_cfg()).unwrap();
            // stay connected but silent past the read timeout, then send
            stop_rx.recv().unwrap();
            tx.send(&[9u8; 16]).unwrap();
        });
        let err = rx.recv().unwrap_err();
        assert!(matches!(err, Error::Timeout { what: "message header" }), "{err}");
        stop_tx.send(()).unwrap();
        let got = rx.recv().unwrap();
        assert_eq!(got.frame, vec![9u8; 16]);
        tx_thread.join().unwrap();
    }

    #[test]
    fn retransmitted_v2_frame_is_acked_but_delivered_once() {
        let mut rx = FrameReceiver::bind("127.0.0.1:0", fast_cfg()).unwrap();
        let addr = rx.local_addr().unwrap().to_string();
        let client = std::thread::spawn(move || {
            let mut s = TcpStream::connect(addr).unwrap();
            let msg = wire::encode_msg_v2(&[1, 2, 3], 7);
            let mut v = [0u8; 1];
            // original
            s.write_all(&msg).unwrap();
            s.read_exact(&mut v).unwrap();
            assert_eq!(v[0], wire::ACK);
            // retransmit after a "lost" ACK: byte-identical message
            s.write_all(&msg).unwrap();
            s.read_exact(&mut v).unwrap();
            assert_eq!(v[0], wire::ACK, "a duplicate must still be ACKed");
            // the stream continues with a fresh seq
            s.write_all(&wire::encode_msg_v2(&[4, 5, 6], 8)).unwrap();
            s.read_exact(&mut v).unwrap();
            assert_eq!(v[0], wire::ACK);
        });
        let a = rx.recv().unwrap();
        assert_eq!(a.frame, vec![1, 2, 3]);
        assert_eq!(a.seq, Some(7));
        // the next recv skips the duplicate internally and returns the
        // fresh frame behind it
        let b = rx.recv().unwrap();
        assert_eq!(b.frame, vec![4, 5, 6]);
        assert_eq!(b.seq, Some(8));
        let st = rx.stats();
        assert_eq!(st.duplicates, 1);
        assert_eq!(st.frames, 2, "the duplicate is not counted as a delivery");
        client.join().unwrap();
    }

    #[test]
    fn v1_messages_still_parse_and_bypass_dedup() {
        let mut rx = FrameReceiver::bind("127.0.0.1:0", fast_cfg()).unwrap();
        let addr = rx.local_addr().unwrap().to_string();
        let client = std::thread::spawn(move || {
            let mut s = TcpStream::connect(addr).unwrap();
            let msg = wire::encode_msg(&[9, 9]);
            let mut v = [0u8; 1];
            // identical v1 messages carry no seq: both are delivered
            for _ in 0..2 {
                s.write_all(&msg).unwrap();
                s.read_exact(&mut v).unwrap();
                assert_eq!(v[0], wire::ACK);
            }
        });
        for _ in 0..2 {
            let r = rx.recv().unwrap();
            assert_eq!(r.frame, vec![9, 9]);
            assert_eq!(r.seq, None);
        }
        assert_eq!(rx.stats().duplicates, 0);
        assert_eq!(rx.stats().frames, 2);
        client.join().unwrap();
    }

    #[test]
    fn busy_rejection_keeps_conn_and_does_not_poison_dedup() {
        let mut rx = FrameReceiver::bind("127.0.0.1:0", fast_cfg()).unwrap();
        let addr = rx.local_addr().unwrap().to_string();
        let client = std::thread::spawn(move || {
            let mut s = TcpStream::connect(addr).unwrap();
            let msg = wire::encode_msg_v2(&[5, 5, 5], 42);
            let mut v = [0u8; 1];
            s.write_all(&msg).unwrap();
            s.read_exact(&mut v).unwrap();
            assert_eq!(v[0], wire::BUSY, "overload must answer BUSY, not NACK");
            // retransmit once the overload clears: must be fresh
            s.write_all(&msg).unwrap();
            s.read_exact(&mut v).unwrap();
            assert_eq!(v[0], wire::ACK);
        });
        let err = rx.recv_admit(&mut |_| false).unwrap_err();
        assert!(matches!(err, Error::Busy), "{err}");
        let got = rx.recv().unwrap();
        assert_eq!(got.frame, vec![5, 5, 5]);
        assert_eq!(got.seq, Some(42));
        let st = rx.stats();
        assert_eq!(st.busy, 1);
        assert_eq!(st.frames, 1);
        assert_eq!(st.duplicates, 0, "a BUSY-shed frame is not a duplicate");
        client.join().unwrap();
    }
}
