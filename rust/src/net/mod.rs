//! The edge→cloud network transport: container frames over TCP.
//!
//! Until now the E5 pipeline moved frames between the edge and cloud
//! stages over an in-process `mpsc` channel — the lossy
//! bandwidth-constrained link the paper's whole premise rests on was
//! simulated. This module makes it real with a dependency-free
//! `std::net` transport:
//!
//! * [`wire`] — the length-prefixed message layout (magic + version +
//!   frame_len + container frame + per-message CRC32, then a one-byte
//!   ACK/NACK from the receiver);
//! * [`FrameSender`] — the edge side: connects, sends framed messages,
//!   waits for the ACK, and survives disconnects with bounded
//!   exponential backoff (jittered via [`crate::util::SplitMix64`]);
//! * [`FrameReceiver`] — the cloud side: accepts, reads and validates
//!   messages with read timeouts, acks good frames, nacks and drops the
//!   connection on wire corruption (framing can't be trusted after a
//!   bad message);
//! * [`dedup`] — the bounded sequence-number window that turns the
//!   sender's at-least-once retry loop into exactly-once delivery at
//!   the pipeline (wire v2 carries a per-stream `u64` seq);
//! * [`chaos`] — a deterministic userspace loopback shim that injects
//!   latency, throttling, fragmentation, corruption, resets, and stalls
//!   between the two ends, so the soak tests exercise the transport
//!   under the packet-level faults it exists to survive.
//!
//! # Error handling & robustness
//!
//! The receiver is fed bytes it does not control, so the same totality
//! contract as [`crate::codec`] applies: every failure is a typed
//! [`Error`] — [`Error::Timeout`], [`Error::ConnClosed`],
//! [`Error::Protocol`], [`Error::TooLarge`] (checked against
//! [`wire::MAX_FRAME_LEN`], derived from
//! [`crate::codec::MAX_DECODED_SAMPLES`], *before* any allocation), or
//! [`Error::Codec`] wrapping the container decode error — never a
//! panic, never an unbounded allocation. `tests/transport_robustness.rs`
//! drives the wire-level fault generators
//! ([`crate::codec::faultgen::wire_mutations`]) plus mid-stream
//! disconnects and stalls over a loopback socket to enforce it.

pub mod chaos;
pub mod dedup;
pub mod receiver;
pub mod sender;
pub mod wire;

pub use chaos::{ChaosConfig, ChaosProxy};
pub use dedup::DedupWindow;
pub use receiver::{FrameReceiver, Received};
pub use sender::FrameSender;

use std::fmt;
use std::time::Duration;

/// Typed transport error taxonomy. Mirrors [`crate::codec::Error`]'s
/// role for the decode path: the serving loop matches on the variant to
/// decide between retrying, re-accepting, and dropping a frame.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Error {
    /// A read or write did not complete within the configured timeout.
    Timeout { what: &'static str },
    /// The peer closed the connection (cleanly between messages, or
    /// mid-message — `what` says which).
    ConnClosed { what: &'static str },
    /// Structurally invalid wire bytes: bad magic, unknown version,
    /// message CRC mismatch, or a rejected (NACKed) frame.
    Protocol(String),
    /// The length prefix asks for more than [`wire::MAX_FRAME_LEN`];
    /// rejected before any allocation.
    TooLarge { requested: usize, limit: usize },
    /// The wire message was intact but the container frame inside it
    /// failed to decode.
    Codec(crate::codec::Error),
    /// The receiver answered [`wire::BUSY`]: the frame was wire-valid
    /// but shed at ingress admission because the server is saturated.
    /// Not retried — retrying into an overloaded server makes the
    /// overload worse; the caller counts the frame as shed.
    Busy,
    /// The sender's circuit breaker is open after repeated whole-budget
    /// delivery failures; the frame was shed at the edge without
    /// touching the socket, so the arrival process is never blocked by
    /// a dead link.
    BreakerOpen,
    /// Any other socket-level failure (resolve, bind, connect refused).
    Io(String),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::Timeout { what } => write!(f, "net timeout: {what}"),
            Error::ConnClosed { what } => write!(f, "connection closed: {what}"),
            Error::Protocol(msg) => write!(f, "wire protocol error: {msg}"),
            Error::TooLarge { requested, limit } => {
                write!(f, "wire frame too large: {requested} > {limit}")
            }
            Error::Codec(e) => write!(f, "frame decode failed: {e}"),
            Error::Busy => write!(f, "receiver busy: frame shed at ingress"),
            Error::BreakerOpen => {
                write!(f, "circuit breaker open: frame shed at the edge")
            }
            Error::Io(msg) => write!(f, "net i/o error: {msg}"),
        }
    }
}

impl std::error::Error for Error {}

impl From<crate::codec::Error> for Error {
    fn from(e: crate::codec::Error) -> Self {
        Error::Codec(e)
    }
}

/// Transport result type.
pub type Result<T> = std::result::Result<T, Error>;

/// Classify an `std::io::Error` into the transport taxonomy.
pub(crate) fn classify_io(what: &'static str, e: &std::io::Error) -> Error {
    use std::io::ErrorKind;
    match e.kind() {
        ErrorKind::WouldBlock | ErrorKind::TimedOut => Error::Timeout { what },
        ErrorKind::UnexpectedEof
        | ErrorKind::ConnectionReset
        | ErrorKind::ConnectionAborted
        | ErrorKind::BrokenPipe
        | ErrorKind::NotConnected => Error::ConnClosed { what },
        _ => Error::Io(format!("{what}: {e}")),
    }
}

/// Transport tunables. One struct serves both ends; the receiver only
/// reads the `*_timeout` fields, the sender also uses the reconnect
/// policy.
#[derive(Debug, Clone)]
pub struct NetConfig {
    /// Per-attempt TCP connect timeout (sender).
    pub connect_timeout: Duration,
    /// Socket read timeout: ack reads on the sender, message reads on
    /// the receiver. An idle receiver surfaces this as
    /// [`Error::Timeout`] without dropping the connection.
    pub read_timeout: Duration,
    /// Socket write timeout.
    pub write_timeout: Duration,
    /// How long the receiver polls for an incoming connection before
    /// reporting [`Error::Timeout`].
    pub accept_timeout: Duration,
    /// Maximum reconnect attempts per send before the typed error is
    /// returned to the caller (bounds the retry loop).
    pub max_reconnects: u32,
    /// First reconnect delay; doubles per attempt (exponential backoff).
    pub backoff_base: Duration,
    /// Backoff ceiling.
    pub backoff_max: Duration,
    /// Seed for the jitter PRNG (deterministic backoff in tests).
    pub seed: u64,
    /// Sender circuit breaker: consecutive sends that exhaust the whole
    /// `max_reconnects` budget before the breaker opens and frames are
    /// shed at the edge instead of blocking on a dead link. 0 disables
    /// the breaker.
    pub breaker_threshold: u32,
    /// How long an open breaker sheds before allowing one half-open
    /// probe send (single attempt, no backoff loop).
    pub breaker_cooldown: Duration,
    /// Receiver dedup window capacity: how many recent v2 sequence
    /// numbers are remembered to suppress retransmitted duplicates.
    pub dedup_window: usize,
}

impl Default for NetConfig {
    fn default() -> Self {
        NetConfig {
            connect_timeout: Duration::from_secs(2),
            read_timeout: Duration::from_secs(5),
            write_timeout: Duration::from_secs(5),
            accept_timeout: Duration::from_secs(10),
            max_reconnects: 8,
            backoff_base: Duration::from_millis(50),
            backoff_max: Duration::from_secs(2),
            seed: 0xBAF_0E7,
            breaker_threshold: 3,
            breaker_cooldown: Duration::from_millis(500),
            dedup_window: 1024,
        }
    }
}

/// Transport-side counters, exported by the coordinator as `net_*`
/// metrics. Plain values (single-threaded owners); snapshot with
/// [`FrameSender::stats`] / [`FrameReceiver::stats`].
#[derive(Debug, Default, Clone, Copy)]
pub struct NetStats {
    /// Frames successfully transferred (acked).
    pub frames: u64,
    /// Wire bytes moved (header + payload + CRC, both directions' view
    /// of its own side).
    pub bytes: u64,
    /// Sender: reconnect attempts performed.
    pub reconnects: u64,
    /// Read/write timeouts observed.
    pub timeouts: u64,
    /// Receiver: messages rejected at the wire layer (bad magic/CRC/
    /// oversized length).
    pub rejected: u64,
    /// Receiver: v2 retransmits recognized by the dedup window — ACKed
    /// but not delivered a second time.
    pub duplicates: u64,
    /// Receiver: frames answered BUSY at admission. Sender: BUSY
    /// verdicts received.
    pub busy: u64,
    /// Sender: frames shed by the open circuit breaker without touching
    /// the socket.
    pub shed: u64,
    /// Sender: times the circuit breaker opened.
    pub breaker_opens: u64,
}

impl NetStats {
    /// Publish the sender-side view into a metrics registry.
    pub fn export_sender_into(&self, r: &crate::metrics::Registry) {
        r.counter("net_frames_out").add(self.frames);
        r.counter("net_bytes_out").add(self.bytes);
        r.counter("net_reconnects").add(self.reconnects);
        r.counter("net_timeouts").add(self.timeouts);
        r.counter("net_frames_busy").add(self.busy);
        r.counter("net_frames_shed_breaker").add(self.shed);
        r.counter("net_breaker_opens").add(self.breaker_opens);
    }

    /// Publish the receiver-side view into a metrics registry.
    pub fn export_receiver_into(&self, r: &crate::metrics::Registry) {
        r.counter("net_frames_in").add(self.frames);
        r.counter("net_bytes_in").add(self.bytes);
        r.counter("net_frames_rejected").add(self.rejected);
        r.counter("net_timeouts").add(self.timeouts);
        r.counter("net_frames_duplicate").add(self.duplicates);
        r.counter("net_frames_busy_answered").add(self.busy);
    }
}

#[cfg(test)]
mod tests {
    #![allow(clippy::unwrap_used, clippy::expect_used)]

    use super::*;

    #[test]
    fn error_display_names_the_failure() {
        assert!(Error::Timeout { what: "ack" }.to_string().contains("ack"));
        assert!(Error::TooLarge { requested: 9, limit: 4 }
            .to_string()
            .contains("9 > 4"));
        let e: Error = crate::codec::Error::Corrupt("x".into()).into();
        assert!(matches!(e, Error::Codec(_)));
        assert!(e.to_string().contains("decode failed"));
    }

    #[test]
    fn io_classification() {
        use std::io::{Error as IoError, ErrorKind};
        assert!(matches!(
            classify_io("read", &IoError::new(ErrorKind::TimedOut, "t")),
            Error::Timeout { .. }
        ));
        assert!(matches!(
            classify_io("read", &IoError::new(ErrorKind::ConnectionReset, "r")),
            Error::ConnClosed { .. }
        ));
        assert!(matches!(
            classify_io("bind", &IoError::new(ErrorKind::AddrInUse, "a")),
            Error::Io(_)
        ));
    }

    #[test]
    fn stats_export_uses_net_prefix() {
        let r = crate::metrics::Registry::default();
        let st = NetStats {
            frames: 3,
            bytes: 100,
            reconnects: 1,
            timeouts: 2,
            rejected: 4,
            duplicates: 5,
            busy: 6,
            shed: 7,
            breaker_opens: 8,
        };
        st.export_sender_into(&r);
        st.export_receiver_into(&r);
        let v = r.export();
        let c = v.get("counters").unwrap();
        assert_eq!(c.get("net_frames_out").unwrap().as_usize(), Some(3));
        assert_eq!(c.get("net_bytes_in").unwrap().as_usize(), Some(100));
        assert_eq!(c.get("net_reconnects").unwrap().as_usize(), Some(1));
        assert_eq!(c.get("net_frames_rejected").unwrap().as_usize(), Some(4));
        assert_eq!(c.get("net_timeouts").unwrap().as_usize(), Some(4));
        assert_eq!(c.get("net_frames_duplicate").unwrap().as_usize(), Some(5));
        assert_eq!(c.get("net_frames_busy").unwrap().as_usize(), Some(6));
        assert_eq!(c.get("net_frames_busy_answered").unwrap().as_usize(), Some(6));
        assert_eq!(c.get("net_frames_shed_breaker").unwrap().as_usize(), Some(7));
        assert_eq!(c.get("net_breaker_opens").unwrap().as_usize(), Some(8));
    }

    #[test]
    fn new_error_variants_display() {
        assert!(Error::Busy.to_string().contains("shed at ingress"));
        assert!(Error::BreakerOpen.to_string().contains("breaker open"));
    }
}
