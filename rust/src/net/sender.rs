//! The edge side of the transport: connect, send, wait for the ACK,
//! reconnect with bounded jittered exponential backoff.

use super::{classify_io, wire, Error, NetConfig, NetStats, Result};
use crate::util::SplitMix64;
use std::io::{Read, Write};
use std::net::{SocketAddr, TcpStream, ToSocketAddrs};
use std::time::Duration;

/// Sends container frames to a [`super::FrameReceiver`].
///
/// Delivery is at-least-once: a frame is only counted sent once its ACK
/// arrives, and a connection failure anywhere in the write→ack window
/// triggers reconnect-and-resend (bounded by
/// [`NetConfig::max_reconnects`], delayed by exponential backoff with
/// jitter from [`SplitMix64`] so a fleet of edges doesn't reconnect in
/// lockstep). A NACK is returned as [`Error::Protocol`] without retry —
/// the receiver rejected the bytes deterministically.
#[derive(Debug)]
pub struct FrameSender {
    addr: String,
    cfg: NetConfig,
    stream: Option<TcpStream>,
    rng: SplitMix64,
    stats: NetStats,
}

impl FrameSender {
    /// Resolve `addr` and establish the first connection (retrying with
    /// backoff like any later reconnect).
    pub fn connect(addr: &str, cfg: NetConfig) -> Result<Self> {
        let rng = SplitMix64::new(cfg.seed);
        let mut s = FrameSender {
            addr: addr.to_string(),
            cfg,
            stream: None,
            rng,
            stats: NetStats::default(),
        };
        let mut last = Error::Io(format!("never attempted {}", s.addr));
        for attempt in 0..=s.cfg.max_reconnects {
            if attempt > 0 {
                s.stats.reconnects += 1;
                let d = s.backoff_delay(attempt - 1);
                std::thread::sleep(d);
            }
            match s.ensure_connected() {
                Ok(()) => return Ok(s),
                Err(e) => {
                    if matches!(e, Error::Timeout { .. }) {
                        s.stats.timeouts += 1;
                    }
                    last = e;
                }
            }
        }
        Err(last)
    }

    /// Counter snapshot (frames/bytes out, reconnects, timeouts).
    pub fn stats(&self) -> NetStats {
        self.stats
    }

    fn resolve(&self) -> Result<SocketAddr> {
        self.addr
            .to_socket_addrs()
            .map_err(|e| Error::Io(format!("resolving {}: {e}", self.addr)))?
            .next()
            .ok_or_else(|| Error::Io(format!("{} resolves to no address", self.addr)))
    }

    fn dial(&self) -> Result<TcpStream> {
        let sa = self.resolve()?;
        let stream = TcpStream::connect_timeout(&sa, self.cfg.connect_timeout)
            .map_err(|e| classify_io("connect", &e))?;
        stream
            .set_read_timeout(Some(self.cfg.read_timeout))
            .and_then(|()| stream.set_write_timeout(Some(self.cfg.write_timeout)))
            .and_then(|()| stream.set_nodelay(true))
            .map_err(|e| Error::Io(format!("socket options: {e}")))?;
        Ok(stream)
    }

    /// Backoff delay before reconnect attempt `attempt` (0-based):
    /// `base * 2^attempt`, capped at `backoff_max`, jittered by a factor
    /// in [0.5, 1.5).
    fn backoff_delay(&mut self, attempt: u32) -> Duration {
        let exp = self.cfg.backoff_base.saturating_mul(1u32 << attempt.min(16));
        let capped = exp.min(self.cfg.backoff_max);
        let jitter = 0.5 + self.rng.next_f64();
        Duration::from_secs_f64(capped.as_secs_f64() * jitter)
    }

    /// One connect attempt if currently disconnected. The retry/backoff
    /// loops live in [`Self::connect`] and [`Self::send`], so the retry
    /// budget is never nested.
    fn ensure_connected(&mut self) -> Result<()> {
        if self.stream.is_some() {
            return Ok(());
        }
        let s = self.dial()?;
        self.stream = Some(s);
        Ok(())
    }

    /// One write→ack exchange on the current connection.
    fn try_send(&mut self, msg: &[u8]) -> Result<()> {
        let stream = self.stream.as_mut().ok_or(Error::ConnClosed { what: "no connection" })?;
        stream.write_all(msg).map_err(|e| classify_io("frame write", &e))?;
        let mut verdict = [0u8; 1];
        // a clean EOF here means the receiver died between write and ack
        match stream.read(&mut verdict) {
            Ok(0) => Err(Error::ConnClosed { what: "awaiting ack" }),
            Ok(_) => match verdict[0] {
                wire::ACK => Ok(()),
                wire::NACK => Err(Error::Protocol(
                    "receiver rejected the frame (NACK)".to_string(),
                )),
                other => Err(Error::Protocol(format!("unknown ack byte {other:#04x}"))),
            },
            Err(e) => Err(classify_io("ack read", &e)),
        }
    }

    /// Send one container frame and wait for the receiver's ACK.
    ///
    /// Connection-level failures (closed, reset, timed out) drop the
    /// socket and retry through the reconnect/backoff loop; after
    /// `max_reconnects` failed attempts the last typed error is
    /// returned. [`Error::Protocol`] (NACK) is returned immediately.
    pub fn send(&mut self, frame: &[u8]) -> Result<()> {
        let msg = wire::encode_msg(frame);
        let mut last = Error::ConnClosed { what: "never attempted" };
        for attempt in 0..=self.cfg.max_reconnects {
            if attempt > 0 {
                self.stats.reconnects += 1;
                std::thread::sleep(self.backoff_delay(attempt - 1));
            }
            if let Err(e) = self.ensure_connected() {
                // receiver may be mid-restart: keep retrying on backoff
                if matches!(e, Error::Timeout { .. }) {
                    self.stats.timeouts += 1;
                }
                last = e;
                continue;
            }
            match self.try_send(&msg) {
                Ok(()) => {
                    self.stats.frames += 1;
                    self.stats.bytes += msg.len() as u64;
                    return Ok(());
                }
                Err(Error::Protocol(p)) => {
                    // deterministic rejection: resending the same bytes
                    // cannot succeed, surface it to the caller
                    self.stream = None;
                    return Err(Error::Protocol(p));
                }
                Err(e) => {
                    if matches!(e, Error::Timeout { .. }) {
                        self.stats.timeouts += 1;
                    }
                    self.stream = None;
                    last = e;
                }
            }
        }
        Err(last)
    }

    /// Drop the current connection (next send reconnects).
    pub fn disconnect(&mut self) {
        self.stream = None;
    }
}

#[cfg(test)]
mod tests {
    #![allow(clippy::unwrap_used, clippy::expect_used)]

    use super::*;
    use std::net::TcpListener;

    fn fast_cfg() -> NetConfig {
        NetConfig {
            connect_timeout: Duration::from_millis(200),
            read_timeout: Duration::from_millis(300),
            write_timeout: Duration::from_millis(300),
            accept_timeout: Duration::from_millis(300),
            max_reconnects: 2,
            backoff_base: Duration::from_millis(5),
            backoff_max: Duration::from_millis(20),
            seed: 1,
        }
    }

    #[test]
    fn connect_to_dead_port_fails_with_typed_error_after_bounded_retries() {
        // bind then drop: the port is (almost certainly) closed
        let addr = {
            let l = TcpListener::bind("127.0.0.1:0").unwrap();
            l.local_addr().unwrap().to_string()
        };
        let t0 = std::time::Instant::now();
        let err = FrameSender::connect(&addr, fast_cfg()).unwrap_err();
        assert!(
            matches!(err, Error::Io(_) | Error::Timeout { .. } | Error::ConnClosed { .. }),
            "unexpected error class: {err}"
        );
        // 3 attempts with ~5/10ms backoffs: well under a second
        assert!(t0.elapsed() < Duration::from_secs(5));
    }

    #[test]
    fn unresolvable_address_is_io_error() {
        let err = FrameSender::connect("definitely-not-a-host-xyz:1", fast_cfg());
        assert!(matches!(err, Err(Error::Io(_))));
    }

    #[test]
    fn nack_is_protocol_error_without_retry() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let server = std::thread::spawn(move || {
            let (mut conn, _) = listener.accept().unwrap();
            let mut buf = vec![0u8; wire::HEADER_LEN + 3 + wire::CRC_LEN];
            conn.read_exact(&mut buf).unwrap();
            conn.write_all(&[wire::NACK]).unwrap();
        });
        let mut tx = FrameSender::connect(&addr, fast_cfg()).unwrap();
        let err = tx.send(&[1, 2, 3]).unwrap_err();
        assert!(matches!(err, Error::Protocol(_)), "{err}");
        assert_eq!(tx.stats().frames, 0, "a NACKed frame must not count as sent");
        server.join().unwrap();
    }

    #[test]
    fn backoff_grows_and_is_jittered_within_bounds() {
        let addr = {
            let l = TcpListener::bind("127.0.0.1:0").unwrap();
            l.local_addr().unwrap().to_string()
        };
        let mut s = FrameSender {
            addr,
            cfg: NetConfig {
                backoff_base: Duration::from_millis(100),
                backoff_max: Duration::from_secs(60),
                ..fast_cfg()
            },
            stream: None,
            rng: SplitMix64::new(7),
            stats: NetStats::default(),
        };
        for attempt in 0..6u32 {
            let nominal = 100.0e-3 * f64::from(1u32 << attempt);
            let d = s.backoff_delay(attempt).as_secs_f64();
            assert!(
                d >= nominal * 0.5 && d < nominal * 1.5,
                "attempt {attempt}: {d}s outside [{:.3}, {:.3})",
                nominal * 0.5,
                nominal * 1.5
            );
        }
        // the cap holds even for absurd attempt counts (no overflow)
        let capped = s.backoff_delay(40);
        assert!(capped < Duration::from_secs(91));
    }
}
