//! The edge side of the transport: connect, send, wait for the ACK,
//! reconnect with bounded jittered exponential backoff.

use super::{classify_io, wire, Error, NetConfig, NetStats, Result};
use crate::util::SplitMix64;
use std::io::{Read, Write};
use std::net::{SocketAddr, TcpStream, ToSocketAddrs};
use std::time::{Duration, Instant};

/// Sends container frames to a [`super::FrameReceiver`].
///
/// Delivery is at-least-once on the wire: a frame is only counted sent
/// once its ACK arrives, and a connection failure anywhere in the
/// write→ack window triggers reconnect-and-resend (bounded by
/// [`NetConfig::max_reconnects`], delayed by exponential backoff with
/// jitter from [`SplitMix64`] so a fleet of edges doesn't reconnect in
/// lockstep). Every message carries a wire-v2 sequence number from a
/// per-sender monotone stream — retransmits of one frame reuse the same
/// number, which is what lets the receiver's dedup window turn
/// at-least-once into exactly-once delivery at the pipeline.
///
/// Two verdicts short-circuit the retry loop: a NACK is returned as
/// [`Error::Protocol`] (the receiver rejected the bytes
/// deterministically — resending cannot succeed) and a BUSY as
/// [`Error::Busy`] (the receiver shed the frame under overload —
/// retrying into a saturated server makes it worse).
///
/// A circuit breaker guards the arrival process against a dead link:
/// after [`NetConfig::breaker_threshold`] consecutive sends that each
/// burned the whole reconnect budget, the breaker opens and subsequent
/// frames are shed immediately ([`Error::BreakerOpen`]) for
/// [`NetConfig::breaker_cooldown`], after which a single half-open
/// probe send (one attempt, no backoff loop) decides whether to close
/// it again.
#[derive(Debug)]
pub struct FrameSender {
    addr: String,
    cfg: NetConfig,
    stream: Option<TcpStream>,
    rng: SplitMix64,
    stats: NetStats,
    /// Next wire-v2 sequence number; allocated once per `send` call so
    /// retransmits inside the call share it.
    next_seq: u64,
    /// Consecutive `send` calls that exhausted the whole retry budget.
    consec_failures: u32,
    /// While `Some(t)` and `now < t`, the breaker is open and frames
    /// are shed; past `t` the next send is a half-open probe.
    open_until: Option<Instant>,
}

impl FrameSender {
    /// Resolve `addr` and establish the first connection (retrying with
    /// backoff like any later reconnect).
    pub fn connect(addr: &str, cfg: NetConfig) -> Result<Self> {
        let rng = SplitMix64::new(cfg.seed);
        let mut s = FrameSender {
            addr: addr.to_string(),
            cfg,
            stream: None,
            rng,
            stats: NetStats::default(),
            next_seq: 1,
            consec_failures: 0,
            open_until: None,
        };
        let mut last = Error::Io(format!("never attempted {}", s.addr));
        for attempt in 0..=s.cfg.max_reconnects {
            if attempt > 0 {
                s.stats.reconnects += 1;
                let d = s.backoff_delay(attempt - 1);
                std::thread::sleep(d);
            }
            match s.ensure_connected() {
                Ok(()) => return Ok(s),
                Err(e) => {
                    if matches!(e, Error::Timeout { .. }) {
                        s.stats.timeouts += 1;
                    }
                    last = e;
                }
            }
        }
        Err(last)
    }

    /// Counter snapshot (frames/bytes out, reconnects, timeouts).
    pub fn stats(&self) -> NetStats {
        self.stats
    }

    fn resolve(&self) -> Result<SocketAddr> {
        self.addr
            .to_socket_addrs()
            .map_err(|e| Error::Io(format!("resolving {}: {e}", self.addr)))?
            .next()
            .ok_or_else(|| Error::Io(format!("{} resolves to no address", self.addr)))
    }

    fn dial(&self) -> Result<TcpStream> {
        let sa = self.resolve()?;
        let stream = TcpStream::connect_timeout(&sa, self.cfg.connect_timeout)
            .map_err(|e| classify_io("connect", &e))?;
        stream
            .set_read_timeout(Some(self.cfg.read_timeout))
            .and_then(|()| stream.set_write_timeout(Some(self.cfg.write_timeout)))
            .and_then(|()| stream.set_nodelay(true))
            .map_err(|e| Error::Io(format!("socket options: {e}")))?;
        Ok(stream)
    }

    /// Backoff delay before reconnect attempt `attempt` (0-based):
    /// `base * 2^attempt`, capped at `backoff_max`, jittered by a factor
    /// in [0.5, 1.5).
    fn backoff_delay(&mut self, attempt: u32) -> Duration {
        let exp = self.cfg.backoff_base.saturating_mul(1u32 << attempt.min(16));
        let capped = exp.min(self.cfg.backoff_max);
        let jitter = 0.5 + self.rng.next_f64();
        Duration::from_secs_f64(capped.as_secs_f64() * jitter)
    }

    /// One connect attempt if currently disconnected. The retry/backoff
    /// loops live in [`Self::connect`] and [`Self::send`], so the retry
    /// budget is never nested.
    fn ensure_connected(&mut self) -> Result<()> {
        if self.stream.is_some() {
            return Ok(());
        }
        let s = self.dial()?;
        self.stream = Some(s);
        Ok(())
    }

    /// One write→ack exchange on the current connection.
    fn try_send(&mut self, msg: &[u8]) -> Result<()> {
        let stream = self.stream.as_mut().ok_or(Error::ConnClosed { what: "no connection" })?;
        stream.write_all(msg).map_err(|e| classify_io("frame write", &e))?;
        let mut verdict = [0u8; 1];
        // a clean EOF here means the receiver died between write and ack
        match stream.read(&mut verdict) {
            Ok(0) => Err(Error::ConnClosed { what: "awaiting ack" }),
            Ok(_) => match verdict[0] {
                wire::ACK => Ok(()),
                wire::NACK => Err(Error::Protocol(
                    "receiver rejected the frame (NACK)".to_string(),
                )),
                wire::BUSY => Err(Error::Busy),
                other => Err(Error::Protocol(format!("unknown ack byte {other:#04x}"))),
            },
            Err(e) => Err(classify_io("ack read", &e)),
        }
    }

    /// Send one container frame and wait for the receiver's ACK.
    ///
    /// Connection-level failures (closed, reset, timed out) drop the
    /// socket and retry through the reconnect/backoff loop; after
    /// `max_reconnects` failed attempts the last typed error is
    /// returned and the breaker's failure streak advances.
    /// [`Error::Protocol`] (NACK) and [`Error::Busy`] are returned
    /// immediately; both prove the link alive, so they reset the
    /// breaker. While the breaker is open, [`Error::BreakerOpen`] is
    /// returned without touching the socket.
    pub fn send(&mut self, frame: &[u8]) -> Result<()> {
        let half_open = match self.open_until {
            Some(until) if Instant::now() < until => {
                self.stats.shed += 1;
                return Err(Error::BreakerOpen);
            }
            Some(_) => true,
            None => false,
        };
        let seq = self.next_seq;
        self.next_seq = self.next_seq.wrapping_add(1);
        let msg = wire::encode_msg_v2(frame, seq);
        // a half-open probe gets one attempt, not the whole budget: the
        // point of the open state is to stop burning the arrival
        // process on a link that keeps failing
        let budget = if half_open { 0 } else { self.cfg.max_reconnects };
        let mut last = Error::ConnClosed { what: "never attempted" };
        for attempt in 0..=budget {
            if attempt > 0 {
                self.stats.reconnects += 1;
                std::thread::sleep(self.backoff_delay(attempt - 1));
            }
            if let Err(e) = self.ensure_connected() {
                // receiver may be mid-restart: keep retrying on backoff
                if matches!(e, Error::Timeout { .. }) {
                    self.stats.timeouts += 1;
                }
                last = e;
                continue;
            }
            match self.try_send(&msg) {
                Ok(()) => {
                    self.note_link_alive();
                    self.stats.frames += 1;
                    self.stats.bytes += msg.len() as u64;
                    return Ok(());
                }
                Err(Error::Protocol(p)) => {
                    // deterministic rejection: resending the same bytes
                    // cannot succeed, surface it to the caller. The
                    // receiver answered, so the link itself is fine.
                    self.note_link_alive();
                    self.stream = None;
                    return Err(Error::Protocol(p));
                }
                Err(Error::Busy) => {
                    // overload shed at the receiver: don't retry into a
                    // saturated server. The connection stays usable.
                    self.note_link_alive();
                    self.stats.busy += 1;
                    return Err(Error::Busy);
                }
                Err(e) => {
                    if matches!(e, Error::Timeout { .. }) {
                        self.stats.timeouts += 1;
                    }
                    self.stream = None;
                    last = e;
                }
            }
        }
        // the whole budget failed: advance the breaker streak
        self.consec_failures = self.consec_failures.saturating_add(1);
        if self.cfg.breaker_threshold > 0
            && self.consec_failures >= self.cfg.breaker_threshold
        {
            self.open_until = Some(Instant::now() + self.cfg.breaker_cooldown);
            self.stats.breaker_opens += 1;
        }
        Err(last)
    }

    /// A verdict byte arrived, so the link works: reset the breaker.
    fn note_link_alive(&mut self) {
        self.consec_failures = 0;
        self.open_until = None;
    }

    /// Is the circuit breaker currently shedding (open, cooldown not
    /// yet elapsed)?
    pub fn breaker_open(&self) -> bool {
        self.open_until.is_some_and(|until| Instant::now() < until)
    }

    /// Drop the current connection (next send reconnects).
    pub fn disconnect(&mut self) {
        self.stream = None;
    }
}

#[cfg(test)]
mod tests {
    #![allow(clippy::unwrap_used, clippy::expect_used)]

    use super::*;
    use std::net::TcpListener;

    fn fast_cfg() -> NetConfig {
        NetConfig {
            connect_timeout: Duration::from_millis(200),
            read_timeout: Duration::from_millis(300),
            write_timeout: Duration::from_millis(300),
            accept_timeout: Duration::from_millis(300),
            max_reconnects: 2,
            backoff_base: Duration::from_millis(5),
            backoff_max: Duration::from_millis(20),
            seed: 1,
            // breaker disabled unless a test opts in: these tests probe
            // the raw retry loop
            breaker_threshold: 0,
            breaker_cooldown: Duration::from_millis(100),
            dedup_window: 64,
        }
    }

    fn bare_sender(addr: String, cfg: NetConfig, seed: u64) -> FrameSender {
        FrameSender {
            addr,
            cfg,
            stream: None,
            rng: SplitMix64::new(seed),
            stats: NetStats::default(),
            next_seq: 1,
            consec_failures: 0,
            open_until: None,
        }
    }

    #[test]
    fn connect_to_dead_port_fails_with_typed_error_after_bounded_retries() {
        // bind then drop: the port is (almost certainly) closed
        let addr = {
            let l = TcpListener::bind("127.0.0.1:0").unwrap();
            l.local_addr().unwrap().to_string()
        };
        let t0 = std::time::Instant::now();
        let err = FrameSender::connect(&addr, fast_cfg()).unwrap_err();
        assert!(
            matches!(err, Error::Io(_) | Error::Timeout { .. } | Error::ConnClosed { .. }),
            "unexpected error class: {err}"
        );
        // 3 attempts with ~5/10ms backoffs: well under a second
        assert!(t0.elapsed() < Duration::from_secs(5));
    }

    #[test]
    fn unresolvable_address_is_io_error() {
        let err = FrameSender::connect("definitely-not-a-host-xyz:1", fast_cfg());
        assert!(matches!(err, Err(Error::Io(_))));
    }

    #[test]
    fn nack_is_protocol_error_without_retry() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let server = std::thread::spawn(move || {
            let (mut conn, _) = listener.accept().unwrap();
            // the sender speaks wire v2 now: header carries the seq
            let mut buf = vec![0u8; wire::HEADER_V2_LEN + 3 + wire::CRC_LEN];
            conn.read_exact(&mut buf).unwrap();
            conn.write_all(&[wire::NACK]).unwrap();
        });
        let mut tx = FrameSender::connect(&addr, fast_cfg()).unwrap();
        let err = tx.send(&[1, 2, 3]).unwrap_err();
        assert!(matches!(err, Error::Protocol(_)), "{err}");
        assert_eq!(tx.stats().frames, 0, "a NACKed frame must not count as sent");
        server.join().unwrap();
    }

    #[test]
    fn backoff_grows_and_is_jittered_within_bounds() {
        let addr = {
            let l = TcpListener::bind("127.0.0.1:0").unwrap();
            l.local_addr().unwrap().to_string()
        };
        let mut s = bare_sender(
            addr,
            NetConfig {
                backoff_base: Duration::from_millis(100),
                backoff_max: Duration::from_secs(60),
                ..fast_cfg()
            },
            7,
        );
        for attempt in 0..6u32 {
            let nominal = 100.0e-3 * f64::from(1u32 << attempt);
            let d = s.backoff_delay(attempt).as_secs_f64();
            assert!(
                d >= nominal * 0.5 && d < nominal * 1.5,
                "attempt {attempt}: {d}s outside [{:.3}, {:.3})",
                nominal * 0.5,
                nominal * 1.5
            );
        }
        // the cap holds even for absurd attempt counts (no overflow)
        let capped = s.backoff_delay(40);
        assert!(capped < Duration::from_secs(91));
    }

    #[test]
    fn backoff_matches_formula_exactly_and_is_replayable() {
        // the delay schedule is a pure function of (base, max, seed):
        // base * 2^attempt capped at backoff_max, times a jitter factor
        // of 0.5 + next_f64() from the seeded SplitMix64 stream
        let cfg = NetConfig {
            backoff_base: Duration::from_millis(100),
            backoff_max: Duration::from_secs(1),
            ..fast_cfg()
        };
        let addr = "127.0.0.1:1".to_string();
        let mut s = bare_sender(addr.clone(), cfg.clone(), 42);
        let mut model = SplitMix64::new(42);
        let mut schedule = Vec::new();
        for attempt in 0..8u32 {
            let nominal = (0.1 * f64::from(1u32 << attempt)).min(1.0);
            let expect = nominal * (0.5 + model.next_f64());
            let got = s.backoff_delay(attempt).as_secs_f64();
            assert!(
                (got - expect).abs() < 1e-9,
                "attempt {attempt}: got {got}, formula says {expect}"
            );
            schedule.push(got);
        }
        // same seed → identical schedule (replayable); different seed → not
        let mut again = bare_sender(addr.clone(), cfg.clone(), 42);
        let replay: Vec<f64> =
            (0..8u32).map(|a| again.backoff_delay(a).as_secs_f64()).collect();
        assert_eq!(schedule, replay);
        let mut other = bare_sender(addr, cfg, 43);
        assert_ne!(
            schedule,
            (0..8u32).map(|a| other.backoff_delay(a).as_secs_f64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn max_reconnects_is_honored_exactly() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        listener.set_nonblocking(true).unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let cfg = NetConfig { max_reconnects: 3, ..fast_cfg() };
        let h = std::thread::spawn(move || {
            let mut tx = FrameSender::connect(&addr, cfg).unwrap();
            let err = tx.send(&[9, 9, 9]).unwrap_err();
            (err, tx.stats())
        });
        // every accepted connection is dropped immediately, so the send
        // fails each attempt: 1 accept from connect() + exactly
        // max_reconnects accepts from the retry loop, no more
        let mut accepts = 0u32;
        while !h.is_finished() {
            if listener.accept().is_ok() {
                accepts += 1;
            }
            std::thread::sleep(Duration::from_millis(1));
        }
        // catch any straggler the kernel had queued
        std::thread::sleep(Duration::from_millis(50));
        while listener.accept().is_ok() {
            accepts += 1;
        }
        let (err, stats) = h.join().unwrap();
        assert!(
            matches!(err, Error::ConnClosed { .. } | Error::Io(_) | Error::Timeout { .. }),
            "{err}"
        );
        assert_eq!(accepts, 1 + 3, "connect + exactly max_reconnects retries");
        assert_eq!(stats.reconnects, 3);
        assert_eq!(stats.frames, 0);
    }

    #[test]
    fn busy_verdict_is_typed_and_not_retried() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let server = std::thread::spawn(move || {
            let (mut conn, _) = listener.accept().unwrap();
            let mut buf = vec![0u8; wire::HEADER_V2_LEN + 3 + wire::CRC_LEN];
            conn.read_exact(&mut buf).unwrap();
            conn.write_all(&[wire::BUSY]).unwrap();
        });
        let mut tx = FrameSender::connect(&addr, fast_cfg()).unwrap();
        let err = tx.send(&[1, 2, 3]).unwrap_err();
        assert!(matches!(err, Error::Busy), "{err}");
        let st = tx.stats();
        assert_eq!(st.busy, 1);
        assert_eq!(st.frames, 0, "a shed frame must not count as sent");
        assert_eq!(st.reconnects, 0, "BUSY must not trigger the retry loop");
        server.join().unwrap();
    }

    #[test]
    fn breaker_opens_sheds_and_recovers_via_half_open_probe() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let server = std::thread::spawn(move || {
            let mut accepts = 0u32;
            loop {
                let Ok((mut conn, _)) = listener.accept() else { break };
                accepts += 1;
                if accepts <= 2 {
                    // outage phase: kill the connection immediately
                    drop(conn);
                    continue;
                }
                // recovered: serve two messages on this connection
                for _ in 0..2 {
                    let mut buf =
                        vec![0u8; wire::HEADER_V2_LEN + 3 + wire::CRC_LEN];
                    if conn.read_exact(&mut buf).is_err() {
                        break;
                    }
                    if conn.write_all(&[wire::ACK]).is_err() {
                        break;
                    }
                }
                break;
            }
            accepts
        });
        let cfg = NetConfig {
            max_reconnects: 0,
            breaker_threshold: 2,
            breaker_cooldown: Duration::from_millis(150),
            ..fast_cfg()
        };
        let mut tx = FrameSender::connect(&addr, cfg).unwrap();
        // two whole-budget failures trip the breaker...
        assert!(tx.send(&[1, 2, 3]).is_err());
        assert!(!tx.breaker_open());
        assert!(tx.send(&[1, 2, 3]).is_err());
        assert!(tx.breaker_open());
        assert_eq!(tx.stats().breaker_opens, 1);
        // ...after which frames shed instantly without touching the socket
        let t0 = std::time::Instant::now();
        assert!(matches!(tx.send(&[4, 5, 6]), Err(Error::BreakerOpen)));
        assert!(matches!(tx.send(&[4, 5, 6]), Err(Error::BreakerOpen)));
        assert!(t0.elapsed() < Duration::from_millis(100), "shedding must be instant");
        assert_eq!(tx.stats().shed, 2);
        // cooldown elapses → half-open probe succeeds → breaker closes
        std::thread::sleep(Duration::from_millis(180));
        tx.send(&[7, 8, 9]).unwrap();
        assert!(!tx.breaker_open());
        tx.send(&[7, 8, 9]).unwrap();
        assert_eq!(tx.stats().frames, 2);
        assert_eq!(server.join().unwrap(), 3, "shed frames never reached the socket");
    }
}
