//! A deterministic userspace chaos shim for the loopback transport.
//!
//! [`ChaosProxy`] sits between a [`super::FrameSender`] and a
//! [`super::FrameReceiver`] as a plain TCP relay: the sender connects
//! to the proxy's ephemeral port, the proxy dials the real receiver,
//! and a pair of relay threads shuttles bytes in each direction. Every
//! relayed *segment* (a bounded read) can be hit by faults:
//!
//! * added latency and jitter (per-segment sleeps);
//! * bandwidth throttling (sleep proportional to bytes moved);
//! * fragmentation (segments are capped at a drawn size, so one wire
//!   message crosses in many pieces) and coalescing (a segment is held
//!   back and flushed together with the next one);
//! * byte corruption (one bit of the segment flipped);
//! * mid-stream truncation + connection reset (both directions torn
//!   down partway through a message);
//! * stalls (a long per-segment sleep, exercising read timeouts).
//!
//! # Determinism
//!
//! Every fault decision is a pure function of `(seed, connection index,
//! direction, segment index)` via the counter-based SplitMix64 output
//! function — not of wall-clock time or a shared mutable RNG — so a
//! failing schedule is replayable from the seed alone. (How the kernel
//! sizes each read can still vary run to run, which shifts *where* in
//! the byte stream segment `k` falls; the decisions themselves, and
//! therefore the fault density and kind mix, are seed-determined.)
//!
//! The shim is dependency-free `std::net` + `std::thread`, lives inside
//! the `net` no-panic contract, and never panics on any socket failure:
//! a dying connection just ends its relay threads.

use super::{Error, Result};
use crate::util::prng::{mix, GAMMA};
use std::io::{Read, Write};
use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream, ToSocketAddrs};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

/// Per-fault-kind salts so one segment index yields independent draws.
const SALT_SEGMENT: u64 = 1;
const SALT_CORRUPT: u64 = 2;
const SALT_CORRUPT_BIT: u64 = 3;
const SALT_RESET: u64 = 4;
const SALT_STALL: u64 = 5;
const SALT_JITTER: u64 = 6;
const SALT_COALESCE: u64 = 7;

/// Flush the coalescing hold-back buffer once it grows past this many
/// bytes, whatever the schedule says (bounds proxy memory).
const COALESCE_CAP: usize = 64 * 1024;

/// Fault schedule knobs. All probabilities are per relayed segment and
/// evaluated independently; `Default` is a transparent proxy (no
/// faults, generous segment size).
#[derive(Debug, Clone)]
pub struct ChaosConfig {
    /// Root seed of the fault schedule.
    pub seed: u64,
    /// Fixed extra delay per relayed segment.
    pub latency: Duration,
    /// Extra uniform delay in `[0, jitter)` per segment.
    pub jitter: Duration,
    /// Bandwidth cap in bytes/second (0 = unthrottled).
    pub throttle_bytes_per_sec: u64,
    /// Largest segment the relay moves at once; each segment's actual
    /// cap is drawn from `[1, max_segment]` (fragmentation).
    pub max_segment: usize,
    /// Probability a segment is held back and flushed with the next
    /// one (coalescing).
    pub coalesce_prob: f64,
    /// Probability one bit of the segment is flipped.
    pub corrupt_prob: f64,
    /// Probability the connection is reset (both directions) before
    /// the segment is written — mid-stream truncation.
    pub reset_prob: f64,
    /// Probability of a long stall before the segment moves.
    pub stall_prob: f64,
    /// How long a stall lasts.
    pub stall: Duration,
}

impl Default for ChaosConfig {
    fn default() -> Self {
        ChaosConfig {
            seed: 0xC4A05,
            latency: Duration::ZERO,
            jitter: Duration::ZERO,
            throttle_bytes_per_sec: 0,
            max_segment: 4096,
            coalesce_prob: 0.0,
            corrupt_prob: 0.0,
            reset_prob: 0.0,
            stall_prob: 0.0,
            stall: Duration::from_millis(100),
        }
    }
}

#[derive(Debug, Default)]
struct ChaosCounters {
    connections: AtomicU64,
    resets: AtomicU64,
    corrupted: AtomicU64,
    stalls: AtomicU64,
    coalesced: AtomicU64,
    bytes_up: AtomicU64,
    bytes_down: AtomicU64,
}

/// Snapshot of what the proxy has done so far.
#[derive(Debug, Clone, Copy, Default)]
pub struct ChaosStats {
    /// Client connections accepted (and dialed upstream).
    pub connections: u64,
    /// Connections reset by the fault schedule.
    pub resets: u64,
    /// Segments with a flipped bit.
    pub corrupted: u64,
    /// Stalls injected.
    pub stalls: u64,
    /// Segments held back for coalescing.
    pub coalesced: u64,
    /// Payload bytes relayed client→upstream.
    pub bytes_up: u64,
    /// Payload bytes relayed upstream→client.
    pub bytes_down: u64,
}

/// The running shim: an ephemeral listener plus relay threads. Dropping
/// it (or calling [`Self::shutdown`]) stops the accept loop and joins
/// every relay.
#[derive(Debug)]
pub struct ChaosProxy {
    local: SocketAddr,
    stop: Arc<AtomicBool>,
    accept_thread: Option<JoinHandle<()>>,
    counters: Arc<ChaosCounters>,
}

/// The per-fault uniform draw for `(seed, conn, direction, segment)`:
/// counter-based SplitMix64, so schedules are replayable and no state
/// is shared between threads.
fn draw(seed: u64, conn: u64, dir: u64, segment: u64, salt: u64) -> u64 {
    let counter = conn
        .wrapping_mul(0x9E37_79B9_0000_0001)
        .wrapping_add(dir.wrapping_mul(0x0000_0001_0000_003B))
        .wrapping_add(segment.wrapping_mul(GAMMA))
        .wrapping_add(salt.wrapping_mul(0xD1B5_4A32_D192_ED03));
    mix(seed.wrapping_add(counter))
}

/// Map a raw draw to a uniform f64 in [0, 1).
fn unit(x: u64) -> f64 {
    (x >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
}

impl ChaosProxy {
    /// Bind an ephemeral loopback port and start relaying every
    /// accepted connection to `upstream` under the fault schedule.
    pub fn start(upstream: &str, cfg: ChaosConfig) -> Result<Self> {
        let upstream_addr: SocketAddr = upstream
            .to_socket_addrs()
            .map_err(|e| Error::Io(format!("resolving {upstream}: {e}")))?
            .next()
            .ok_or_else(|| Error::Io(format!("{upstream} resolves to no address")))?;
        let listener = TcpListener::bind("127.0.0.1:0")
            .map_err(|e| Error::Io(format!("chaos bind: {e}")))?;
        listener
            .set_nonblocking(true)
            .map_err(|e| Error::Io(format!("chaos listener options: {e}")))?;
        let local = listener
            .local_addr()
            .map_err(|e| Error::Io(format!("chaos local_addr: {e}")))?;
        let stop = Arc::new(AtomicBool::new(false));
        let counters = Arc::new(ChaosCounters::default());
        let t_stop = Arc::clone(&stop);
        let t_counters = Arc::clone(&counters);
        let accept_thread = std::thread::spawn(move || {
            accept_loop(&listener, upstream_addr, &cfg, &t_stop, &t_counters);
        });
        Ok(ChaosProxy { local, stop, accept_thread: Some(accept_thread), counters })
    }

    /// The address a [`super::FrameSender`] should connect to.
    pub fn local_addr(&self) -> SocketAddr {
        self.local
    }

    /// Counter snapshot.
    pub fn stats(&self) -> ChaosStats {
        let c = &self.counters;
        ChaosStats {
            connections: c.connections.load(Ordering::Relaxed),
            resets: c.resets.load(Ordering::Relaxed),
            corrupted: c.corrupted.load(Ordering::Relaxed),
            stalls: c.stalls.load(Ordering::Relaxed),
            coalesced: c.coalesced.load(Ordering::Relaxed),
            bytes_up: c.bytes_up.load(Ordering::Relaxed),
            bytes_down: c.bytes_down.load(Ordering::Relaxed),
        }
    }

    /// Stop accepting, tear down every relay, and join the threads.
    /// Idempotent; also runs on drop.
    pub fn shutdown(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(h) = self.accept_thread.take() {
            let _ = h.join();
        }
    }
}

impl Drop for ChaosProxy {
    fn drop(&mut self) {
        self.shutdown();
    }
}

fn accept_loop(
    listener: &TcpListener,
    upstream: SocketAddr,
    cfg: &ChaosConfig,
    stop: &Arc<AtomicBool>,
    counters: &Arc<ChaosCounters>,
) {
    let mut relays: Vec<JoinHandle<()>> = Vec::new();
    let mut conn_idx = 0u64;
    while !stop.load(Ordering::Relaxed) {
        let client = match listener.accept() {
            Ok((stream, _peer)) => stream,
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_millis(2));
                continue;
            }
            Err(_) => break,
        };
        conn_idx += 1;
        counters.connections.fetch_add(1, Ordering::Relaxed);
        let server =
            match TcpStream::connect_timeout(&upstream, Duration::from_secs(2)) {
                Ok(s) => s,
                // upstream down: drop the client, which sees a reset
                Err(_) => continue,
            };
        let (Ok(client2), Ok(server2)) = (client.try_clone(), server.try_clone())
        else {
            continue;
        };
        let up = RelayEnd {
            cfg: cfg.clone(),
            stop: Arc::clone(stop),
            counters: Arc::clone(counters),
            conn: conn_idx,
            upstream_dir: true,
        };
        let down = RelayEnd { upstream_dir: false, ..up.clone() };
        relays.push(std::thread::spawn(move || relay(client, server, &up)));
        relays.push(std::thread::spawn(move || relay(server2, client2, &down)));
        // reap finished relays so a long soak doesn't hoard handles
        relays.retain(|h| !h.is_finished());
    }
    for h in relays {
        let _ = h.join();
    }
}

#[derive(Debug, Clone)]
struct RelayEnd {
    cfg: ChaosConfig,
    stop: Arc<AtomicBool>,
    counters: Arc<ChaosCounters>,
    conn: u64,
    upstream_dir: bool,
}

/// Shuttle bytes `from` → `to`, one fault-scheduled segment at a time,
/// until EOF, a socket error, a scheduled reset, or shutdown.
fn relay(mut from: TcpStream, mut to: TcpStream, end: &RelayEnd) {
    let cfg = &end.cfg;
    // short read timeout so the stop flag is honored promptly
    let _ = from.set_read_timeout(Some(Duration::from_millis(50)));
    let _ = to.set_write_timeout(Some(Duration::from_secs(5)));
    let _ = to.set_nodelay(true);
    let cap = cfg.max_segment.max(1);
    let mut buf = vec![0u8; cap];
    let mut pending: Vec<u8> = Vec::new();
    let dir = u64::from(end.upstream_dir);
    let mut segment = 0u64;
    loop {
        if end.stop.load(Ordering::Relaxed) {
            break;
        }
        // fragmentation: this segment moves at most `want` bytes
        let want =
            1 + (draw(cfg.seed, end.conn, dir, segment, SALT_SEGMENT) as usize) % cap;
        let n = match from.read(&mut buf[..want]) {
            Ok(0) => {
                // EOF: flush what coalescing held back, half-close, done
                if !pending.is_empty() {
                    let _ = to.write_all(&pending);
                }
                let _ = to.shutdown(Shutdown::Write);
                return;
            }
            Ok(n) => n,
            Err(e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut =>
            {
                // the source went idle: flush anything coalescing held
                // back, otherwise a held message tail would strand the
                // peer until its own read timeout fires
                if !pending.is_empty() {
                    if to.write_all(&pending).is_err() {
                        return;
                    }
                    pending.clear();
                }
                continue;
            }
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
            Err(_) => return,
        };
        let seg = buf.get_mut(..n).unwrap_or(&mut []);
        if end.upstream_dir {
            end.counters.bytes_up.fetch_add(n as u64, Ordering::Relaxed);
        } else {
            end.counters.bytes_down.fetch_add(n as u64, Ordering::Relaxed);
        }
        // stall: a long pause that exercises the peers' read timeouts
        if unit(draw(cfg.seed, end.conn, dir, segment, SALT_STALL)) < cfg.stall_prob {
            end.counters.stalls.fetch_add(1, Ordering::Relaxed);
            sleep_unless_stopped(cfg.stall, &end.stop);
        }
        // latency + jitter
        let jit_ns = if cfg.jitter.is_zero() {
            0
        } else {
            draw(cfg.seed, end.conn, dir, segment, SALT_JITTER)
                % cfg.jitter.as_nanos().min(u128::from(u64::MAX)) as u64
        };
        let delay = cfg.latency + Duration::from_nanos(jit_ns);
        if !delay.is_zero() {
            sleep_unless_stopped(delay, &end.stop);
        }
        // throttle: pay for the bytes at the configured bandwidth
        if cfg.throttle_bytes_per_sec > 0 {
            let secs = n as f64 / cfg.throttle_bytes_per_sec as f64;
            sleep_unless_stopped(Duration::from_secs_f64(secs), &end.stop);
        }
        // corruption: flip one bit of the segment
        if unit(draw(cfg.seed, end.conn, dir, segment, SALT_CORRUPT)) < cfg.corrupt_prob
        {
            let bit =
                draw(cfg.seed, end.conn, dir, segment, SALT_CORRUPT_BIT) % (n as u64 * 8);
            if let Some(byte) = seg.get_mut((bit / 8) as usize) {
                *byte ^= 1u8 << (bit % 8);
            }
            end.counters.corrupted.fetch_add(1, Ordering::Relaxed);
        }
        // reset: tear the connection down with this segment undelivered
        // (mid-stream truncation from the peers' point of view)
        if unit(draw(cfg.seed, end.conn, dir, segment, SALT_RESET)) < cfg.reset_prob {
            end.counters.resets.fetch_add(1, Ordering::Relaxed);
            let _ = to.shutdown(Shutdown::Both);
            let _ = from.shutdown(Shutdown::Both);
            return;
        }
        // coalescing: hold this segment and flush it with the next one
        pending.extend_from_slice(seg);
        let hold = unit(draw(cfg.seed, end.conn, dir, segment, SALT_COALESCE))
            < cfg.coalesce_prob
            && pending.len() < COALESCE_CAP;
        if hold {
            end.counters.coalesced.fetch_add(1, Ordering::Relaxed);
        } else {
            if to.write_all(&pending).is_err() {
                return;
            }
            pending.clear();
        }
        segment += 1;
    }
}

/// Sleep in small slices so shutdown is never blocked behind a long
/// stall.
fn sleep_unless_stopped(total: Duration, stop: &Arc<AtomicBool>) {
    let mut left = total;
    let slice = Duration::from_millis(20);
    while !left.is_zero() {
        if stop.load(Ordering::Relaxed) {
            return;
        }
        let step = left.min(slice);
        std::thread::sleep(step);
        left = left.saturating_sub(step);
    }
}

#[cfg(test)]
mod tests {
    #![allow(clippy::unwrap_used, clippy::expect_used)]

    use super::*;
    use crate::net::{FrameReceiver, FrameSender, NetConfig};

    fn fast_cfg() -> NetConfig {
        NetConfig {
            connect_timeout: Duration::from_millis(500),
            read_timeout: Duration::from_millis(500),
            write_timeout: Duration::from_millis(500),
            accept_timeout: Duration::from_millis(1500),
            max_reconnects: 4,
            backoff_base: Duration::from_millis(5),
            backoff_max: Duration::from_millis(40),
            seed: 11,
            breaker_threshold: 0,
            breaker_cooldown: Duration::from_millis(100),
            dedup_window: 64,
        }
    }

    #[test]
    fn transparent_proxy_roundtrips_frames() {
        let mut rx = FrameReceiver::bind("127.0.0.1:0", fast_cfg()).unwrap();
        let upstream = rx.local_addr().unwrap().to_string();
        let proxy = ChaosProxy::start(&upstream, ChaosConfig::default()).unwrap();
        let addr = proxy.local_addr().to_string();
        let payload: Vec<u8> = (0..=255u8).cycle().take(700).collect();
        let sent = payload.clone();
        let tx_thread = std::thread::spawn(move || {
            let mut tx = FrameSender::connect(&addr, fast_cfg()).unwrap();
            tx.send(&sent).unwrap();
        });
        let got = rx.recv().unwrap();
        assert_eq!(got.frame, payload);
        tx_thread.join().unwrap();
        let st = proxy.stats();
        assert_eq!(st.connections, 1);
        assert_eq!(st.resets, 0);
        assert!(st.bytes_up > 0 && st.bytes_down > 0);
    }

    #[test]
    fn fragmentation_and_coalescing_preserve_the_byte_stream() {
        let mut rx = FrameReceiver::bind("127.0.0.1:0", fast_cfg()).unwrap();
        let upstream = rx.local_addr().unwrap().to_string();
        let cfg = ChaosConfig {
            seed: 99,
            max_segment: 7,
            coalesce_prob: 0.5,
            ..ChaosConfig::default()
        };
        let proxy = ChaosProxy::start(&upstream, cfg).unwrap();
        let addr = proxy.local_addr().to_string();
        let frames: Vec<Vec<u8>> =
            (0..5u8).map(|i| vec![i; 40 + usize::from(i)]).collect();
        let expect = frames.clone();
        let tx_thread = std::thread::spawn(move || {
            let mut tx = FrameSender::connect(&addr, fast_cfg()).unwrap();
            for f in &frames {
                tx.send(f).unwrap();
            }
        });
        for want in &expect {
            let got = rx.recv().unwrap();
            assert_eq!(&got.frame, want);
        }
        tx_thread.join().unwrap();
        assert!(proxy.stats().coalesced > 0, "schedule should have coalesced");
    }

    #[test]
    fn corruption_is_rejected_not_delivered() {
        let mut rx = FrameReceiver::bind("127.0.0.1:0", fast_cfg()).unwrap();
        let upstream = rx.local_addr().unwrap().to_string();
        let cfg = ChaosConfig { seed: 5, corrupt_prob: 1.0, ..ChaosConfig::default() };
        let proxy = ChaosProxy::start(&upstream, cfg).unwrap();
        let addr = proxy.local_addr().to_string();
        let tx_thread = std::thread::spawn(move || {
            let mut tx = FrameSender::connect(&addr, fast_cfg()).unwrap();
            // every segment corrupt: the receiver must NACK, which the
            // sender types as Protocol (deterministic rejection)
            tx.send(&[42u8; 300]).unwrap_err()
        });
        // the receiver sees only corrupt messages; drain until the
        // sender gives up, asserting nothing corrupt is ever delivered
        let mut rejected = 0u32;
        loop {
            match rx.recv() {
                Ok(r) => panic!("corrupt stream delivered a frame: {:?}", &r.frame[..8]),
                Err(Error::Protocol(_)) | Err(Error::TooLarge { .. }) => rejected += 1,
                Err(_) => {
                    if tx_thread.is_finished() {
                        break;
                    }
                }
            }
        }
        let err = tx_thread.join().unwrap();
        assert!(
            matches!(err, Error::Protocol(_)),
            "sender should see the NACK: {err}"
        );
        assert!(rejected >= 1);
        drop(proxy);
    }

    #[test]
    fn same_seed_same_schedule_different_seed_different_schedule() {
        // the schedule is a pure function of the inputs — no sockets
        // needed to verify replayability
        let a: Vec<u64> =
            (0..64).map(|k| draw(1, 1, 0, k, SALT_SEGMENT)).collect();
        let b: Vec<u64> =
            (0..64).map(|k| draw(1, 1, 0, k, SALT_SEGMENT)).collect();
        let c: Vec<u64> =
            (0..64).map(|k| draw(2, 1, 0, k, SALT_SEGMENT)).collect();
        assert_eq!(a, b);
        assert_ne!(a, c);
        // directions and fault kinds draw independent streams
        let d: Vec<u64> = (0..64).map(|k| draw(1, 1, 1, k, SALT_SEGMENT)).collect();
        let e: Vec<u64> = (0..64).map(|k| draw(1, 1, 0, k, SALT_RESET)).collect();
        assert_ne!(a, d);
        assert_ne!(a, e);
    }

    #[test]
    fn shutdown_joins_cleanly_mid_traffic() {
        let mut rx = FrameReceiver::bind("127.0.0.1:0", fast_cfg()).unwrap();
        let upstream = rx.local_addr().unwrap().to_string();
        let cfg = ChaosConfig {
            seed: 3,
            stall_prob: 0.2,
            stall: Duration::from_millis(300),
            max_segment: 5,
            ..ChaosConfig::default()
        };
        let mut proxy = ChaosProxy::start(&upstream, cfg).unwrap();
        let addr = proxy.local_addr().to_string();
        let tx_thread = std::thread::spawn(move || {
            let mut tx = match FrameSender::connect(&addr, fast_cfg()) {
                Ok(tx) => tx,
                Err(_) => return,
            };
            for _ in 0..4 {
                let _ = tx.send(&[7u8; 200]);
            }
        });
        // consume what arrives while the sender struggles through stalls
        let _ = rx.recv();
        let t0 = std::time::Instant::now();
        proxy.shutdown();
        assert!(
            t0.elapsed() < Duration::from_secs(5),
            "shutdown must not hang on stalled relays"
        );
        let _ = tx_thread.join();
    }
}
