//! The length-prefixed wire layout for one edge→cloud message.
//!
//! ```text
//! offset size  field
//! 0      4     magic "BAFN"
//! 4      1     wire version (1)
//! 5      4     frame_len (u32 LE, <= MAX_FRAME_LEN)
//! 9      len   container frame (the codec::container bytes, verbatim)
//! 9+len  4     CRC32 over everything above (header + frame)
//! ```
//!
//! After reading and validating a message the receiver answers with one
//! byte: [`ACK`] (frame accepted) or [`NACK`] (wire-level rejection; the
//! receiver drops the connection right after, because framing downstream
//! of a corrupt message cannot be trusted). The sender treats a NACK as
//! a non-retryable [`super::Error::Protocol`] — resending the same bytes
//! would fail the same way.
//!
//! The message CRC is deliberately redundant with the container's own
//! trailing CRC32: the wire check localizes corruption to the transport
//! (and covers the length prefix, which the container CRC cannot), while
//! the container check keeps protecting frames at rest.

use super::{Error, Result};
use crate::codec::MAX_DECODED_SAMPLES;

pub const MAGIC: &[u8; 4] = b"BAFN";
pub const VERSION: u8 = 1;
/// magic + version + frame_len.
pub const HEADER_LEN: usize = 9;
/// Trailing message CRC32.
pub const CRC_LEN: usize = 4;

/// Receiver's one-byte verdict on a message.
pub const ACK: u8 = 0xA5;
pub const NACK: u8 = 0x5A;

/// Hard cap on the transported frame length, derived from the decode
/// cap: a frame decodes to at most [`MAX_DECODED_SAMPLES`] u16 samples
/// (32 MiB), and no registered codec expands the entropy-coded payload
/// past 2x the raw sample bytes, so 4 bytes/sample bounds every real
/// frame with headroom. A hostile length prefix beyond this is rejected
/// before any allocation.
pub const MAX_FRAME_LEN: usize = 4 * MAX_DECODED_SAMPLES;

/// Serialize one container frame into a complete wire message.
/// Panics if the frame exceeds [`MAX_FRAME_LEN`] (trusted, locally
/// produced input — a violation is a bug, not an input error).
pub fn encode_msg(frame: &[u8]) -> Vec<u8> {
    assert!(
        frame.len() <= MAX_FRAME_LEN,
        "frame of {} bytes exceeds the wire cap {MAX_FRAME_LEN}",
        frame.len()
    );
    let mut out = Vec::with_capacity(HEADER_LEN + frame.len() + CRC_LEN);
    out.extend_from_slice(MAGIC);
    out.push(VERSION);
    out.extend_from_slice(&(frame.len() as u32).to_le_bytes());
    out.extend_from_slice(frame);
    let crc = crc32fast::hash(&out);
    out.extend_from_slice(&crc.to_le_bytes());
    out
}

/// Validate a message header; returns the declared frame length.
/// Total: bad magic / version is [`Error::Protocol`], an oversized
/// length is [`Error::TooLarge`] — checked before the caller allocates.
pub fn validate_header(hdr: &[u8; HEADER_LEN]) -> Result<usize> {
    if &hdr[0..4] != MAGIC {
        return Err(Error::Protocol(format!(
            "bad wire magic {:02x?} (want {MAGIC:02x?})",
            &hdr[0..4]
        )));
    }
    if hdr[4] != VERSION {
        return Err(Error::Protocol(format!(
            "wire version {} (this build speaks {VERSION})",
            hdr[4]
        )));
    }
    let len = u32::from_le_bytes([hdr[5], hdr[6], hdr[7], hdr[8]]) as usize;
    if len > MAX_FRAME_LEN {
        return Err(Error::TooLarge { requested: len, limit: MAX_FRAME_LEN });
    }
    Ok(len)
}

/// Verify the trailing CRC32 of a complete message body (header +
/// frame) against the stored trailer.
pub fn check_crc(body: &[u8], trailer: &[u8; CRC_LEN]) -> Result<()> {
    check_crc_parts(body, &[], trailer)
}

/// [`check_crc`] for a message body held as two pieces (header, then
/// frame payload): the CRC is streamed over both, so the receiver can
/// validate without concatenating them into a fresh allocation.
pub fn check_crc_parts(head: &[u8], rest: &[u8], trailer: &[u8; CRC_LEN]) -> Result<()> {
    let want = u32::from_le_bytes(*trailer);
    let mut hasher = crc32fast::Hasher::new();
    hasher.update(head);
    hasher.update(rest);
    let got = hasher.finalize();
    if want != got {
        return Err(Error::Protocol(format!(
            "message CRC mismatch: stored {want:#010x}, computed {got:#010x}"
        )));
    }
    Ok(())
}

/// Recompute the trailing CRC32 of a (possibly mutated) wire message in
/// place — the fault-injection harness uses this to reach validation
/// logic behind the checksum, mirroring `container::refresh_crc`.
/// Messages shorter than the CRC field are returned unchanged.
pub fn refresh_msg_crc(msg: &mut [u8]) {
    if msg.len() < CRC_LEN {
        return;
    }
    let body_len = msg.len() - CRC_LEN;
    let crc = crc32fast::hash(&msg[..body_len]);
    msg[body_len..].copy_from_slice(&crc.to_le_bytes());
}

#[cfg(test)]
mod tests {
    #![allow(clippy::unwrap_used, clippy::expect_used)]

    use super::*;

    fn header_of(msg: &[u8]) -> [u8; HEADER_LEN] {
        let mut h = [0u8; HEADER_LEN];
        h.copy_from_slice(&msg[..HEADER_LEN]);
        h
    }

    #[test]
    fn encode_validate_roundtrip() {
        let frame = vec![7u8; 33];
        let msg = encode_msg(&frame);
        assert_eq!(msg.len(), HEADER_LEN + 33 + CRC_LEN);
        assert_eq!(validate_header(&header_of(&msg)).unwrap(), 33);
        let (body, crc) = msg.split_at(msg.len() - CRC_LEN);
        let mut trailer = [0u8; CRC_LEN];
        trailer.copy_from_slice(crc);
        check_crc(body, &trailer).unwrap();
        assert_eq!(&body[HEADER_LEN..], frame.as_slice());
    }

    #[test]
    fn bad_magic_version_and_length_rejected() {
        let msg = encode_msg(&[1, 2, 3]);
        let mut h = header_of(&msg);
        h[0] = b'X';
        assert!(matches!(validate_header(&h), Err(Error::Protocol(_))));
        let mut h = header_of(&msg);
        h[4] = 9;
        assert!(matches!(validate_header(&h), Err(Error::Protocol(_))));
        let mut h = header_of(&msg);
        h[5..9].copy_from_slice(&u32::MAX.to_le_bytes());
        assert!(matches!(
            validate_header(&h),
            Err(Error::TooLarge { requested, .. }) if requested == u32::MAX as usize
        ));
        // the cap itself is accepted (allocation stays bounded)
        let mut h = header_of(&msg);
        h[5..9].copy_from_slice(&(MAX_FRAME_LEN as u32).to_le_bytes());
        assert_eq!(validate_header(&h).unwrap(), MAX_FRAME_LEN);
    }

    #[test]
    fn crc_refresh_matches_encode() {
        let mut msg = encode_msg(&[9u8; 10]);
        let orig = msg.clone();
        // mutate + refresh: the CRC must track the new bytes
        msg[HEADER_LEN] ^= 0xFF;
        refresh_msg_crc(&mut msg);
        assert_ne!(msg, orig);
        let (body, crc) = msg.split_at(msg.len() - CRC_LEN);
        let mut trailer = [0u8; CRC_LEN];
        trailer.copy_from_slice(crc);
        check_crc(body, &trailer).unwrap();
        // short slices are a no-op, not a panic
        refresh_msg_crc(&mut [0u8; 2]);
    }
}
