//! The length-prefixed wire layout for one edge→cloud message.
//!
//! Two wire versions coexist on the same port (the receiver dispatches
//! on the version byte, so old senders keep working):
//!
//! ```text
//! v1:
//! offset size  field
//! 0      4     magic "BAFN"
//! 4      1     wire version (1)
//! 5      4     frame_len (u32 LE, <= MAX_FRAME_LEN)
//! 9      len   container frame (the codec::container bytes, verbatim)
//! 9+len  4     CRC32 over everything above (header + frame)
//!
//! v2 (sequenced — what FrameSender speaks):
//! offset size  field
//! 0      4     magic "BAFN"
//! 4      1     wire version (2)
//! 5      8     seq (u64 LE, per-sender stream; retransmits reuse it)
//! 13     4     frame_len (u32 LE, <= MAX_FRAME_LEN)
//! 17     len   container frame
//! 17+len 4     CRC32 over everything above (header + frame)
//! ```
//!
//! After reading and validating a message the receiver answers with one
//! byte: [`ACK`] (frame accepted — or already accepted: a v2 retransmit
//! of a sequence number inside the receiver's dedup window is ACKed so
//! the sender stops resending, but is *not* delivered again), [`NACK`]
//! (wire-level rejection; the receiver drops the connection right after,
//! because framing downstream of a corrupt message cannot be trusted),
//! or [`BUSY`] (the frame was valid but the receiver's ingress is
//! saturated — the frame is shed at admission, the connection survives).
//! The sender treats a NACK as a non-retryable
//! [`super::Error::Protocol`] — resending the same bytes would fail the
//! same way — and a BUSY as [`super::Error::Busy`], an overload signal
//! the caller sheds on rather than retries.
//!
//! The v2 sequence number is what upgrades the sender's at-least-once
//! retry loop to exactly-once delivery at the pipeline: a retransmit
//! after a lost ACK carries the same `seq`, and the receiver's bounded
//! dedup window ([`super::dedup::DedupWindow`]) suppresses the second
//! delivery while still ACKing it.
//!
//! The message CRC is deliberately redundant with the container's own
//! trailing CRC32: the wire check localizes corruption to the transport
//! (and covers the length prefix and sequence number, which the
//! container CRC cannot), while the container check keeps protecting
//! frames at rest.

use super::{Error, Result};
use crate::codec::MAX_DECODED_SAMPLES;

pub const MAGIC: &[u8; 4] = b"BAFN";
pub const VERSION: u8 = 1;
/// The sequenced wire version (adds a u64 sequence number).
pub const VERSION2: u8 = 2;
/// magic + version: the version-independent part every message starts
/// with; the rest of the header is dispatched on the version byte.
pub const PREFIX_LEN: usize = 5;
/// v1 header: magic + version + frame_len.
pub const HEADER_LEN: usize = 9;
/// v2 header: magic + version + seq + frame_len.
pub const HEADER_V2_LEN: usize = 17;
/// Trailing message CRC32.
pub const CRC_LEN: usize = 4;

/// Receiver's one-byte verdict on a message.
pub const ACK: u8 = 0xA5;
pub const NACK: u8 = 0x5A;
/// Overload verdict: the message was wire-valid but the receiver's
/// ingress is saturated; the frame is shed, the connection survives.
pub const BUSY: u8 = 0xB5;

/// Hard cap on the transported frame length, derived from the decode
/// cap: a frame decodes to at most [`MAX_DECODED_SAMPLES`] u16 samples
/// (32 MiB), and no registered codec expands the entropy-coded payload
/// past 2x the raw sample bytes, so 4 bytes/sample bounds every real
/// frame with headroom. A hostile length prefix beyond this is rejected
/// before any allocation.
pub const MAX_FRAME_LEN: usize = 4 * MAX_DECODED_SAMPLES;

/// Serialize one container frame into a complete wire message.
/// Panics if the frame exceeds [`MAX_FRAME_LEN`] (trusted, locally
/// produced input — a violation is a bug, not an input error).
pub fn encode_msg(frame: &[u8]) -> Vec<u8> {
    assert!(
        frame.len() <= MAX_FRAME_LEN,
        "frame of {} bytes exceeds the wire cap {MAX_FRAME_LEN}",
        frame.len()
    );
    let mut out = Vec::with_capacity(HEADER_LEN + frame.len() + CRC_LEN);
    out.extend_from_slice(MAGIC);
    out.push(VERSION);
    out.extend_from_slice(&(frame.len() as u32).to_le_bytes());
    out.extend_from_slice(frame);
    let crc = crc32fast::hash(&out);
    out.extend_from_slice(&crc.to_le_bytes());
    out
}

/// Serialize one container frame into a complete sequenced (v2) wire
/// message. Panics on an oversized frame, like [`encode_msg`].
pub fn encode_msg_v2(frame: &[u8], seq: u64) -> Vec<u8> {
    assert!(
        frame.len() <= MAX_FRAME_LEN,
        "frame of {} bytes exceeds the wire cap {MAX_FRAME_LEN}",
        frame.len()
    );
    let mut out = Vec::with_capacity(HEADER_V2_LEN + frame.len() + CRC_LEN);
    out.extend_from_slice(MAGIC);
    out.push(VERSION2);
    out.extend_from_slice(&seq.to_le_bytes());
    out.extend_from_slice(&(frame.len() as u32).to_le_bytes());
    out.extend_from_slice(frame);
    let crc = crc32fast::hash(&out);
    out.extend_from_slice(&crc.to_le_bytes());
    out
}

/// Validate the version-independent message prefix (magic + version);
/// returns the wire version so the caller knows how much more header to
/// read. Total: bad magic or an unknown version is [`Error::Protocol`].
pub fn validate_prefix(prefix: &[u8; PREFIX_LEN]) -> Result<u8> {
    if &prefix[0..4] != MAGIC {
        return Err(Error::Protocol(format!(
            "bad wire magic {:02x?} (want {MAGIC:02x?})",
            &prefix[0..4]
        )));
    }
    let ver = prefix[4];
    if ver != VERSION && ver != VERSION2 {
        return Err(Error::Protocol(format!(
            "wire version {ver} (this build speaks {VERSION} and {VERSION2})"
        )));
    }
    Ok(ver)
}

/// Total header length (including the prefix) for a wire version that
/// [`validate_prefix`] accepted.
pub fn header_len_for(version: u8) -> usize {
    if version == VERSION2 { HEADER_V2_LEN } else { HEADER_LEN }
}

/// Parse a complete, prefix-validated header of either version: returns
/// the sequence number (None for v1) and the declared frame length,
/// re-checking magic/version so the function is total on any slice.
/// An oversized length is [`Error::TooLarge`] — checked before the
/// caller allocates.
pub fn parse_header(hdr: &[u8]) -> Result<(Option<u64>, usize)> {
    let prefix: &[u8; PREFIX_LEN] = hdr
        .get(..PREFIX_LEN)
        .and_then(|s| s.try_into().ok())
        .ok_or_else(|| Error::Protocol(format!("header of {} bytes is shorter than the prefix", hdr.len())))?;
    let ver = validate_prefix(prefix)?;
    let want = header_len_for(ver);
    if hdr.len() != want {
        return Err(Error::Protocol(format!(
            "v{ver} header must be {want} bytes, got {}",
            hdr.len()
        )));
    }
    let (seq, len_bytes) = if ver == VERSION2 {
        let seq_bytes: [u8; 8] = hdr
            .get(5..13)
            .and_then(|s| s.try_into().ok())
            .ok_or_else(|| Error::Protocol("v2 header too short for seq".to_string()))?;
        (Some(u64::from_le_bytes(seq_bytes)), hdr.get(13..17))
    } else {
        (None, hdr.get(5..9))
    };
    let len_bytes: [u8; 4] = len_bytes
        .and_then(|s| s.try_into().ok())
        .ok_or_else(|| Error::Protocol("header too short for frame_len".to_string()))?;
    let len = u32::from_le_bytes(len_bytes) as usize;
    if len > MAX_FRAME_LEN {
        return Err(Error::TooLarge { requested: len, limit: MAX_FRAME_LEN });
    }
    Ok((seq, len))
}

/// Validate a v1 message header; returns the declared frame length.
/// Total: bad magic / version is [`Error::Protocol`], an oversized
/// length is [`Error::TooLarge`] — checked before the caller allocates.
pub fn validate_header(hdr: &[u8; HEADER_LEN]) -> Result<usize> {
    if &hdr[0..4] != MAGIC {
        return Err(Error::Protocol(format!(
            "bad wire magic {:02x?} (want {MAGIC:02x?})",
            &hdr[0..4]
        )));
    }
    if hdr[4] != VERSION {
        return Err(Error::Protocol(format!(
            "wire version {} (this build speaks {VERSION})",
            hdr[4]
        )));
    }
    let len = u32::from_le_bytes([hdr[5], hdr[6], hdr[7], hdr[8]]) as usize;
    if len > MAX_FRAME_LEN {
        return Err(Error::TooLarge { requested: len, limit: MAX_FRAME_LEN });
    }
    Ok(len)
}

/// Verify the trailing CRC32 of a complete message body (header +
/// frame) against the stored trailer.
pub fn check_crc(body: &[u8], trailer: &[u8; CRC_LEN]) -> Result<()> {
    check_crc_parts(body, &[], trailer)
}

/// [`check_crc`] for a message body held as two pieces (header, then
/// frame payload): the CRC is streamed over both, so the receiver can
/// validate without concatenating them into a fresh allocation.
pub fn check_crc_parts(head: &[u8], rest: &[u8], trailer: &[u8; CRC_LEN]) -> Result<()> {
    let want = u32::from_le_bytes(*trailer);
    let mut hasher = crc32fast::Hasher::new();
    hasher.update(head);
    hasher.update(rest);
    let got = hasher.finalize();
    if want != got {
        return Err(Error::Protocol(format!(
            "message CRC mismatch: stored {want:#010x}, computed {got:#010x}"
        )));
    }
    Ok(())
}

/// Recompute the trailing CRC32 of a (possibly mutated) wire message in
/// place — the fault-injection harness uses this to reach validation
/// logic behind the checksum, mirroring `container::refresh_crc`.
/// Messages shorter than the CRC field are returned unchanged.
pub fn refresh_msg_crc(msg: &mut [u8]) {
    if msg.len() < CRC_LEN {
        return;
    }
    let body_len = msg.len() - CRC_LEN;
    let crc = crc32fast::hash(&msg[..body_len]);
    msg[body_len..].copy_from_slice(&crc.to_le_bytes());
}

#[cfg(test)]
mod tests {
    #![allow(clippy::unwrap_used, clippy::expect_used)]

    use super::*;

    fn header_of(msg: &[u8]) -> [u8; HEADER_LEN] {
        let mut h = [0u8; HEADER_LEN];
        h.copy_from_slice(&msg[..HEADER_LEN]);
        h
    }

    #[test]
    fn encode_validate_roundtrip() {
        let frame = vec![7u8; 33];
        let msg = encode_msg(&frame);
        assert_eq!(msg.len(), HEADER_LEN + 33 + CRC_LEN);
        assert_eq!(validate_header(&header_of(&msg)).unwrap(), 33);
        let (body, crc) = msg.split_at(msg.len() - CRC_LEN);
        let mut trailer = [0u8; CRC_LEN];
        trailer.copy_from_slice(crc);
        check_crc(body, &trailer).unwrap();
        assert_eq!(&body[HEADER_LEN..], frame.as_slice());
    }

    #[test]
    fn bad_magic_version_and_length_rejected() {
        let msg = encode_msg(&[1, 2, 3]);
        let mut h = header_of(&msg);
        h[0] = b'X';
        assert!(matches!(validate_header(&h), Err(Error::Protocol(_))));
        let mut h = header_of(&msg);
        h[4] = 9;
        assert!(matches!(validate_header(&h), Err(Error::Protocol(_))));
        let mut h = header_of(&msg);
        h[5..9].copy_from_slice(&u32::MAX.to_le_bytes());
        assert!(matches!(
            validate_header(&h),
            Err(Error::TooLarge { requested, .. }) if requested == u32::MAX as usize
        ));
        // the cap itself is accepted (allocation stays bounded)
        let mut h = header_of(&msg);
        h[5..9].copy_from_slice(&(MAX_FRAME_LEN as u32).to_le_bytes());
        assert_eq!(validate_header(&h).unwrap(), MAX_FRAME_LEN);
    }

    #[test]
    fn v2_encode_parse_roundtrip() {
        let frame = vec![3u8; 21];
        let msg = encode_msg_v2(&frame, 0xDEAD_BEEF_0123_4567);
        assert_eq!(msg.len(), HEADER_V2_LEN + 21 + CRC_LEN);
        let mut prefix = [0u8; PREFIX_LEN];
        prefix.copy_from_slice(&msg[..PREFIX_LEN]);
        assert_eq!(validate_prefix(&prefix).unwrap(), VERSION2);
        assert_eq!(header_len_for(VERSION2), HEADER_V2_LEN);
        let (seq, len) = parse_header(&msg[..HEADER_V2_LEN]).unwrap();
        assert_eq!(seq, Some(0xDEAD_BEEF_0123_4567));
        assert_eq!(len, 21);
        let (body, crc) = msg.split_at(msg.len() - CRC_LEN);
        let mut trailer = [0u8; CRC_LEN];
        trailer.copy_from_slice(crc);
        check_crc(body, &trailer).unwrap();
        assert_eq!(&body[HEADER_V2_LEN..], frame.as_slice());
    }

    #[test]
    fn parse_header_handles_both_versions_and_rejects_junk() {
        // v1 parses with no sequence number
        let msg = encode_msg(&[1, 2, 3]);
        assert_eq!(parse_header(&msg[..HEADER_LEN]).unwrap(), (None, 3));
        // wrong version byte in the prefix
        let mut p = [0u8; PREFIX_LEN];
        p.copy_from_slice(&msg[..PREFIX_LEN]);
        p[4] = 7;
        assert!(matches!(validate_prefix(&p), Err(Error::Protocol(_))));
        // a v2 header truncated to v1 length is a protocol error
        let msg2 = encode_msg_v2(&[1, 2, 3], 9);
        assert!(matches!(
            parse_header(&msg2[..HEADER_LEN]),
            Err(Error::Protocol(_))
        ));
        // hostile v2 length is rejected before allocation
        let mut hdr = [0u8; HEADER_V2_LEN];
        hdr.copy_from_slice(&msg2[..HEADER_V2_LEN]);
        hdr[13..17].copy_from_slice(&u32::MAX.to_le_bytes());
        assert!(matches!(
            parse_header(&hdr),
            Err(Error::TooLarge { requested, .. }) if requested == u32::MAX as usize
        ));
        // empty slice
        assert!(parse_header(&[]).is_err());
    }

    #[test]
    fn verdict_bytes_are_distinct() {
        assert_ne!(ACK, NACK);
        assert_ne!(ACK, BUSY);
        assert_ne!(NACK, BUSY);
    }

    #[test]
    fn crc_refresh_matches_encode() {
        let mut msg = encode_msg(&[9u8; 10]);
        let orig = msg.clone();
        // mutate + refresh: the CRC must track the new bytes
        msg[HEADER_LEN] ^= 0xFF;
        refresh_msg_crc(&mut msg);
        assert_ne!(msg, orig);
        let (body, crc) = msg.split_at(msg.len() - CRC_LEN);
        let mut trailer = [0u8; CRC_LEN];
        trailer.copy_from_slice(crc);
        check_crc(body, &trailer).unwrap();
        // short slices are a no-op, not a panic
        refresh_msg_crc(&mut [0u8; 2]);
    }
}
