//! Bounded receive-side dedup window for wire-v2 sequence numbers.
//!
//! The sender's delivery loop is at-least-once: a retransmit after a
//! lost ACK puts the same frame on the wire twice. Every v2 message
//! carries a per-sender-stream sequence number that survives
//! reconnects, so the receiver can recognize the second copy. The
//! window remembers the most recent sequence numbers in a fixed ring —
//! O(capacity) memory, O(1) per lookup — and classifies each arrival:
//!
//! * inside the ring and recorded → duplicate (ACK it, don't deliver);
//! * more than `capacity` below the highest seen → *conservatively*
//!   duplicate: the ring can no longer prove freshness, and with a
//!   bounded retransmission budget a genuinely fresh frame can never
//!   lag the stream head that far;
//! * anything else → fresh.
//!
//! Correctness of the ring indexing: slots are keyed by `seq %
//! capacity`. All remembered sequence numbers lie in a half-open span
//! of `capacity` consecutive values ending at the highest seen, and any
//! two distinct values in such a span have distinct residues, so a slot
//! collision can only evict a below-window entry — which the lag check
//! already classifies as duplicate without consulting the ring.
//!
//! Recording is split from lookup (`contains` / `observe`) on purpose:
//! the receiver records a sequence number only after the frame is
//! *admitted*. A frame rejected with BUSY at admission stays fresh, so
//! its retransmit is not mistaken for a duplicate.

/// See the module docs. `Default` capacity comes from
/// [`super::NetConfig::default`]'s `dedup_window`.
#[derive(Debug)]
pub struct DedupWindow {
    /// `slots[seq % capacity] == Some(seq)` means `seq` was observed
    /// recently enough for the ring to still prove it.
    slots: Vec<Option<u64>>,
    /// Highest sequence number ever observed (valid only if `any`).
    hi: u64,
    any: bool,
}

impl DedupWindow {
    /// A window remembering up to `capacity` recent sequence numbers
    /// (clamped to at least 1).
    pub fn new(capacity: usize) -> Self {
        DedupWindow { slots: vec![None; capacity.max(1)], hi: 0, any: false }
    }

    pub fn capacity(&self) -> usize {
        self.slots.len()
    }

    /// Has `seq` been observed (or is it too far below the window to
    /// prove otherwise)? Does not record anything.
    pub fn contains(&self, seq: u64) -> bool {
        if !self.any {
            return false;
        }
        let cap = self.slots.len() as u64;
        if seq < self.hi && self.hi - seq >= cap {
            // below the window: conservatively a duplicate
            return true;
        }
        let idx = (seq % cap) as usize;
        self.slots.get(idx).copied().flatten() == Some(seq)
    }

    /// Record `seq` as observed. Call only after the frame is admitted.
    pub fn observe(&mut self, seq: u64) {
        let cap = self.slots.len() as u64;
        let idx = (seq % cap) as usize;
        if let Some(slot) = self.slots.get_mut(idx) {
            *slot = Some(seq);
        }
        if !self.any || seq > self.hi {
            self.hi = seq;
        }
        self.any = true;
    }
}

#[cfg(test)]
mod tests {
    #![allow(clippy::unwrap_used, clippy::expect_used)]

    use super::*;

    #[test]
    fn fresh_then_duplicate() {
        let mut w = DedupWindow::new(8);
        assert!(!w.contains(1));
        w.observe(1);
        assert!(w.contains(1));
        assert!(!w.contains(2));
    }

    #[test]
    fn observe_is_explicit_not_implied_by_contains() {
        let mut w = DedupWindow::new(8);
        // a BUSY-rejected frame is looked up but never observed: its
        // retransmit must still be fresh
        assert!(!w.contains(5));
        assert!(!w.contains(5));
        w.observe(5);
        assert!(w.contains(5));
    }

    #[test]
    fn below_window_is_conservatively_duplicate() {
        let mut w = DedupWindow::new(4);
        w.observe(100);
        assert!(w.contains(96), "100 - 96 == capacity: below the window");
        assert!(!w.contains(97), "inside the window and never observed");
        assert!(!w.contains(101));
    }

    #[test]
    fn ring_collisions_only_evict_below_window_entries() {
        let mut w = DedupWindow::new(4);
        for seq in 0..100u64 {
            w.observe(seq);
            // every in-window observed seq stays provably observed
            for back in 0..4u64.min(seq + 1) {
                assert!(w.contains(seq - back), "seq {seq} back {back}");
            }
        }
    }

    #[test]
    fn capacity_clamped_to_one() {
        let mut w = DedupWindow::new(0);
        assert_eq!(w.capacity(), 1);
        w.observe(7);
        assert!(w.contains(7));
        assert!(!w.contains(8));
    }
}
