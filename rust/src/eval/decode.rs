//! Decode the detector's raw head output into scored boxes, plus NMS.
//!
//! YOLO-v3 parameterization (must mirror `detector.decode_head` in
//! Python): sigmoid cell offsets, exponential anchor scaling, objectness
//! times max class probability as the score.

use super::boxes::Box2D;
use crate::runtime::Manifest;
use crate::tensor::Tensor;

#[inline]
fn sigmoid(x: f32) -> f32 {
    1.0 / (1.0 + (-x).exp())
}

/// Decode one image's head map (grid, grid, A*(5+K)) into boxes with
/// score >= `score_thresh`.
pub fn decode_head(head: &Tensor, m: &Manifest, score_thresh: f32) -> Vec<Box2D> {
    let g = m.grid;
    let a = m.anchors.len();
    let k = m.num_classes;
    let stride = 5 + k;
    assert_eq!(head.shape(), &[g, g, a * stride], "head shape mismatch");
    let cell = m.cell as f32;
    let mut out = Vec::new();
    for gy in 0..g {
        for gx in 0..g {
            for ai in 0..a {
                let off = (gy * g + gx) * a * stride + ai * stride;
                let d = &head.data()[off..off + stride];
                let obj = sigmoid(d[4]);
                // softmax over class logits
                let max_logit = d[5..].iter().fold(f32::NEG_INFINITY, |acc, &v| acc.max(v));
                let mut denom = 0.0;
                for &l in &d[5..] {
                    denom += (l - max_logit).exp();
                }
                let (mut best_c, mut best_p) = (0usize, 0.0f32);
                for (ci, &l) in d[5..].iter().enumerate() {
                    let p = (l - max_logit).exp() / denom;
                    if p > best_p {
                        best_p = p;
                        best_c = ci;
                    }
                }
                let score = obj * best_p;
                if score < score_thresh {
                    continue;
                }
                let cx = (gx as f32 + sigmoid(d[0])) * cell;
                let cy = (gy as f32 + sigmoid(d[1])) * cell;
                let (aw, ah) = m.anchors[ai];
                let bw = aw * d[2].clamp(-6.0, 6.0).exp();
                let bh = ah * d[3].clamp(-6.0, 6.0).exp();
                out.push(Box2D {
                    x0: cx - bw / 2.0,
                    y0: cy - bh / 2.0,
                    x1: cx + bw / 2.0,
                    y1: cy + bh / 2.0,
                    score,
                    class: best_c,
                });
            }
        }
    }
    out
}

/// Greedy per-class non-maximum suppression.
pub fn nms(mut boxes: Vec<Box2D>, iou_thresh: f32) -> Vec<Box2D> {
    boxes.sort_by(|a, b| b.score.total_cmp(&a.score));
    let mut keep: Vec<Box2D> = Vec::with_capacity(boxes.len());
    'outer: for b in boxes {
        for k in &keep {
            if k.class == b.class && k.iou(&b) > iou_thresh {
                continue 'outer;
            }
        }
        keep.push(b);
    }
    keep
}

/// Standard post-processing: decode, NMS, cap detections per image.
pub fn postprocess(head: &Tensor, m: &Manifest) -> Vec<Box2D> {
    let mut boxes = nms(decode_head(head, m, 0.05), 0.45);
    boxes.truncate(50);
    boxes
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn nms_suppresses_same_class_overlaps_only() {
        let a = Box2D { x0: 0.0, y0: 0.0, x1: 10.0, y1: 10.0, score: 0.9, class: 0 };
        let b = Box2D { x0: 1.0, y0: 1.0, x1: 11.0, y1: 11.0, score: 0.8, class: 0 };
        let c = Box2D { x0: 1.0, y0: 1.0, x1: 11.0, y1: 11.0, score: 0.7, class: 1 };
        let d = Box2D { x0: 40.0, y0: 40.0, x1: 50.0, y1: 50.0, score: 0.6, class: 0 };
        let kept = nms(vec![a, b, c, d], 0.5);
        assert_eq!(kept.len(), 3);
        assert!(kept.iter().any(|k| k.class == 1));
        assert!(kept.iter().any(|k| (k.x0 - 40.0).abs() < 1e-6));
        // the survivor of the (a, b) pair is the higher-scoring one
        assert!(kept.iter().any(|k| (k.score - 0.9).abs() < 1e-6));
        assert!(!kept.iter().any(|k| (k.score - 0.8).abs() < 1e-6));
    }

    #[test]
    fn nms_keeps_order_by_score() {
        let mk = |s: f32, x: f32| Box2D {
            x0: x,
            y0: 0.0,
            x1: x + 5.0,
            y1: 5.0,
            score: s,
            class: 0,
        };
        let kept = nms(vec![mk(0.3, 0.0), mk(0.9, 20.0), mk(0.5, 40.0)], 0.5);
        assert_eq!(kept.len(), 3);
        assert!(kept[0].score >= kept[1].score && kept[1].score >= kept[2].score);
    }
}
