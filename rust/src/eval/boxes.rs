//! Detection boxes and IoU.

/// A detection or ground-truth box; corner format, x1/y1 exclusive.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Box2D {
    pub x0: f32,
    pub y0: f32,
    pub x1: f32,
    pub y1: f32,
    pub score: f32,
    pub class: usize,
}

impl Box2D {
    pub fn area(&self) -> f32 {
        (self.x1 - self.x0).max(0.0) * (self.y1 - self.y0).max(0.0)
    }

    /// Intersection-over-union with another box.
    pub fn iou(&self, other: &Box2D) -> f32 {
        let ix0 = self.x0.max(other.x0);
        let iy0 = self.y0.max(other.y0);
        let ix1 = self.x1.min(other.x1);
        let iy1 = self.y1.min(other.y1);
        let inter = (ix1 - ix0).max(0.0) * (iy1 - iy0).max(0.0);
        let union = self.area() + other.area() - inter;
        if union <= 0.0 {
            0.0
        } else {
            inter / union
        }
    }
}

impl From<crate::data::GtBox> for Box2D {
    fn from(g: crate::data::GtBox) -> Self {
        Box2D { x0: g.x0, y0: g.y0, x1: g.x1, y1: g.y1, score: 1.0, class: g.class }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mk(x0: f32, y0: f32, x1: f32, y1: f32) -> Box2D {
        Box2D { x0, y0, x1, y1, score: 1.0, class: 0 }
    }

    #[test]
    fn iou_identity_is_one() {
        let b = mk(2.0, 3.0, 10.0, 12.0);
        assert!((b.iou(&b) - 1.0).abs() < 1e-6);
    }

    #[test]
    fn iou_disjoint_is_zero() {
        assert_eq!(mk(0.0, 0.0, 4.0, 4.0).iou(&mk(5.0, 5.0, 9.0, 9.0)), 0.0);
    }

    #[test]
    fn iou_half_overlap() {
        // boxes of area 4 overlapping in area 2 -> IoU = 2/6
        let a = mk(0.0, 0.0, 2.0, 2.0);
        let b = mk(1.0, 0.0, 3.0, 2.0);
        assert!((a.iou(&b) - 1.0 / 3.0).abs() < 1e-6);
    }

    #[test]
    fn degenerate_boxes_are_safe() {
        let z = mk(1.0, 1.0, 1.0, 1.0);
        assert_eq!(z.area(), 0.0);
        assert_eq!(z.iou(&mk(0.0, 0.0, 4.0, 4.0)), 0.0);
    }
}
