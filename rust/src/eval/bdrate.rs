//! BD-Bitrate over mAP (the "BD-Bitrate-mAP" metric of [4], used in the
//! paper's §4 to report >90% savings vs HEVC-all-channels) plus the
//! "bit savings at a given accuracy-loss budget" headline numbers.
//!
//! Classic Bjøntegaard delta computation: fit cubic polynomials of
//! log-rate as a function of quality over the overlapping quality range
//! of two RD curves, integrate, report the average rate difference in %.

/// One rate-distortion point: bits per image (or KB — any consistent
/// unit) and mAP in [0, 1].
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct RdPoint {
    pub rate: f64,
    pub map: f64,
}

/// Fit a cubic through (x, y) pairs via least squares (n >= 4 exact for 4).
fn polyfit3(xs: &[f64], ys: &[f64]) -> [f64; 4] {
    // normal equations for degree-3 LS fit
    let n = xs.len();
    assert!(n >= 4, "BD-rate needs at least 4 RD points");
    let mut ata = [[0f64; 4]; 4];
    let mut atb = [0f64; 4];
    for i in 0..n {
        let powers = [1.0, xs[i], xs[i] * xs[i], xs[i] * xs[i] * xs[i]];
        for r in 0..4 {
            atb[r] += powers[r] * ys[i];
            for c in 0..4 {
                ata[r][c] += powers[r] * powers[c];
            }
        }
    }
    // gaussian elimination with partial pivoting
    let mut a = ata;
    let mut b = atb;
    for col in 0..4 {
        let mut piv = col;
        for r in col + 1..4 {
            if a[r][col].abs() > a[piv][col].abs() {
                piv = r;
            }
        }
        a.swap(col, piv);
        b.swap(col, piv);
        let d = a[col][col];
        assert!(d.abs() > 1e-12, "singular BD-rate fit");
        for r in col + 1..4 {
            let f = a[r][col] / d;
            for c in col..4 {
                a[r][c] -= f * a[col][c];
            }
            b[r] -= f * b[col];
        }
    }
    let mut x = [0f64; 4];
    for r in (0..4).rev() {
        let mut acc = b[r];
        for c in r + 1..4 {
            acc -= a[r][c] * x[c];
        }
        x[r] = acc / a[r][r];
    }
    x
}

fn poly_integral(coef: &[f64; 4], lo: f64, hi: f64) -> f64 {
    let eval = |x: f64| {
        coef[0] * x + coef[1] * x * x / 2.0 + coef[2] * x * x * x / 3.0
            + coef[3] * x * x * x * x / 4.0
    };
    eval(hi) - eval(lo)
}

/// BD-rate of `test` vs `anchor` in percent (negative = test saves bits
/// at equal quality). Both curves need >= 4 points and overlapping mAP
/// ranges.
pub fn bd_rate(anchor: &[RdPoint], test: &[RdPoint]) -> Option<f64> {
    if anchor.len() < 4 || test.len() < 4 {
        return None;
    }
    let prep = |pts: &[RdPoint]| -> (Vec<f64>, Vec<f64>) {
        let mut p: Vec<RdPoint> = pts.to_vec();
        p.sort_by(|a, b| a.map.total_cmp(&b.map));
        (p.iter().map(|q| q.map).collect(), p.iter().map(|q| q.rate.ln()).collect())
    };
    let (aq, ar) = prep(anchor);
    let (tq, tr) = prep(test);
    let lo = aq.first()?.max(*tq.first()?);
    let hi = aq.last()?.min(*tq.last()?);
    if hi <= lo {
        return None; // no quality overlap
    }
    let ca = polyfit3(&aq, &ar);
    let ct = polyfit3(&tq, &tr);
    let avg_diff = (poly_integral(&ct, lo, hi) - poly_integral(&ca, lo, hi)) / (hi - lo);
    Some((avg_diff.exp() - 1.0) * 100.0)
}

/// Bit savings (in %) of `test` vs the `reference_rate` at the smallest
/// rate whose mAP is within `max_loss` of `reference_map`. This is the
/// paper's headline statement ("62%/75% reduction with <1%/<2% loss").
pub fn savings_at_loss(
    test: &[RdPoint],
    reference_map: f64,
    reference_rate: f64,
    max_loss: f64,
) -> Option<(f64, RdPoint)> {
    let ok: Vec<&RdPoint> = test
        .iter()
        .filter(|p| reference_map - p.map <= max_loss)
        .collect();
    let best = ok.into_iter().min_by(|a, b| a.rate.total_cmp(&b.rate))?;
    Some(((1.0 - best.rate / reference_rate) * 100.0, *best))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn curve(scale: f64) -> Vec<RdPoint> {
        // a plausible RD curve: map rises with log rate
        [1.0, 2.0, 4.0, 8.0, 16.0]
            .iter()
            .map(|&r| RdPoint { rate: r * scale, map: 0.3 + 0.1 * (r as f64).ln() })
            .collect()
    }

    #[test]
    fn identical_curves_have_zero_bd_rate() {
        let a = curve(1.0);
        let d = bd_rate(&a, &a).unwrap();
        assert!(d.abs() < 1e-6, "{d}");
    }

    #[test]
    fn half_rate_curve_reports_minus_fifty() {
        let a = curve(1.0);
        let t = curve(0.5); // same quality at half the bits
        let d = bd_rate(&a, &t).unwrap();
        assert!((d + 50.0).abs() < 1.0, "{d}");
        // symmetric: anchor at half rate -> +100%
        let d2 = bd_rate(&t, &a).unwrap();
        assert!((d2 - 100.0).abs() < 2.0, "{d2}");
    }

    #[test]
    fn disjoint_quality_ranges_yield_none() {
        let a: Vec<RdPoint> =
            (1..5).map(|i| RdPoint { rate: i as f64, map: 0.1 + 0.01 * i as f64 }).collect();
        let b: Vec<RdPoint> =
            (1..5).map(|i| RdPoint { rate: i as f64, map: 0.8 + 0.01 * i as f64 }).collect();
        assert!(bd_rate(&a, &b).is_none());
    }

    #[test]
    fn savings_at_loss_picks_cheapest_admissible() {
        let pts = vec![
            RdPoint { rate: 100.0, map: 0.50 },
            RdPoint { rate: 60.0, map: 0.495 },
            RdPoint { rate: 30.0, map: 0.47 },
            RdPoint { rate: 10.0, map: 0.40 },
        ];
        let (sav, p) = savings_at_loss(&pts, 0.50, 100.0, 0.01).unwrap();
        assert_eq!(p.rate, 60.0);
        assert!((sav - 40.0).abs() < 1e-9);
        let (sav2, p2) = savings_at_loss(&pts, 0.50, 100.0, 0.04).unwrap();
        assert_eq!(p2.rate, 30.0);
        assert!((sav2 - 70.0).abs() < 1e-9);
        assert!(savings_at_loss(&pts, 0.9, 100.0, 0.01).is_none());
    }
}
