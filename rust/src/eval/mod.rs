//! Detection evaluation: box decoding, NMS, mAP, BD-rate metrics.

pub mod bdrate;
pub mod boxes;
pub mod decode;
pub mod map;
pub mod report;

pub use bdrate::{bd_rate, savings_at_loss, RdPoint};
pub use boxes::Box2D;
pub use decode::{decode_head, nms, postprocess};
pub use map::{evaluate, map_at, ImageEval, MapResult};
pub use report::{per_class, ClassReport};
