//! Mean Average Precision — the paper's accuracy metric.
//!
//! COCO-style evaluation: per class, detections over the whole set are
//! sorted by score and greedily matched to ground truth at an IoU
//! threshold (each GT matches at most once); AP is the 101-point
//! interpolated area under the precision-recall curve. `map_50` is the
//! headline metric (the paper's YOLO numbers are mAP@0.5-style);
//! `map_50_95` averages thresholds .50:.05:.95 like COCO.

use super::boxes::Box2D;

/// Detections + ground truth for one image.
#[derive(Debug, Clone, Default)]
pub struct ImageEval {
    pub detections: Vec<Box2D>,
    pub ground_truth: Vec<Box2D>,
}

/// Result of a mAP evaluation.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct MapResult {
    /// mAP at IoU 0.5 (the headline number).
    pub map_50: f64,
    /// COCO-style mAP averaged over IoU .50:.05:.95.
    pub map_50_95: f64,
}

/// Average precision for one class at one IoU threshold.
fn average_precision(images: &[ImageEval], class: usize, iou_thresh: f32) -> Option<f64> {
    // collect (score, image, box) detections of this class
    let mut dets: Vec<(f32, usize, Box2D)> = Vec::new();
    let mut total_gt = 0usize;
    for (i, img) in images.iter().enumerate() {
        total_gt += img.ground_truth.iter().filter(|g| g.class == class).count();
        for d in img.detections.iter().filter(|d| d.class == class) {
            dets.push((d.score, i, *d));
        }
    }
    if total_gt == 0 {
        return None; // class absent from the split -> excluded from the mean
    }
    dets.sort_by(|a, b| b.0.total_cmp(&a.0));

    let mut matched: Vec<Vec<bool>> = images
        .iter()
        .map(|img| vec![false; img.ground_truth.len()])
        .collect();
    let mut tp = vec![false; dets.len()];
    for (di, (_score, img_i, d)) in dets.iter().enumerate() {
        let gts = &images[*img_i].ground_truth;
        let mut best = -1isize;
        let mut best_iou = iou_thresh;
        for (gi, g) in gts.iter().enumerate() {
            if g.class != class || matched[*img_i][gi] {
                continue;
            }
            let iou = d.iou(g);
            if iou >= best_iou {
                best_iou = iou;
                best = gi as isize;
            }
        }
        if best >= 0 {
            matched[*img_i][best as usize] = true;
            tp[di] = true;
        }
    }

    // precision/recall curve
    let mut cum_tp = 0usize;
    let mut curve: Vec<(f64, f64)> = Vec::with_capacity(dets.len()); // (recall, precision)
    for (i, &is_tp) in tp.iter().enumerate() {
        if is_tp {
            cum_tp += 1;
        }
        let precision = cum_tp as f64 / (i + 1) as f64;
        let recall = cum_tp as f64 / total_gt as f64;
        curve.push((recall, precision));
    }
    // 101-point interpolation with monotone precision envelope
    let mut ap = 0.0;
    for r in 0..=100 {
        let r = r as f64 / 100.0;
        let p = curve
            .iter()
            .filter(|(rec, _)| *rec >= r)
            .map(|(_, prec)| *prec)
            .fold(0.0f64, f64::max);
        ap += p;
    }
    Some(ap / 101.0)
}

/// mAP at one threshold: mean over classes present in the ground truth.
pub fn map_at(images: &[ImageEval], num_classes: usize, iou_thresh: f32) -> f64 {
    let mut sum = 0.0;
    let mut count = 0usize;
    for c in 0..num_classes {
        if let Some(ap) = average_precision(images, c, iou_thresh) {
            sum += ap;
            count += 1;
        }
    }
    if count == 0 {
        0.0
    } else {
        sum / count as f64
    }
}

/// Full evaluation: mAP@0.5 and mAP@[.5:.95].
pub fn evaluate(images: &[ImageEval], num_classes: usize) -> MapResult {
    let map_50 = map_at(images, num_classes, 0.5);
    let mut acc = 0.0;
    let mut thresh = 0.50;
    let mut n = 0;
    while thresh < 0.96 {
        acc += map_at(images, num_classes, thresh as f32);
        thresh += 0.05;
        n += 1;
    }
    MapResult { map_50, map_50_95: acc / n as f64 }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn gt(x: f32, class: usize) -> Box2D {
        Box2D { x0: x, y0: 0.0, x1: x + 10.0, y1: 10.0, score: 1.0, class }
    }

    fn det(x: f32, score: f32, class: usize) -> Box2D {
        Box2D { x0: x, y0: 0.0, x1: x + 10.0, y1: 10.0, score, class }
    }

    #[test]
    fn perfect_detections_give_map_one() {
        let images = vec![ImageEval {
            detections: vec![det(0.0, 0.9, 0), det(20.0, 0.8, 1)],
            ground_truth: vec![gt(0.0, 0), gt(20.0, 1)],
        }];
        let r = evaluate(&images, 4);
        assert!((r.map_50 - 1.0).abs() < 1e-9, "{r:?}");
        assert!((r.map_50_95 - 1.0).abs() < 1e-9);
    }

    #[test]
    fn misses_reduce_map() {
        let images = vec![ImageEval {
            detections: vec![det(0.0, 0.9, 0)],
            ground_truth: vec![gt(0.0, 0), gt(30.0, 0)],
        }];
        let r = evaluate(&images, 4);
        // recall caps at 0.5 -> AP roughly (51/101)
        assert!(r.map_50 < 0.6 && r.map_50 > 0.4, "{r:?}");
    }

    #[test]
    fn false_positives_reduce_precision() {
        let clean = vec![ImageEval {
            detections: vec![det(0.0, 0.9, 0)],
            ground_truth: vec![gt(0.0, 0)],
        }];
        let noisy = vec![ImageEval {
            detections: vec![det(0.0, 0.9, 0), det(40.0, 0.95, 0)],
            ground_truth: vec![gt(0.0, 0)],
        }];
        assert!(map_at(&noisy, 4, 0.5) < map_at(&clean, 4, 0.5));
    }

    #[test]
    fn localization_quality_affects_high_thresholds_only() {
        // detection shifted by 2px of 10 -> IoU ~ 0.667
        let images = vec![ImageEval {
            detections: vec![det(2.0, 0.9, 0)],
            ground_truth: vec![gt(0.0, 0)],
        }];
        assert!(map_at(&images, 4, 0.5) > 0.99);
        assert!(map_at(&images, 4, 0.7) < 0.01);
        let r = evaluate(&images, 4);
        assert!(r.map_50_95 < r.map_50);
    }

    #[test]
    fn duplicate_detections_count_once() {
        let images = vec![ImageEval {
            detections: vec![det(0.0, 0.9, 0), det(0.5, 0.8, 0)],
            ground_truth: vec![gt(0.0, 0)],
        }];
        // second detection is a FP (GT already matched): precision at
        // rank 2 drops, but AP@0.5 stays 1.0 because recall 1.0 is hit at
        // rank 1 with precision 1.0.
        assert!((map_at(&images, 4, 0.5) - 1.0).abs() < 1e-9);
    }

    #[test]
    fn absent_classes_are_excluded() {
        let images = vec![ImageEval {
            detections: vec![det(0.0, 0.9, 0)],
            ground_truth: vec![gt(0.0, 0)],
        }];
        // class 1..3 never appear -> mAP over class 0 only
        assert!((map_at(&images, 4, 0.5) - 1.0).abs() < 1e-9);
    }
}
