//! Per-class AP breakdown and precision–recall curve export.

use super::boxes::Box2D;
use super::map::ImageEval;
use crate::json::Value;

/// One class's evaluation detail at a fixed IoU threshold.
#[derive(Debug, Clone)]
pub struct ClassReport {
    pub class: usize,
    pub ap: f64,
    pub num_gt: usize,
    pub num_det: usize,
    pub tp: usize,
    pub fp: usize,
    /// (recall, precision) points, in detection-rank order.
    pub pr_curve: Vec<(f64, f64)>,
}

/// Compute the per-class report at `iou_thresh`.
pub fn per_class(images: &[ImageEval], num_classes: usize, iou_thresh: f32) -> Vec<ClassReport> {
    let mut out = Vec::new();
    for class in 0..num_classes {
        let mut dets: Vec<(f32, usize, Box2D)> = Vec::new();
        let mut total_gt = 0usize;
        for (i, img) in images.iter().enumerate() {
            total_gt += img.ground_truth.iter().filter(|g| g.class == class).count();
            for d in img.detections.iter().filter(|d| d.class == class) {
                dets.push((d.score, i, *d));
            }
        }
        dets.sort_by(|a, b| b.0.total_cmp(&a.0));
        let mut matched: Vec<Vec<bool>> = images
            .iter()
            .map(|img| vec![false; img.ground_truth.len()])
            .collect();
        let mut tp_flags = vec![false; dets.len()];
        for (di, (_s, img_i, d)) in dets.iter().enumerate() {
            let gts = &images[*img_i].ground_truth;
            let mut best = -1isize;
            let mut best_iou = iou_thresh;
            for (gi, g) in gts.iter().enumerate() {
                if g.class != class || matched[*img_i][gi] {
                    continue;
                }
                let iou = d.iou(g);
                if iou >= best_iou {
                    best_iou = iou;
                    best = gi as isize;
                }
            }
            if best >= 0 {
                matched[*img_i][best as usize] = true;
                tp_flags[di] = true;
            }
        }
        let mut cum_tp = 0usize;
        let mut curve = Vec::with_capacity(dets.len());
        for (i, &t) in tp_flags.iter().enumerate() {
            if t {
                cum_tp += 1;
            }
            if total_gt > 0 {
                curve.push((
                    cum_tp as f64 / total_gt as f64,
                    cum_tp as f64 / (i + 1) as f64,
                ));
            }
        }
        // 101-point AP from the curve
        let ap = if total_gt == 0 {
            0.0
        } else {
            let mut acc = 0.0;
            for r in 0..=100 {
                let r = r as f64 / 100.0;
                let p = curve
                    .iter()
                    .filter(|(rec, _)| *rec >= r)
                    .map(|(_, prec)| *prec)
                    .fold(0.0f64, f64::max);
                acc += p;
            }
            acc / 101.0
        };
        let tp = cum_tp;
        out.push(ClassReport {
            class,
            ap,
            num_gt: total_gt,
            num_det: dets.len(),
            tp,
            fp: dets.len() - tp,
            pr_curve: curve,
        });
    }
    out
}

/// Markdown table of the per-class report.
pub fn table(reports: &[ClassReport], names: &[&str]) -> String {
    let mut out = String::from("| class | AP@0.5 | GT | det | TP | FP |\n|---|---|---|---|---|---|\n");
    for r in reports {
        let name = names.get(r.class).copied().unwrap_or("?");
        out.push_str(&format!(
            "| {name} | {:.4} | {} | {} | {} | {} |\n",
            r.ap, r.num_gt, r.num_det, r.tp, r.fp
        ));
    }
    out
}

/// JSON export of PR curves (decimated to <= 64 points per class).
pub fn pr_json(reports: &[ClassReport]) -> Value {
    let mut v = Value::obj();
    for r in reports {
        let step = (r.pr_curve.len() / 64).max(1);
        let pts: Vec<Value> = r
            .pr_curve
            .iter()
            .step_by(step)
            .map(|(rec, prec)| {
                let mut p = Value::obj();
                p.set("r", *rec).set("p", *prec);
                p
            })
            .collect();
        let mut c = Value::obj();
        c.set("ap", r.ap).set("points", Value::Arr(pts));
        v.set(&format!("class_{}", r.class), c);
    }
    v
}

#[cfg(test)]
mod tests {
    use super::*;

    fn b(x: f32, score: f32, class: usize) -> Box2D {
        Box2D { x0: x, y0: 0.0, x1: x + 10.0, y1: 10.0, score, class }
    }

    #[test]
    fn per_class_counts_tp_fp() {
        let images = vec![ImageEval {
            detections: vec![b(0.0, 0.9, 0), b(50.0, 0.8, 0), b(0.0, 0.7, 1)],
            ground_truth: vec![b(0.0, 1.0, 0), b(0.0, 1.0, 1)],
        }];
        let reps = per_class(&images, 2, 0.5);
        assert_eq!(reps[0].tp, 1);
        assert_eq!(reps[0].fp, 1);
        assert_eq!(reps[1].tp, 1);
        assert_eq!(reps[1].fp, 0);
        // class 0 reaches recall 1.0 at rank 1 with precision 1.0, so its
        // interpolated AP is also 1.0 despite the trailing FP
        assert!(reps[1].ap >= reps[0].ap);
        assert!((reps[0].pr_curve.last().unwrap().1 - 0.5).abs() < 1e-9);
    }

    #[test]
    fn consistency_with_map() {
        let images = vec![ImageEval {
            detections: vec![b(0.0, 0.9, 0)],
            ground_truth: vec![b(0.0, 1.0, 0)],
        }];
        let reps = per_class(&images, 4, 0.5);
        let mean: f64 = reps.iter().filter(|r| r.num_gt > 0).map(|r| r.ap).sum::<f64>()
            / reps.iter().filter(|r| r.num_gt > 0).count() as f64;
        let map = super::super::map::map_at(&images, 4, 0.5);
        assert!((mean - map).abs() < 1e-9);
    }

    #[test]
    fn table_and_json_render() {
        let images = vec![ImageEval {
            detections: vec![b(0.0, 0.9, 0)],
            ground_truth: vec![b(0.0, 1.0, 0)],
        }];
        let reps = per_class(&images, 2, 0.5);
        let t = table(&reps, &["circle", "square"]);
        assert!(t.contains("circle"));
        let j = pr_json(&reps);
        assert!(j.get("class_0").unwrap().get("ap").is_some());
    }
}
