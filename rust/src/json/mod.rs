//! Minimal JSON: value model, recursive-descent parser, serializer.
//!
//! serde is unavailable offline, and the needs here are small and fixed:
//! read `artifacts/manifest.json`, `channel_stats.json` and the golden
//! files, and write bench/metrics reports. The parser accepts the full
//! JSON grammar (RFC 8259) with the usual numeric caveat that all numbers
//! are f64 (the goldens therefore encode u64s as strings).

mod parse;
mod value;

pub use parse::{parse, ParseError, MAX_DEPTH};
pub use value::Value;

/// Parse a JSON file from disk.
pub fn from_file(path: &std::path::Path) -> anyhow::Result<Value> {
    let text = std::fs::read_to_string(path)
        .map_err(|e| anyhow::anyhow!("reading {}: {e}", path.display()))?;
    parse(&text).map_err(|e| anyhow::anyhow!("parsing {}: {e}", path.display()))
}

/// Serialize a value to a file with 1-space indentation.
pub fn to_file(path: &std::path::Path, v: &Value) -> anyhow::Result<()> {
    std::fs::write(path, v.pretty(1))?;
    Ok(())
}
