//! The JSON value model plus typed accessors and serialization.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON document node. Objects use a BTreeMap so serialization is
/// deterministic (handy for golden-file diffs).
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Value>),
    Obj(BTreeMap<String, Value>),
}

impl Value {
    // ---- constructors -------------------------------------------------
    pub fn obj() -> Value {
        Value::Obj(BTreeMap::new())
    }

    pub fn set(&mut self, key: &str, v: impl Into<Value>) -> &mut Self {
        if let Value::Obj(m) = self {
            m.insert(key.to_string(), v.into());
        } else {
            panic!("set() on non-object");
        }
        self
    }

    // ---- typed accessors ----------------------------------------------
    pub fn get(&self, key: &str) -> Option<&Value> {
        match self {
            Value::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// `get` that errors with the key name — for required fields.
    pub fn req(&self, key: &str) -> anyhow::Result<&Value> {
        self.get(key).ok_or_else(|| anyhow::anyhow!("missing key '{key}'"))
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|f| f as i64)
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().and_then(|f| if f >= 0.0 { Some(f as usize) } else { None })
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Value]> {
        match self {
            Value::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Value>> {
        match self {
            Value::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Array of numbers -> Vec<f64>.
    pub fn as_f64_vec(&self) -> Option<Vec<f64>> {
        self.as_arr()?.iter().map(Value::as_f64).collect()
    }

    /// Array of numbers -> Vec<usize>.
    pub fn as_usize_vec(&self) -> Option<Vec<usize>> {
        self.as_arr()?.iter().map(Value::as_usize).collect()
    }

    // ---- serialization --------------------------------------------------
    /// Compact single-line form.
    pub fn compact(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, None, 0);
        s
    }

    /// Pretty form with the given indent width.
    pub fn pretty(&self, indent: usize) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(indent), 0);
        s.push('\n');
        s
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Value::Null => out.push_str("null"),
            Value::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Value::Num(n) => write_num(out, *n),
            Value::Str(s) => write_str(out, s),
            Value::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    if let Some(w) = indent {
                        out.push('\n');
                        out.push_str(&" ".repeat(w * (depth + 1)));
                    }
                    v.write(out, indent, depth + 1);
                }
                if indent.is_some() && !a.is_empty() {
                    out.push('\n');
                    out.push_str(&" ".repeat(indent.unwrap() * depth));
                }
                out.push(']');
            }
            Value::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    if let Some(w) = indent {
                        out.push('\n');
                        out.push_str(&" ".repeat(w * (depth + 1)));
                    }
                    write_str(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                if indent.is_some() && !m.is_empty() {
                    out.push('\n');
                    out.push_str(&" ".repeat(indent.unwrap() * depth));
                }
                out.push('}');
            }
        }
    }
}

fn write_num(out: &mut String, n: f64) {
    if n.is_finite() && n == n.trunc() && n.abs() < 9.007_199_254_740_992e15 {
        let _ = write!(out, "{}", n as i64);
    } else if n.is_finite() {
        let _ = write!(out, "{n}");
    } else {
        out.push_str("null"); // JSON has no inf/nan
    }
}

fn write_str(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

impl From<f64> for Value {
    fn from(v: f64) -> Self {
        Value::Num(v)
    }
}
impl From<usize> for Value {
    fn from(v: usize) -> Self {
        Value::Num(v as f64)
    }
}
impl From<i64> for Value {
    fn from(v: i64) -> Self {
        Value::Num(v as f64)
    }
}
impl From<u64> for Value {
    fn from(v: u64) -> Self {
        Value::Num(v as f64)
    }
}
impl From<bool> for Value {
    fn from(v: bool) -> Self {
        Value::Bool(v)
    }
}
impl From<&str> for Value {
    fn from(v: &str) -> Self {
        Value::Str(v.to_string())
    }
}
impl From<String> for Value {
    fn from(v: String) -> Self {
        Value::Str(v)
    }
}
impl<T: Into<Value>> From<Vec<T>> for Value {
    fn from(v: Vec<T>) -> Self {
        Value::Arr(v.into_iter().map(Into::into).collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builder_and_accessors() {
        let mut v = Value::obj();
        v.set("a", 1.5).set("b", "x").set("c", vec![1u64, 2, 3]).set("d", true);
        assert_eq!(v.get("a").unwrap().as_f64(), Some(1.5));
        assert_eq!(v.get("b").unwrap().as_str(), Some("x"));
        assert_eq!(v.get("c").unwrap().as_usize_vec(), Some(vec![1, 2, 3]));
        assert_eq!(v.get("d").unwrap().as_bool(), Some(true));
        assert!(v.get("zzz").is_none());
        assert!(v.req("zzz").is_err());
    }

    #[test]
    fn compact_roundtrippable_text() {
        let mut v = Value::obj();
        v.set("s", "he said \"hi\"\n").set("n", -0.25);
        let text = v.compact();
        let back = crate::json::parse(&text).unwrap();
        assert_eq!(back, v);
    }

    #[test]
    fn integers_print_without_fraction() {
        assert_eq!(Value::Num(3.0).compact(), "3");
        assert_eq!(Value::Num(3.5).compact(), "3.5");
    }
}
