//! Recursive-descent JSON parser (RFC 8259).
//!
//! Parsing is total over arbitrary input bytes: every malformed input
//! yields a [`ParseError`], never a panic. Nesting is bounded by
//! [`MAX_DEPTH`] so a hostile `[[[[…` config cannot overflow the parse
//! stack (found by `tests/json_fuzz.rs`).

use super::Value;
use std::collections::BTreeMap;
use std::fmt;

/// Maximum container nesting depth. 128 is far beyond any real config
/// (ours nest 3-4 deep) while keeping worst-case stack use small.
pub const MAX_DEPTH: usize = 128;

#[derive(Debug)]
pub struct ParseError {
    pub offset: usize,
    pub message: String,
}

impl fmt::Display for ParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "JSON error at byte {}: {}", self.offset, self.message)
    }
}

impl std::error::Error for ParseError {}

pub fn parse(text: &str) -> Result<Value, ParseError> {
    let mut p = Parser { bytes: text.as_bytes(), pos: 0, depth: 0 };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        return Err(p.err("trailing characters"));
    }
    Ok(v)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
    depth: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> ParseError {
        ParseError { offset: self.pos, message: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), ParseError> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn value(&mut self) -> Result<Value, ParseError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Value::Str(self.string()?)),
            Some(b't') => self.literal("true", Value::Bool(true)),
            Some(b'f') => self.literal("false", Value::Bool(false)),
            Some(b'n') => self.literal("null", Value::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a JSON value")),
        }
    }

    fn literal(&mut self, word: &str, v: Value) -> Result<Value, ParseError> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{word}'")))
        }
    }

    /// Guard one level of container nesting; callers must pair this
    /// with a `depth -= 1` on every exit path (the `object`/`array`
    /// wrappers do).
    fn descend(&mut self) -> Result<(), ParseError> {
        if self.depth >= MAX_DEPTH {
            return Err(self.err("nesting too deep"));
        }
        self.depth += 1;
        Ok(())
    }

    fn object(&mut self) -> Result<Value, ParseError> {
        self.descend()?;
        let r = self.object_inner();
        self.depth -= 1;
        r
    }

    fn array(&mut self) -> Result<Value, ParseError> {
        self.descend()?;
        let r = self.array_inner();
        self.depth -= 1;
        r
    }

    fn object_inner(&mut self) -> Result<Value, ParseError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Value::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Value::Obj(map));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array_inner(&mut self) -> Result<Value, ParseError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Value::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Value::Arr(items));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, ParseError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'n') => out.push('\n'),
                        Some(b'r') => out.push('\r'),
                        Some(b't') => out.push('\t'),
                        Some(b'u') => {
                            self.pos += 1;
                            let cp = self.hex4()?;
                            // surrogate pair handling
                            let c = if (0xd800..0xdc00).contains(&cp) {
                                if self.peek() == Some(b'\\') {
                                    self.pos += 1;
                                    self.expect(b'u')?;
                                    let lo = self.hex4()?;
                                    // the second escape must be a low
                                    // surrogate, or `lo - 0xdc00`
                                    // underflows — a fuzz finding:
                                    // an escaped high surrogate
                                    // followed by an escaped 'A'
                                    // panicked with overflow checks on
                                    if !(0xdc00..0xe000).contains(&lo) {
                                        return Err(self.err("bad \\u escape"));
                                    }
                                    let combined = 0x10000
                                        + ((cp - 0xd800) << 10)
                                        + (lo - 0xdc00);
                                    char::from_u32(combined)
                                } else {
                                    None
                                }
                            } else {
                                char::from_u32(cp)
                            };
                            out.push(c.ok_or_else(|| self.err("bad \\u escape"))?);
                            continue; // hex4 advanced pos already
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // consume one UTF-8 scalar
                    let rest = &self.bytes[self.pos..];
                    let s = std::str::from_utf8(rest)
                        .map_err(|_| self.err("invalid UTF-8"))?;
                    let c = s.chars().next().unwrap();
                    out.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, ParseError> {
        if self.pos + 4 > self.bytes.len() {
            return Err(self.err("truncated \\u escape"));
        }
        let s = std::str::from_utf8(&self.bytes[self.pos..self.pos + 4])
            .map_err(|_| self.err("bad \\u escape"))?;
        let v = u32::from_str_radix(s, 16).map_err(|_| self.err("bad \\u escape"))?;
        self.pos += 4;
        Ok(v)
    }

    fn number(&mut self) -> Result<Value, ParseError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Value::Num)
            .map_err(|_| self.err("bad number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(parse("42").unwrap().as_f64(), Some(42.0));
        assert_eq!(parse("-1.5e3").unwrap().as_f64(), Some(-1500.0));
        assert_eq!(parse("true").unwrap().as_bool(), Some(true));
        assert_eq!(parse("null").unwrap(), Value::Null);
        assert_eq!(parse("\"a\\nb\"").unwrap().as_str(), Some("a\nb"));
    }

    #[test]
    fn parses_nested() {
        let v = parse(r#"{"a": [1, {"b": "c"}, null], "d": {}}"#).unwrap();
        let arr = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr.len(), 3);
        assert_eq!(arr[1].get("b").unwrap().as_str(), Some("c"));
    }

    #[test]
    fn unicode_escapes() {
        assert_eq!(parse(r#""A""#).unwrap().as_str(), Some("A"));
        // surrogate pair: U+1F600
        assert_eq!(parse(r#""😀""#).unwrap().as_str(), Some("😀"));
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("01a").is_err());
        assert!(parse("\"unterminated").is_err());
        assert!(parse("{}extra").is_err());
    }

    #[test]
    fn lone_or_mismatched_surrogates_are_errors_not_panics() {
        // high surrogate followed by a non-low-surrogate escape used to
        // underflow `lo - 0xdc00` under overflow checks
        assert!(parse("\"\\ud800\\u0041\"").is_err());
        assert!(parse(r#""\ud800A""#).is_err());
        assert!(parse(r#""\ud800\ud800""#).is_err());
        assert!(parse(r#""\ud800x""#).is_err());
        assert!(parse(r#""\udc00""#).is_err());
        // a real pair still decodes
        assert_eq!(parse(r#""😀""#).unwrap().as_str(), Some("😀"));
    }

    #[test]
    fn nesting_depth_is_bounded() {
        // comfortably inside the limit
        let ok = format!("{}1{}", "[".repeat(MAX_DEPTH - 1), "]".repeat(MAX_DEPTH - 1));
        assert!(parse(&ok).is_ok());
        // one past it: typed error, not a stack overflow
        let deep = format!("{}1{}", "[".repeat(MAX_DEPTH + 1), "]".repeat(MAX_DEPTH + 1));
        let err = parse(&deep).unwrap_err();
        assert!(err.message.contains("nesting"), "{err}");
        // alternating containers count too
        let alt = "[{\"k\":".repeat(MAX_DEPTH) + "1" + &"}]".repeat(MAX_DEPTH);
        assert!(parse(&alt).is_err());
    }

    #[test]
    fn roundtrip_pretty() {
        let src = r#"{"x": [1, 2, 3], "y": {"z": "w"}}"#;
        let v = parse(src).unwrap();
        let again = parse(&v.pretty(2)).unwrap();
        assert_eq!(v, again);
    }
}
