//! Micro-bench harness (criterion is unavailable offline).
//!
//! `cargo bench` binaries (benches/*.rs, harness = false) use this to get
//! warmup, repetition, and robust statistics, and to emit the markdown
//! tables EXPERIMENTS.md records.

use std::time::Instant;

/// Timing statistics over repeated runs (all in microseconds).
#[derive(Debug, Clone, Copy)]
pub struct Stats {
    pub iters: usize,
    pub mean_us: f64,
    pub median_us: f64,
    pub min_us: f64,
    pub p95_us: f64,
    pub stddev_us: f64,
}

impl Stats {
    pub fn throughput(&self, items_per_iter: f64) -> f64 {
        items_per_iter / (self.mean_us / 1e6)
    }
}

/// Time `f` with warmup; chooses iteration count so total time ~budget.
pub fn time_fn<F: FnMut()>(mut f: F, warmup: usize, min_iters: usize, budget_ms: f64) -> Stats {
    for _ in 0..warmup {
        f();
    }
    // estimate one call
    let t0 = Instant::now();
    f();
    let est_us = t0.elapsed().as_secs_f64() * 1e6;
    let iters = ((budget_ms * 1e3 / est_us.max(0.01)) as usize)
        .clamp(min_iters, 100_000);
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t = Instant::now();
        f();
        samples.push(t.elapsed().as_secs_f64() * 1e6);
    }
    stats_of(&mut samples)
}

fn stats_of(samples: &mut [f64]) -> Stats {
    samples.sort_by(|a, b| a.total_cmp(b));
    let n = samples.len();
    let mean = samples.iter().sum::<f64>() / n as f64;
    let var = samples.iter().map(|s| (s - mean) * (s - mean)).sum::<f64>() / n as f64;
    Stats {
        iters: n,
        mean_us: mean,
        median_us: samples[n / 2],
        min_us: samples[0],
        p95_us: samples[(n as f64 * 0.95) as usize % n],
        stddev_us: var.sqrt(),
    }
}

/// Pretty one-line summary.
pub fn fmt_stats(name: &str, s: &Stats) -> String {
    format!(
        "{name:<34} mean {m:>9.1} us  median {md:>9.1} us  min {mn:>9.1} us  p95 {p:>9.1} us  (n={i})",
        m = s.mean_us,
        md = s.median_us,
        mn = s.min_us,
        p = s.p95_us,
        i = s.iters
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_are_sane() {
        let s = time_fn(|| { std::hint::black_box((0..1000).sum::<u64>()); }, 2, 10, 5.0);
        assert!(s.iters >= 10);
        assert!(s.min_us <= s.median_us && s.median_us <= s.p95_us);
        assert!(s.mean_us > 0.0);
    }

    #[test]
    fn throughput_inverts_time() {
        let s = Stats {
            iters: 1,
            mean_us: 1000.0,
            median_us: 1000.0,
            min_us: 1000.0,
            p95_us: 1000.0,
            stddev_us: 0.0,
        };
        assert!((s.throughput(10.0) - 10_000.0).abs() < 1e-9);
    }
}
