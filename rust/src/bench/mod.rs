//! Micro-bench harness (criterion is unavailable offline).
//!
//! `cargo bench` binaries (benches/*.rs, harness = false) use this to get
//! warmup, repetition, and robust statistics, and to emit the markdown
//! tables EXPERIMENTS.md records.
//!
//! Every bench binary also supports `--json-out [DIR]` (or
//! `--json-out=DIR`): build a [`JsonReport`], record each case's
//! [`Stats`] and derived metrics, and [`JsonReport::write`] emits
//! `BENCH_<name>.json` — machine-readable mean/median/p95/throughput per
//! case, so CI can diff runs instead of scraping stdout tables.

use crate::json::Value;
use std::path::{Path, PathBuf};
use std::time::Instant;

/// Timing statistics over repeated runs (all in microseconds).
#[derive(Debug, Clone, Copy)]
pub struct Stats {
    pub iters: usize,
    pub mean_us: f64,
    pub median_us: f64,
    pub min_us: f64,
    pub p95_us: f64,
    pub stddev_us: f64,
}

impl Stats {
    pub fn throughput(&self, items_per_iter: f64) -> f64 {
        items_per_iter / (self.mean_us / 1e6)
    }
}

/// Time `f` with warmup; chooses iteration count so total time ~budget.
pub fn time_fn<F: FnMut()>(mut f: F, warmup: usize, min_iters: usize, budget_ms: f64) -> Stats {
    for _ in 0..warmup {
        f();
    }
    // estimate one call
    let t0 = Instant::now();
    f();
    let est_us = t0.elapsed().as_secs_f64() * 1e6;
    let iters = ((budget_ms * 1e3 / est_us.max(0.01)) as usize)
        .clamp(min_iters, 100_000);
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t = Instant::now();
        f();
        samples.push(t.elapsed().as_secs_f64() * 1e6);
    }
    stats_of(&mut samples)
}

fn stats_of(samples: &mut [f64]) -> Stats {
    samples.sort_by(|a, b| a.total_cmp(b));
    let n = samples.len();
    let mean = samples.iter().sum::<f64>() / n as f64;
    let var = samples.iter().map(|s| (s - mean) * (s - mean)).sum::<f64>() / n as f64;
    Stats {
        iters: n,
        mean_us: mean,
        median_us: samples[n / 2],
        min_us: samples[0],
        p95_us: samples[(n as f64 * 0.95) as usize % n],
        stddev_us: var.sqrt(),
    }
}

/// Pretty one-line summary.
pub fn fmt_stats(name: &str, s: &Stats) -> String {
    format!(
        "{name:<34} mean {m:>9.1} us  median {md:>9.1} us  min {mn:>9.1} us  p95 {p:>9.1} us  (n={i})",
        m = s.mean_us,
        md = s.median_us,
        mn = s.min_us,
        p = s.p95_us,
        i = s.iters
    )
}

/// Machine-readable bench results, one object per case, written as
/// `BENCH_<name>.json`.
#[derive(Debug)]
pub struct JsonReport {
    name: String,
    cases: Vec<(String, Value)>,
}

impl JsonReport {
    pub fn new(name: &str) -> Self {
        JsonReport { name: name.to_string(), cases: Vec::new() }
    }

    fn case_mut(&mut self, case: &str) -> &mut Value {
        if !self.cases.iter().any(|(c, _)| c == case) {
            self.cases.push((case.to_string(), Value::obj()));
        }
        // the entry exists by construction above
        let idx = self
            .cases
            .iter()
            .position(|(c, _)| c == case)
            .unwrap_or_default();
        &mut self.cases[idx].1
    }

    /// Record timing statistics for a case (mean/median/min/p95/stddev
    /// in microseconds, plus the iteration count).
    pub fn stats(&mut self, case: &str, s: &Stats) -> &mut Self {
        self.case_mut(case)
            .set("iters", s.iters)
            .set("mean_us", s.mean_us)
            .set("median_us", s.median_us)
            .set("min_us", s.min_us)
            .set("p95_us", s.p95_us)
            .set("stddev_us", s.stddev_us);
        self
    }

    /// Record an arbitrary named metric for a case (e.g. throughput in
    /// items/s, compressed size in bytes, speedup ratios).
    pub fn metric(&mut self, case: &str, key: &str, value: impl Into<Value>) -> &mut Self {
        self.case_mut(case).set(key, value);
        self
    }

    pub fn to_value(&self) -> Value {
        let mut cases = Value::obj();
        for (case, v) in &self.cases {
            cases.set(case, v.clone());
        }
        let mut root = Value::obj();
        root.set("bench", self.name.as_str()).set("cases", cases);
        root
    }

    /// Write `BENCH_<name>.json` under `dir`; returns the path.
    pub fn write(&self, dir: &Path) -> anyhow::Result<PathBuf> {
        let path = dir.join(format!("BENCH_{}.json", self.name));
        crate::json::to_file(&path, &self.to_value())?;
        Ok(path)
    }
}

/// Parse `--json-out [DIR]` / `--json-out=DIR` from an argv slice.
/// `None` means the flag is absent; a bare flag defaults to `.`.
/// (Bench binaries run with `harness = false` parse their own argv.)
pub fn json_out_from(argv: &[String]) -> Option<PathBuf> {
    let mut it = argv.iter().peekable();
    while let Some(arg) = it.next() {
        if let Some(dir) = arg.strip_prefix("--json-out=") {
            return Some(PathBuf::from(dir));
        }
        if arg == "--json-out" {
            // a following non-flag token is the directory
            return match it.peek() {
                Some(next) if !next.starts_with("--") => Some(PathBuf::from(next.as_str())),
                _ => Some(PathBuf::from(".")),
            };
        }
    }
    None
}

/// [`json_out_from`] over the process argv.
pub fn json_out_dir() -> Option<PathBuf> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    json_out_from(&argv)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_are_sane() {
        let s = time_fn(|| { std::hint::black_box((0..1000).sum::<u64>()); }, 2, 10, 5.0);
        assert!(s.iters >= 10);
        assert!(s.min_us <= s.median_us && s.median_us <= s.p95_us);
        assert!(s.mean_us > 0.0);
    }

    #[test]
    fn json_out_flag_parsing() {
        let argv = |s: &[&str]| s.iter().map(|x| x.to_string()).collect::<Vec<_>>();
        assert_eq!(json_out_from(&argv(&[])), None);
        assert_eq!(json_out_from(&argv(&["--smoke"])), None);
        assert_eq!(
            json_out_from(&argv(&["--json-out"])),
            Some(PathBuf::from("."))
        );
        assert_eq!(
            json_out_from(&argv(&["--json-out", "out"])),
            Some(PathBuf::from("out"))
        );
        assert_eq!(
            json_out_from(&argv(&["--json-out=/tmp/x"])),
            Some(PathBuf::from("/tmp/x"))
        );
        // a trailing flag is not swallowed as the directory
        assert_eq!(
            json_out_from(&argv(&["--json-out", "--smoke"])),
            Some(PathBuf::from("."))
        );
    }

    #[test]
    fn json_report_shape_and_write() {
        let mut rep = JsonReport::new("unit");
        let s = Stats {
            iters: 3,
            mean_us: 2.0,
            median_us: 2.0,
            min_us: 1.0,
            p95_us: 3.0,
            stddev_us: 0.5,
        };
        rep.stats("encode_k1", &s);
        rep.metric("encode_k1", "throughput_mps", 12.5);
        rep.metric("encode_k4", "bytes", 1024usize);
        let v = rep.to_value();
        assert_eq!(v.get("bench").and_then(Value::as_str), Some("unit"));
        let cases = v.get("cases").expect("cases");
        let k1 = cases.get("encode_k1").expect("case");
        assert_eq!(k1.get("mean_us").and_then(Value::as_f64), Some(2.0));
        assert_eq!(k1.get("throughput_mps").and_then(Value::as_f64), Some(12.5));
        assert!(cases.get("encode_k4").is_some());

        let dir = std::env::temp_dir().join("baf_bench_json_test");
        std::fs::create_dir_all(&dir).expect("mkdir");
        let path = rep.write(&dir).expect("write");
        assert!(path.ends_with("BENCH_unit.json"));
        let back = crate::json::from_file(&path).expect("parse back");
        assert_eq!(back.get("bench").and_then(Value::as_str), Some("unit"));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn throughput_inverts_time() {
        let s = Stats {
            iters: 1,
            mean_us: 1000.0,
            median_us: 1000.0,
            min_us: 1000.0,
            p95_us: 1000.0,
            stddev_us: 0.0,
        };
        assert!((s.throughput(10.0) - 10_000.0).abs() < 1e-9);
    }
}
