//! `baf` — the leader binary: run the split pipeline, the experiments,
//! the serving demo, and codec tools from one CLI.

use anyhow::Result;
use baf::cli::Args;
use baf::codec::CodecKind;
use baf::config::{PipelineConfig, ServerConfig};
use baf::coordinator::{run_server, CloudOnly, Pipeline};
use baf::experiments::{self, Context, DEFAULT_EVAL_IMAGES};
use baf::runtime::{default_artifact_dir, Engine};
use baf::selection::Policy;
use std::path::PathBuf;
use std::rc::Rc;

const HELP: &str = "\
baf — Back-and-Forth prediction for deep tensor compression (ICASSP'20 repro)

USAGE: baf <command> [options]

COMMANDS
  run        run the split pipeline over the eval set; report mAP + rate
             --c N --n BITS --codec tlc|png|zstd|mic --qp QP
             --policy corr|variance|first|random:SEED --no-consolidate
             --images N --stripes K (striped v2 frames, parallel codec)
  baseline   cloud-only (unmodified detector) mAP over the eval set
  channels   E1 / Fig.3: mAP vs C sweep             [--images N]
  sweep      E2/E3 / Fig.4: rate–mAP curves + headline savings
             [--c N] [--images N]
  codecs     E4: lossless codec comparison          [--images N]
  ablate     E6: consolidation + selection-policy ablations
  serve      E5: pipelined serving demo with Poisson arrivals
             --rate RPS --requests N --batch-cap B --deadline-us US
             --decode-workers N (stripe-decode pool width)
             --corrupt-rate P (inject faults; frames that fail to decode
             are dropped and counted, not fatal) --stripes K
             --listen ADDR (cloud side: accept edge frames over TCP,
             e.g. --listen 0.0.0.0:7878; default is the in-process edge)
             --connect ADDR (edge side: run only the edge stage and ship
             frames to a --listen server over TCP)
             --ingress-depth N (TCP mode: bounded ingress queue; full =>
             shed oldest expired frame or answer BUSY)
             --shed-deadline-ms MS (TCP mode: per-frame latency budget
             for the ingress shed policy)
  encode     compress a CHW f32 .npy tensor into a .baf frame
             <in.npy> <out.baf> [--n BITS] [--codec NAME] [--qp QP]
             [--stripes K]
  decode     decompress a .baf frame back to a CHW f32 .npy
             <in.baf> <out.npy>
  report     per-class AP breakdown + PR-curve JSON   [--images N] [--out F]
  render     write eval images as PPM with GT + detections drawn
             [--count N] [--out-dir D]
  inspect    print the artifact manifest and channel statistics
  golden     verify Rust implementations against python goldens

COMMON OPTIONS
  --artifacts DIR   artifact directory (default: ./artifacts or $BAF_ARTIFACTS)
";

fn artifact_dir(args: &Args) -> PathBuf {
    args.opt("artifacts").map(PathBuf::from).unwrap_or_else(default_artifact_dir)
}

fn pipeline_cfg(args: &Args) -> Result<PipelineConfig> {
    let mut cfg = PipelineConfig {
        artifact_dir: artifact_dir(args),
        ..Default::default()
    };
    if let Some(c) = args.opt_parse::<usize>("c")? {
        anyhow::ensure!(c >= 1, "--c: must be >= 1, got {c}");
        cfg.c = c;
    }
    if let Some(n) = args.opt_parse::<u8>("n")? {
        anyhow::ensure!(
            (1..=16).contains(&n),
            "--n: bit depth must be in 1..=16, got {n}"
        );
        cfg.n = n;
    }
    if let Some(codec) = args.opt("codec") {
        cfg.codec = CodecKind::from_name(codec)?;
    }
    if let Some(qp) = args.opt_parse::<u8>("qp")? {
        cfg.qp = qp;
    }
    if let Some(p) = args.opt("policy") {
        cfg.policy = Policy::parse(p)?;
    }
    if args.has_flag("no-consolidate") {
        cfg.consolidate = false;
    }
    if let Some(k) = args.opt_parse::<usize>("stripes")? {
        anyhow::ensure!(
            (1..=1024).contains(&k),
            "--stripes: must be in 1..=1024, got {k}"
        );
        cfg.stripes = k;
    }
    Ok(cfg)
}

fn images(args: &Args) -> Result<usize> {
    Ok(args.opt_parse::<usize>("images")?.unwrap_or(DEFAULT_EVAL_IMAGES))
}

fn cmd_run(args: &Args) -> Result<()> {
    args.expect_known(&[
        "artifacts", "c", "n", "codec", "qp", "policy", "no-consolidate", "images",
        "stripes",
    ])?;
    let cfg = pipeline_cfg(args)?;
    let n_img = images(args)?;
    println!(
        "pipeline: C={} n={} codec={} qp={} policy={} consolidate={} stripes={}",
        cfg.c,
        cfg.n,
        cfg.codec.name(),
        cfg.qp,
        cfg.policy.name(),
        cfg.consolidate,
        cfg.stripes
    );
    let pipe = Pipeline::open(cfg)?;
    let samples = baf::data::eval_set(n_img);
    let (map, bytes) = pipe.evaluate_set(&samples)?;
    println!("eval images: {n_img}");
    println!("mAP@0.5     = {:.4}", map.map_50);
    println!("mAP@[.5:.95]= {:.4}", map.map_50_95);
    println!("mean rate   = {bytes:.0} bytes/image");
    // stage latency of a single request
    let out = pipe.process(&samples[0].image)?;
    println!("\nper-stage latency (single request):");
    for (name, us) in &out.stages {
        println!("  {name:<18} {us:>9.1} us");
    }
    println!("  consolidation clamp rate: {:.4}", out.consolidation_rate);
    Ok(())
}

fn cmd_baseline(args: &Args) -> Result<()> {
    args.expect_known(&["artifacts", "images"])?;
    let engine = Rc::new(Engine::new(&artifact_dir(args))?);
    let co = CloudOnly::new(engine);
    let samples = baf::data::eval_set(images(args)?);
    let map = co.evaluate_set(&samples)?;
    println!("cloud-only mAP@0.5 = {:.4}", map.map_50);
    println!("cloud-only mAP@[.5:.95] = {:.4}", map.map_50_95);
    Ok(())
}

fn cmd_channels(args: &Args) -> Result<()> {
    args.expect_known(&["artifacts", "images"])?;
    let ctx = Context::open(&artifact_dir(args), images(args)?)?;
    let (cloud_map, rows) = experiments::fig3(&ctx, &[4, 8, 16, 32, 64])?;
    print!("{}", experiments::fig3_table(cloud_map, &rows));
    Ok(())
}

fn cmd_sweep(args: &Args) -> Result<()> {
    args.expect_known(&["artifacts", "images", "c"])?;
    let c = args.opt_parse::<usize>("c")?.unwrap_or(16);
    let ctx = Context::open(&artifact_dir(args), images(args)?)?;
    let r = experiments::fig4(&ctx, c)?;
    print!("{}", experiments::fig4_table(&r, c));
    Ok(())
}

fn cmd_codecs(args: &Args) -> Result<()> {
    args.expect_known(&["artifacts", "images"])?;
    let ctx = Context::open(&artifact_dir(args), images(args)?.min(32))?;
    let rows = experiments::codec_table(&ctx, &[8, 16, 32], &[2, 4, 6, 8])?;
    print!("{}", experiments::codec_table_fmt(&rows));
    Ok(())
}

fn cmd_ablate(args: &Args) -> Result<()> {
    args.expect_known(&["artifacts", "images"])?;
    let ctx = Context::open(&artifact_dir(args), images(args)?)?;
    println!("E6 — ablations (C=16, n=8 unless noted)\n");
    println!("selection policy (beta-fill reconstruction, no BaF):");
    println!("| policy | mAP@0.5 | bytes/img |");
    println!("|---|---|---|");
    for p in [Policy::Correlation, Policy::Variance, Policy::FirstC, Policy::Random(1)] {
        let (map, bytes) = ctx.beta_fill(p, 16, 8)?;
        println!("| {} | {:.4} | {:.0} |", p.name(), map, bytes);
    }
    let (baf_map, _) = ctx.point(16, 8, CodecKind::Tlc, 0)?;
    println!("| correlation + BaF | {baf_map:.4} | (same rate) |");
    println!("\nEq.6 consolidation:");
    println!("| n | mAP on | mAP off | clamp rate |");
    println!("|---|---|---|---|");
    for n in [4u8, 6, 8] {
        let (on, off, rate) = ctx.consolidation_ablation(16, n)?;
        println!("| {n} | {on:.4} | {off:.4} | {rate:.4} |");
    }
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    args.expect_known(&[
        "artifacts", "c", "n", "codec", "qp", "policy", "no-consolidate", "rate",
        "requests", "batch-cap", "deadline-us", "decode-workers", "burst",
        "corrupt-rate", "stripes", "listen", "connect", "ingress-depth",
        "shed-deadline-ms",
    ])?;
    let pcfg = pipeline_cfg(args)?;
    let mut scfg = ServerConfig::default();
    if let Some(v) = args.opt_parse::<f64>("rate")? {
        scfg.arrival_rate = v;
    }
    if let Some(v) = args.opt_parse::<usize>("requests")? {
        scfg.num_requests = v;
    }
    if let Some(v) = args.opt_parse::<usize>("batch-cap")? {
        scfg.batch_cap = v;
    }
    if let Some(v) = args.opt_parse::<u64>("deadline-us")? {
        scfg.batch_deadline_us = v;
    }
    if let Some(v) = args.opt_parse::<usize>("decode-workers")? {
        scfg.decode_workers = v;
    }
    if let Some(v) = args.opt_parse::<f64>("burst")? {
        scfg.burst_factor = v;
    }
    if let Some(v) = args.opt_parse::<f64>("corrupt-rate")? {
        anyhow::ensure!(
            (0.0..=1.0).contains(&v),
            "--corrupt-rate: must be in 0.0..=1.0, got {v}"
        );
        scfg.corrupt_rate = v;
    }
    scfg.listen = args.opt("listen").map(str::to_string);
    scfg.connect = args.opt("connect").map(str::to_string);
    if let Some(v) = args.opt_parse::<usize>("ingress-depth")? {
        anyhow::ensure!(v >= 1, "--ingress-depth: must be >= 1, got {v}");
        scfg.ingress_depth = v;
    }
    if let Some(v) = args.opt_parse::<u64>("shed-deadline-ms")? {
        scfg.shed_deadline_ms = v;
    }
    anyhow::ensure!(
        scfg.listen.is_none() || scfg.connect.is_none(),
        "--listen and --connect are mutually exclusive (one process is \
         either the cloud side or the edge side)"
    );
    if let Some(connect) = scfg.connect.clone() {
        println!(
            "edge client: {} requests @ {}/s -> {connect}",
            scfg.num_requests, scfg.arrival_rate
        );
        let report = baf::coordinator::run_edge_client(&pcfg, &scfg, &connect)?;
        println!(
            "\nsent {} frames ({} B on the wire) in {:.2}s, {} rejected, \
             {} busy, {} shed, {} failed, {} reconnects",
            report.sent,
            report.bytes,
            report.wall_seconds,
            report.rejected,
            report.busy,
            report.shed,
            report.failed,
            report.reconnects
        );
        println!("\n{}", report.table);
        return Ok(());
    }
    println!(
        "serving: {} requests @ {}/s, batch cap {}, deadline {} us, {} decode workers",
        scfg.num_requests,
        scfg.arrival_rate,
        scfg.batch_cap,
        scfg.batch_deadline_us,
        scfg.decode_workers
    );
    if scfg.corrupt_rate > 0.0 {
        println!("fault injection: corrupting ~{:.1}% of frames", scfg.corrupt_rate * 100.0);
    }
    if let Some(listen) = &scfg.listen {
        println!("transport: accepting edge frames over TCP on {listen}");
    }
    let report = run_server(&pcfg, &scfg)?;
    println!(
        "\nserved {} requests in {:.2}s -> {:.1} req/s (mean batch {:.2}, \
         {} dropped, {} shed, {} busy)",
        report.requests,
        report.wall_seconds,
        report.throughput_rps,
        report.mean_batch_size,
        report.dropped,
        report.shed,
        report.busy
    );
    println!("\n{}", report.table);
    Ok(())
}

fn cmd_encode(args: &Args) -> Result<()> {
    args.expect_known(&["n", "codec", "qp", "stripes"])?;
    let [input, output] = args.positional.as_slice() else {
        anyhow::bail!("usage: baf encode <in.npy> <out.baf> [--n BITS] [--codec NAME]");
    };
    let t = baf::tio::read(std::path::Path::new(input))?.into_tensor()?;
    anyhow::ensure!(t.shape().len() == 3, "expected CHW rank-3 tensor");
    let n = args.opt_parse::<u8>("n")?.unwrap_or(8);
    let codec = CodecKind::from_name(args.opt("codec").unwrap_or("tlc"))?;
    let qp = args.opt_parse::<u8>("qp")?.unwrap_or(0);
    let stripes = args.opt_parse::<usize>("stripes")?.unwrap_or(1);
    anyhow::ensure!(
        (1..=1024).contains(&stripes),
        "--stripes: must be in 1..=1024, got {stripes}"
    );
    let q = baf::quant::quantize(&t, n);
    let frame = if stripes > 1 {
        baf::codec::container::pack_v2(&q, codec, qp, stripes)
    } else {
        baf::codec::container::pack(&q, codec, qp)
    };
    let raw = t.len() * 4;
    std::fs::write(output, &frame)?;
    println!(
        "{input} ({raw} B raw f32) -> {output} ({} B, {:.2}x, codec {}, n={n})",
        frame.len(),
        raw as f64 / frame.len() as f64,
        codec.name()
    );
    Ok(())
}

fn cmd_decode(args: &Args) -> Result<()> {
    args.expect_known(&[])?;
    let [input, output] = args.positional.as_slice() else {
        anyhow::bail!("usage: baf decode <in.baf> <out.npy>");
    };
    let bytes = std::fs::read(input)?;
    let frame = baf::codec::container::parse(&bytes)?;
    let q = baf::codec::container::unpack(&frame)?;
    let t = baf::quant::dequantize(&q);
    baf::tio::write_f32(std::path::Path::new(output), &t)?;
    println!(
        "{input} -> {output} (C={} {}x{}, n={}, codec {})",
        q.c,
        q.h,
        q.w,
        q.n,
        frame.codec.name()
    );
    Ok(())
}

fn cmd_report(args: &Args) -> Result<()> {
    args.expect_known(&[
        "artifacts", "c", "n", "codec", "qp", "policy", "no-consolidate", "images",
        "out", "stripes",
    ])?;
    let cfg = pipeline_cfg(args)?;
    let pipe = Pipeline::open(cfg)?;
    let samples = baf::data::eval_set(images(args)?);
    let mut evals = Vec::new();
    for s in &samples {
        let out = pipe.process(&s.image)?;
        evals.push(baf::eval::ImageEval {
            detections: out.boxes,
            ground_truth: s.boxes.iter().map(|&b| b.into()).collect(),
        });
    }
    let reps = baf::eval::per_class(&evals, baf::data::NUM_CLASSES, 0.5);
    print!("{}", baf::eval::report::table(&reps, &baf::data::CLASS_NAMES));
    if let Some(out) = args.opt("out") {
        baf::json::to_file(
            std::path::Path::new(out),
            &baf::eval::report::pr_json(&reps),
        )?;
        println!("PR curves written to {out}");
    }
    Ok(())
}

fn cmd_render(args: &Args) -> Result<()> {
    args.expect_known(&[
        "artifacts", "c", "n", "codec", "qp", "policy", "no-consolidate", "count",
        "out-dir", "stripes",
    ])?;
    let cfg = pipeline_cfg(args)?;
    let pipe = Pipeline::open(cfg)?;
    let count = args.opt_parse::<usize>("count")?.unwrap_or(8);
    let out_dir = std::path::PathBuf::from(args.opt("out-dir").unwrap_or("renders"));
    std::fs::create_dir_all(&out_dir)?;
    for (i, s) in baf::data::eval_set(count).iter().enumerate() {
        let out = pipe.process(&s.image)?;
        let dets: Vec<_> = out.boxes.into_iter().filter(|b| b.score > 0.3).collect();
        let gt: Vec<baf::eval::Box2D> = s.boxes.iter().map(|&b| b.into()).collect();
        let path = out_dir.join(format!("eval_{i:03}.ppm"));
        baf::data::render::write_ppm(&path, &s.image, &gt, &dets)?;
        println!("{} ({} GT, {} detections)", path.display(), gt.len(), dets.len());
    }
    Ok(())
}

fn cmd_inspect(args: &Args) -> Result<()> {
    args.expect_known(&["artifacts"])?;
    let dir = artifact_dir(args);
    let engine = Engine::new(&dir)?;
    let m = engine.manifest();
    println!("artifact dir : {}", dir.display());
    println!(
        "model        : {}x{} input, grid {}, {} anchors, {} classes",
        m.image_size,
        m.image_size,
        m.grid,
        m.anchors.len(),
        m.num_classes
    );
    println!(
        "split tensor : Z = {}x{}x{} (P={}), X has Q={} channels",
        m.z_shape.0, m.z_shape.1, m.z_shape.2, m.p_channels, m.q_channels
    );
    println!("artifacts    : {}", m.artifacts.len());
    for (name, spec) in &m.artifacts {
        println!(
            "  {name:<22} {:>9} KiB  in={:?} out={:?}",
            std::fs::metadata(&spec.file).map(|md| md.len() / 1024).unwrap_or(0),
            spec.inputs,
            spec.output
        );
    }
    let stats = baf::selection::ChannelStats::load(&dir)?;
    println!("\nchannel order (first 16): {:?}", &stats.order[..16.min(stats.order.len())]);
    println!("BaF variants: {:?}", m.baf_variants());
    Ok(())
}

fn cmd_golden(args: &Args) -> Result<()> {
    args.expect_known(&["artifacts"])?;
    let dir = artifact_dir(args);
    baf::golden::verify_all(&dir)?;
    println!("all goldens OK");
    Ok(())
}

fn main() {
    baf::util::logging::init();
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = match Args::parse(&argv) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e:#}");
            std::process::exit(2);
        }
    };
    let result = match args.command.as_str() {
        "run" => cmd_run(&args),
        "baseline" => cmd_baseline(&args),
        "channels" => cmd_channels(&args),
        "sweep" => cmd_sweep(&args),
        "codecs" => cmd_codecs(&args),
        "ablate" => cmd_ablate(&args),
        "serve" => cmd_serve(&args),
        "encode" => cmd_encode(&args),
        "decode" => cmd_decode(&args),
        "report" => cmd_report(&args),
        "render" => cmd_render(&args),
        "inspect" => cmd_inspect(&args),
        "golden" => cmd_golden(&args),
        "help" | "--help" | "-h" => {
            print!("{HELP}");
            Ok(())
        }
        other => {
            print!("{HELP}");
            Err(anyhow::anyhow!("unknown command '{other}'"))
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}
