//! A small dense f32 tensor with the two layouts the pipeline uses.
//!
//! The runtime moves feature maps around in NHWC (what the JAX artifacts
//! consume/produce) and the codec/quantizer work channel-major (CHW — one
//! quantizer and one tile per channel, paper §3.2). This module owns the
//! representation plus the handful of operations the hot path needs:
//! channel gather/scatter, layout conversion, and per-channel statistics.

mod ops;

pub use ops::*;

/// Dense row-major f32 tensor of arbitrary rank (rank <= 4 in practice).
#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    shape: Vec<usize>,
    data: Vec<f32>,
}

impl Default for Tensor {
    /// An empty rank-1 tensor (useful as a placeholder in parallel_map).
    fn default() -> Self {
        Tensor { shape: vec![0], data: Vec::new() }
    }
}

impl Tensor {
    pub fn zeros(shape: &[usize]) -> Self {
        let n: usize = shape.iter().product();
        Self { shape: shape.to_vec(), data: vec![0.0; n] }
    }

    pub fn from_vec(shape: &[usize], data: Vec<f32>) -> Self {
        let n: usize = shape.iter().product();
        assert_eq!(n, data.len(), "shape {:?} != data len {}", shape, data.len());
        Self { shape: shape.to_vec(), data }
    }

    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    pub fn data(&self) -> &[f32] {
        &self.data
    }

    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    pub fn into_vec(self) -> Vec<f32> {
        self.data
    }

    /// Reinterpret with a new shape of identical element count.
    pub fn reshape(mut self, shape: &[usize]) -> Self {
        let n: usize = shape.iter().product();
        assert_eq!(n, self.data.len(), "reshape {:?} -> {:?}", self.shape, shape);
        self.shape = shape.to_vec();
        self
    }

    /// Row-major linear index for a 3-D tensor.
    #[inline]
    pub fn idx3(&self, a: usize, b: usize, c: usize) -> usize {
        debug_assert_eq!(self.shape.len(), 3);
        (a * self.shape[1] + b) * self.shape[2] + c
    }

    #[inline]
    pub fn at3(&self, a: usize, b: usize, c: usize) -> f32 {
        self.data[self.idx3(a, b, c)]
    }

    /// Maximum absolute difference against another tensor of equal shape.
    pub fn max_abs_diff(&self, other: &Tensor) -> f32 {
        assert_eq!(self.shape, other.shape);
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max)
    }

    /// Mean squared error against another tensor of equal shape.
    pub fn mse(&self, other: &Tensor) -> f64 {
        assert_eq!(self.shape, other.shape);
        let s: f64 = self
            .data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| {
                let d = (*a - *b) as f64;
                d * d
            })
            .sum();
        s / self.data.len() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construction_and_indexing() {
        let t = Tensor::from_vec(&[2, 3, 4], (0..24).map(|i| i as f32).collect());
        assert_eq!(t.at3(1, 2, 3), 23.0);
        assert_eq!(t.at3(0, 0, 0), 0.0);
        assert_eq!(t.at3(1, 0, 0), 12.0);
    }

    #[test]
    #[should_panic]
    fn shape_mismatch_panics() {
        Tensor::from_vec(&[2, 2], vec![1.0; 5]);
    }

    #[test]
    fn diff_metrics() {
        let a = Tensor::from_vec(&[4], vec![0.0, 1.0, 2.0, 3.0]);
        let b = Tensor::from_vec(&[4], vec![0.0, 1.5, 2.0, 2.0]);
        assert_eq!(a.max_abs_diff(&b), 1.0);
        assert!((a.mse(&b) - (0.25 + 1.0) / 4.0).abs() < 1e-12);
    }

    #[test]
    fn reshape_preserves_data() {
        let t = Tensor::from_vec(&[6], (0..6).map(|i| i as f32).collect());
        let r = t.clone().reshape(&[2, 3]);
        assert_eq!(r.shape(), &[2, 3]);
        assert_eq!(r.data(), t.data());
    }
}
