//! Layout conversion, channel gather/scatter, per-channel statistics.

use super::Tensor;

/// HWC -> CHW (channel-major) conversion for one feature map.
pub fn hwc_to_chw(t: &Tensor) -> Tensor {
    let (h, w, c) = dims3(t);
    let src = t.data();
    let mut out = vec![0.0f32; src.len()];
    for ch in 0..c {
        for y in 0..h {
            for x in 0..w {
                out[(ch * h + y) * w + x] = src[(y * w + x) * c + ch];
            }
        }
    }
    Tensor::from_vec(&[c, h, w], out)
}

/// CHW -> HWC conversion.
pub fn chw_to_hwc(t: &Tensor) -> Tensor {
    let (c, h, w) = dims3(t);
    let src = t.data();
    let mut out = vec![0.0f32; src.len()];
    for ch in 0..c {
        for y in 0..h {
            for x in 0..w {
                out[(y * w + x) * c + ch] = src[(ch * h + y) * w + x];
            }
        }
    }
    Tensor::from_vec(&[h, w, c], out)
}

/// Gather a channel subset from an HWC map into CHW order.
///
/// This is the edge-side "select C of P channels" step (§3.1): output
/// channel k is input channel `sel[k]`, laid out channel-major, ready for
/// per-channel quantization and tiling.
pub fn gather_channels_hwc_to_chw(t: &Tensor, sel: &[usize]) -> Tensor {
    let (h, w, c) = dims3(t);
    let src = t.data();
    let mut out = vec![0.0f32; sel.len() * h * w];
    for (k, &ch) in sel.iter().enumerate() {
        assert!(ch < c, "channel {ch} out of range (C={c})");
        let plane = &mut out[k * h * w..(k + 1) * h * w];
        for y in 0..h {
            for x in 0..w {
                plane[y * w + x] = src[(y * w + x) * c + ch];
            }
        }
    }
    Tensor::from_vec(&[sel.len(), h, w], out)
}

/// Scatter CHW channel planes back into an HWC map at positions `sel`.
///
/// Cloud-side inverse of `gather_channels_hwc_to_chw`: used to overwrite
/// the BaF-predicted transmitted channels with their consolidated values
/// (Eq. 6) inside the full Z-tilde tensor.
pub fn scatter_channels_chw_into_hwc(planes: &Tensor, sel: &[usize], dst: &mut Tensor) {
    let (cs, h, w) = dims3(planes);
    assert_eq!(cs, sel.len());
    let (dh, dw, dc) = dims3(dst);
    assert_eq!((dh, dw), (h, w), "spatial dims must match");
    let src = planes.data();
    let out = dst.data_mut();
    for (k, &ch) in sel.iter().enumerate() {
        assert!(ch < dc);
        let plane = &src[k * h * w..(k + 1) * h * w];
        for y in 0..h {
            for x in 0..w {
                out[(y * w + x) * dc + ch] = plane[y * w + x];
            }
        }
    }
}

/// Per-channel (min, max) over a CHW tensor.
pub fn channel_min_max(t: &Tensor) -> Vec<(f32, f32)> {
    let (c, h, w) = dims3(t);
    let mut out = Vec::with_capacity(c);
    for ch in 0..c {
        let plane = &t.data()[ch * h * w..(ch + 1) * h * w];
        let mut mn = f32::INFINITY;
        let mut mx = f32::NEG_INFINITY;
        for &v in plane {
            mn = mn.min(v);
            mx = mx.max(v);
        }
        out.push((mn, mx));
    }
    out
}

/// Per-channel variance over a CHW tensor (selection ablation).
pub fn channel_variance(t: &Tensor) -> Vec<f64> {
    let (c, h, w) = dims3(t);
    let n = (h * w) as f64;
    (0..c)
        .map(|ch| {
            let plane = &t.data()[ch * h * w..(ch + 1) * h * w];
            let mean: f64 = plane.iter().map(|&v| v as f64).sum::<f64>() / n;
            plane.iter().map(|&v| (v as f64 - mean).powi(2)).sum::<f64>() / n
        })
        .collect()
}

/// LeakyReLU with the detector's slope (sigma(.) of the paper).
pub fn leaky_relu_inplace(t: &mut Tensor, slope: f32) {
    for v in t.data_mut() {
        if *v < 0.0 {
            *v *= slope;
        }
    }
}

fn dims3(t: &Tensor) -> (usize, usize, usize) {
    let s = t.shape();
    assert_eq!(s.len(), 3, "expected rank-3 tensor, got {:?}", s);
    (s[0], s[1], s[2])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::SplitMix64;

    fn random_hwc(h: usize, w: usize, c: usize, seed: u64) -> Tensor {
        let mut r = SplitMix64::new(seed);
        Tensor::from_vec(
            &[h, w, c],
            (0..h * w * c).map(|_| r.next_f32() * 4.0 - 2.0).collect(),
        )
    }

    #[test]
    fn layout_roundtrip() {
        let t = random_hwc(5, 7, 3, 1);
        let back = chw_to_hwc(&hwc_to_chw(&t));
        assert_eq!(t, back);
    }

    #[test]
    fn gather_scatter_roundtrip() {
        let t = random_hwc(4, 4, 8, 2);
        let sel = vec![6, 1, 3];
        let planes = gather_channels_hwc_to_chw(&t, &sel);
        assert_eq!(planes.shape(), &[3, 4, 4]);
        // gathered plane k equals channel sel[k]
        for (k, &ch) in sel.iter().enumerate() {
            for y in 0..4 {
                for x in 0..4 {
                    assert_eq!(planes.at3(k, y, x), t.at3(y, x, ch));
                }
            }
        }
        let mut dst = Tensor::zeros(&[4, 4, 8]);
        scatter_channels_chw_into_hwc(&planes, &sel, &mut dst);
        for &ch in &sel {
            for y in 0..4 {
                for x in 0..4 {
                    assert_eq!(dst.at3(y, x, ch), t.at3(y, x, ch));
                }
            }
        }
        // untouched channels remain zero
        assert_eq!(dst.at3(0, 0, 0), 0.0);
    }

    #[test]
    fn minmax_and_variance() {
        let t = Tensor::from_vec(&[2, 1, 3], vec![1.0, 2.0, 3.0, -4.0, 0.0, 4.0]);
        let mm = channel_min_max(&t);
        assert_eq!(mm[0], (1.0, 3.0));
        assert_eq!(mm[1], (-4.0, 4.0));
        let var = channel_variance(&t);
        assert!(var[1] > var[0]);
    }

    #[test]
    fn leaky_relu_matches_definition() {
        let mut t = Tensor::from_vec(&[1, 1, 4], vec![-2.0, -0.5, 0.0, 3.0]);
        leaky_relu_inplace(&mut t, 0.1);
        assert_eq!(t.data(), &[-0.2, -0.05, 0.0, 3.0]);
    }
}
