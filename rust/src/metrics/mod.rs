//! Serving metrics: counters + streaming histograms with percentile
//! queries, exported as JSON or a human table. Used by the coordinator's
//! server loop and the E5 bench.

use crate::json::Value;
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

/// A monotonically increasing counter (lock-free).
#[derive(Debug, Default)]
pub struct Counter(AtomicU64);

impl Counter {
    pub fn inc(&self) {
        self.0.fetch_add(1, Ordering::Relaxed);
    }

    pub fn add(&self, v: u64) {
        self.0.fetch_add(v, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Log-bucketed latency histogram (microseconds), p50/p95/p99 queries.
///
/// Buckets grow geometrically (~8% per bucket) covering 1us .. ~70s with
/// 256 buckets; recording is lock-free.
#[derive(Debug)]
pub struct Histogram {
    buckets: Vec<AtomicU64>,
    count: AtomicU64,
    sum_us: AtomicU64,
    max_us: AtomicU64,
}

const NUM_BUCKETS: usize = 256;
const GROWTH: f64 = 1.08;

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

impl Histogram {
    pub fn new() -> Self {
        Histogram {
            buckets: (0..NUM_BUCKETS).map(|_| AtomicU64::new(0)).collect(),
            count: AtomicU64::new(0),
            sum_us: AtomicU64::new(0),
            max_us: AtomicU64::new(0),
        }
    }

    fn bucket_of(us: f64) -> usize {
        if us <= 1.0 {
            return 0;
        }
        let b = us.ln() / GROWTH.ln();
        (b as usize).min(NUM_BUCKETS - 1)
    }

    fn bucket_upper(i: usize) -> f64 {
        GROWTH.powi(i as i32 + 1)
    }

    pub fn record_us(&self, us: f64) {
        self.buckets[Self::bucket_of(us)].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum_us.fetch_add(us as u64, Ordering::Relaxed);
        self.max_us.fetch_max(us as u64, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    pub fn mean_us(&self) -> f64 {
        let c = self.count();
        if c == 0 {
            0.0
        } else {
            self.sum_us.load(Ordering::Relaxed) as f64 / c as f64
        }
    }

    pub fn max_us(&self) -> f64 {
        self.max_us.load(Ordering::Relaxed) as f64
    }

    /// Approximate quantile (upper bound of the containing bucket).
    pub fn quantile_us(&self, q: f64) -> f64 {
        let total = self.count();
        if total == 0 {
            return 0.0;
        }
        let target = (q * total as f64).ceil() as u64;
        let mut acc = 0u64;
        for (i, b) in self.buckets.iter().enumerate() {
            acc += b.load(Ordering::Relaxed);
            if acc >= target {
                return Self::bucket_upper(i);
            }
        }
        Self::bucket_upper(NUM_BUCKETS - 1)
    }

    pub fn summary(&self) -> Value {
        let mut v = Value::obj();
        v.set("count", self.count())
            .set("mean_us", self.mean_us())
            .set("p50_us", self.quantile_us(0.50))
            .set("p95_us", self.quantile_us(0.95))
            .set("p99_us", self.quantile_us(0.99))
            .set("max_us", self.max_us());
        v
    }
}

/// A named registry of counters and histograms.
#[derive(Debug, Default)]
pub struct Registry {
    counters: Mutex<BTreeMap<String, std::sync::Arc<Counter>>>,
    histograms: Mutex<BTreeMap<String, std::sync::Arc<Histogram>>>,
}

/// Recover the map even if a recording thread panicked mid-insert: the
/// maps only grow, so the inner state is always usable.
fn lock_recover<T>(m: &Mutex<T>) -> std::sync::MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|poisoned| poisoned.into_inner())
}

impl Registry {
    pub fn counter(&self, name: &str) -> std::sync::Arc<Counter> {
        let mut m = lock_recover(&self.counters);
        m.entry(name.to_string()).or_default().clone()
    }

    pub fn histogram(&self, name: &str) -> std::sync::Arc<Histogram> {
        let mut m = lock_recover(&self.histograms);
        m.entry(name.to_string())
            .or_insert_with(|| std::sync::Arc::new(Histogram::new()))
            .clone()
    }

    /// Export everything as a JSON object.
    pub fn export(&self) -> Value {
        let mut v = Value::obj();
        let mut counters = Value::obj();
        for (k, c) in lock_recover(&self.counters).iter() {
            counters.set(k, c.get());
        }
        let mut hists = Value::obj();
        for (k, h) in lock_recover(&self.histograms).iter() {
            hists.set(k, h.summary());
        }
        v.set("counters", counters).set("latencies", hists);
        v
    }

    /// Human-readable metrics table (fixed-width markdown): latency
    /// histograms followed by the counters (drop/corruption accounting
    /// included).
    pub fn table(&self) -> String {
        let mut out = String::from(
            "| stage | count | mean(us) | p50(us) | p95(us) | p99(us) | max(us) |\n|---|---|---|---|---|---|---|\n",
        );
        for (k, h) in lock_recover(&self.histograms).iter() {
            out.push_str(&format!(
                "| {k} | {} | {:.0} | {:.0} | {:.0} | {:.0} | {:.0} |\n",
                h.count(),
                h.mean_us(),
                h.quantile_us(0.5),
                h.quantile_us(0.95),
                h.quantile_us(0.99),
                h.max_us()
            ));
        }
        let counters = lock_recover(&self.counters);
        if !counters.is_empty() {
            out.push_str("\n| counter | value |\n|---|---|\n");
            for (k, c) in counters.iter() {
                out.push_str(&format!("| {k} | {} |\n", c.get()));
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    #![allow(clippy::unwrap_used, clippy::expect_used)]

    use super::*;

    #[test]
    fn counter_concurrent_increments() {
        let c = std::sync::Arc::new(Counter::default());
        std::thread::scope(|s| {
            for _ in 0..8 {
                let c = c.clone();
                s.spawn(move || {
                    for _ in 0..1000 {
                        c.inc();
                    }
                });
            }
        });
        assert_eq!(c.get(), 8000);
    }

    #[test]
    fn histogram_quantiles_are_ordered_and_close() {
        let h = Histogram::new();
        for i in 1..=10_000u32 {
            h.record_us(i as f64);
        }
        let p50 = h.quantile_us(0.5);
        let p95 = h.quantile_us(0.95);
        let p99 = h.quantile_us(0.99);
        assert!(p50 <= p95 && p95 <= p99);
        // log buckets are ~8% wide; allow 10% slack
        assert!((p50 - 5000.0).abs() / 5000.0 < 0.10, "p50={p50}");
        assert!((p95 - 9500.0).abs() / 9500.0 < 0.10, "p95={p95}");
        assert!(h.max_us() >= 10_000.0);
    }

    #[test]
    fn registry_exports_json() {
        let r = Registry::default();
        r.counter("requests").add(3);
        r.histogram("e2e").record_us(1234.0);
        let v = r.export();
        assert_eq!(
            v.get("counters").unwrap().get("requests").unwrap().as_f64(),
            Some(3.0)
        );
        assert!(v.get("latencies").unwrap().get("e2e").is_some());
        let table = r.table();
        assert!(table.contains("e2e"));
        assert!(table.contains("requests"), "counters must appear in the table");
    }
}
