//! The bounded server ingress queue with an explicit overload policy.
//!
//! In TCP serving mode the receiver thread must never block on the
//! decode pipeline: while it blocks it is not reading the socket, the
//! kernel buffers fill, and overload turns into opaque sender timeouts
//! instead of a measured signal. The ingress queue sits between the
//! receiver thread and the decode dispatcher and makes the overload
//! policy explicit:
//!
//! * space available → the frame is enqueued;
//! * queue full and the *oldest* queued frame is past its arrival
//!   deadline → that frame is shed (drop-oldest: it has already missed
//!   its latency budget, finishing it helps nobody) and the new frame
//!   is enqueued — counted by the server as `frames_shed`;
//! * queue full and even the oldest frame is still within its deadline
//!   → the new frame is refused ([`PushOutcome::Rejected`]) and the
//!   receiver answers BUSY, shedding at the *edge* instead.
//!
//! Frames are pushed in arrival order, so the front entry always holds
//! the earliest deadline — deadline ordering is arrival ordering.

use std::collections::VecDeque;
use std::sync::{Condvar, Mutex, MutexGuard};
use std::time::{Duration, Instant};

/// Poison-safe lock: a panicked holder cannot leave the queue unusable
/// (mirrors `codec::scratch`).
fn lock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|poisoned| poisoned.into_inner())
}

#[derive(Debug)]
struct Entry<T> {
    item: T,
    deadline: Instant,
}

#[derive(Debug)]
struct Inner<T> {
    q: VecDeque<Entry<T>>,
    closed: bool,
}

/// What happened to a pushed frame (and to its victim, if any).
#[derive(Debug)]
pub enum PushOutcome<T> {
    /// Enqueued; `shed` carries the expired oldest entry this push
    /// evicted, if the queue was full.
    Accepted { shed: Option<T> },
    /// Queue full and nothing shed-eligible: the caller gets the item
    /// back and should answer BUSY.
    Rejected(T),
}

/// Result of a blocking pop.
#[derive(Debug)]
pub enum PopOutcome<T> {
    Item(T),
    /// Nothing arrived within the timeout; the queue is still open.
    TimedOut,
    /// Closed and drained: no more items will ever arrive.
    Closed,
}

/// Bounded MPMC queue with deadline-aware drop-oldest shedding. See the
/// module docs for the policy.
#[derive(Debug)]
pub struct IngressQueue<T> {
    inner: Mutex<Inner<T>>,
    cv: Condvar,
    capacity: usize,
}

impl<T> IngressQueue<T> {
    /// A queue holding at most `capacity` entries (clamped to >= 1).
    pub fn new(capacity: usize) -> Self {
        IngressQueue {
            inner: Mutex::new(Inner { q: VecDeque::new(), closed: false }),
            cv: Condvar::new(),
            capacity: capacity.max(1),
        }
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    pub fn len(&self) -> usize {
        lock(&self.inner).q.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Would a push right now be accepted (with or without shedding)?
    /// The admission answer can only improve between this call and the
    /// matching `push` as long as this thread is the only pusher:
    /// consumers shrink the queue and time only expires deadlines.
    pub fn can_accept(&self, now: Instant) -> bool {
        let inner = lock(&self.inner);
        if inner.closed {
            return false;
        }
        inner.q.len() < self.capacity
            || inner.q.front().is_some_and(|e| e.deadline <= now)
    }

    /// Push with the module-level overload policy. Never blocks.
    pub fn push(&self, item: T, deadline: Instant) -> PushOutcome<T> {
        let mut inner = lock(&self.inner);
        if inner.closed {
            return PushOutcome::Rejected(item);
        }
        let mut shed = None;
        if inner.q.len() >= self.capacity {
            let oldest_expired =
                inner.q.front().is_some_and(|e| e.deadline <= Instant::now());
            if !oldest_expired {
                return PushOutcome::Rejected(item);
            }
            shed = inner.q.pop_front().map(|e| e.item);
        }
        inner.q.push_back(Entry { item, deadline });
        drop(inner);
        self.cv.notify_one();
        PushOutcome::Accepted { shed }
    }

    /// Blocking pop with a timeout. Returns [`PopOutcome::Closed`] only
    /// once the queue is both closed and drained, so no accepted frame
    /// is ever lost at shutdown.
    pub fn pop(&self, timeout: Duration) -> PopOutcome<T> {
        let deadline = Instant::now() + timeout;
        let mut inner = lock(&self.inner);
        loop {
            if let Some(e) = inner.q.pop_front() {
                return PopOutcome::Item(e.item);
            }
            if inner.closed {
                return PopOutcome::Closed;
            }
            let now = Instant::now();
            if now >= deadline {
                return PopOutcome::TimedOut;
            }
            let (guard, _timed_out) = self
                .cv
                .wait_timeout(inner, deadline - now)
                .unwrap_or_else(|poisoned| poisoned.into_inner());
            inner = guard;
        }
    }

    /// Close the queue: pushes are rejected from now on and, once the
    /// backlog drains, pops return [`PopOutcome::Closed`].
    pub fn close(&self) {
        lock(&self.inner).closed = true;
        self.cv.notify_all();
    }
}

#[cfg(test)]
mod tests {
    #![allow(clippy::unwrap_used, clippy::expect_used)]

    use super::*;

    fn later(ms: u64) -> Instant {
        Instant::now() + Duration::from_millis(ms)
    }

    #[test]
    fn fifo_within_capacity() {
        let q = IngressQueue::new(4);
        for i in 0..4 {
            assert!(matches!(q.push(i, later(1000)), PushOutcome::Accepted { shed: None }));
        }
        for i in 0..4 {
            match q.pop(Duration::from_millis(50)) {
                PopOutcome::Item(v) => assert_eq!(v, i),
                other => panic!("{other:?}"),
            }
        }
        assert!(matches!(q.pop(Duration::from_millis(10)), PopOutcome::TimedOut));
    }

    #[test]
    fn full_queue_with_live_deadlines_rejects() {
        let q = IngressQueue::new(2);
        assert!(matches!(q.push(1, later(1000)), PushOutcome::Accepted { .. }));
        assert!(matches!(q.push(2, later(1000)), PushOutcome::Accepted { .. }));
        assert!(!q.can_accept(Instant::now()));
        match q.push(3, later(1000)) {
            PushOutcome::Rejected(v) => assert_eq!(v, 3),
            other => panic!("{other:?}"),
        }
        // nothing was lost
        assert_eq!(q.len(), 2);
    }

    #[test]
    fn full_queue_sheds_expired_oldest() {
        let q = IngressQueue::new(2);
        // already-expired deadline on the oldest entry
        assert!(matches!(
            q.push(1, Instant::now() - Duration::from_millis(1)),
            PushOutcome::Accepted { .. }
        ));
        assert!(matches!(q.push(2, later(1000)), PushOutcome::Accepted { .. }));
        assert!(q.can_accept(Instant::now()));
        match q.push(3, later(1000)) {
            PushOutcome::Accepted { shed: Some(v) } => assert_eq!(v, 1),
            other => panic!("{other:?}"),
        }
        match q.pop(Duration::from_millis(50)) {
            PopOutcome::Item(v) => assert_eq!(v, 2),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn close_drains_then_signals_closed() {
        let q = IngressQueue::new(4);
        assert!(matches!(q.push(7, later(1000)), PushOutcome::Accepted { .. }));
        q.close();
        assert!(matches!(q.push(8, later(1000)), PushOutcome::Rejected(8)));
        assert!(matches!(q.pop(Duration::from_millis(10)), PopOutcome::Item(7)));
        assert!(matches!(q.pop(Duration::from_millis(10)), PopOutcome::Closed));
    }

    #[test]
    fn pop_wakes_on_concurrent_push() {
        let q = std::sync::Arc::new(IngressQueue::new(2));
        let q2 = std::sync::Arc::clone(&q);
        let h = std::thread::spawn(move || q2.pop(Duration::from_secs(5)));
        std::thread::sleep(Duration::from_millis(30));
        assert!(matches!(q.push(42, later(1000)), PushOutcome::Accepted { .. }));
        match h.join().unwrap() {
            PopOutcome::Item(v) => assert_eq!(v, 42),
            other => panic!("{other:?}"),
        }
    }
}
