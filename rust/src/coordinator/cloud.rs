//! The cloud node: everything after the bitstream arrives (Fig. 1, right).
//!
//! frame -> entropy-decode + untile + inverse-quantize (Eq. 5)
//!       -> BaF prediction (artifact: inverse-BN -> deconv-net -> frozen
//!          layer-l conv+BN, producing all P channels of Z-tilde)
//!       -> Eq. 6 consolidation of the C transmitted channels
//!       -> activation + remaining layers (tail artifact) -> boxes

use crate::codec::container;
use crate::config::PipelineConfig;
use crate::eval::{postprocess, Box2D};
use crate::quant::{self, QuantizedTensor};
use crate::runtime::{Engine, Executable, Manifest};
use crate::tensor::{
    chw_to_hwc, gather_channels_hwc_to_chw, scatter_channels_chw_into_hwc, Tensor,
};
use crate::util::StageClock;
use anyhow::{Context, Result};
use std::rc::Rc;

/// Cloud-side stage outputs.
#[derive(Debug, Clone)]
pub struct CloudTrace {
    /// Reconstructed full tensor (H, W, P) after consolidation, pre-sigma.
    pub z_tilde: Tensor,
    /// Fraction of transmitted elements Eq. 6 had to clamp.
    pub consolidation_rate: f64,
    pub stages: Vec<(&'static str, f64)>,
}

/// The cloud node. Thread-confined (owns PJRT state via `Engine`).
pub struct CloudNode {
    engine: Rc<Engine>,
    baf: Rc<Executable>,
    tail: Rc<Executable>,
    pub sel: Vec<usize>,
    pub cfg: PipelineConfig,
}

impl CloudNode {
    pub fn new(engine: Rc<Engine>, sel: Vec<usize>, cfg: PipelineConfig) -> Result<Self> {
        let baf_name = Manifest::baf_name(cfg.c, cfg.n, 1);
        let baf = engine.load(&baf_name).with_context(|| {
            format!("no BaF model for C={}, n={} (artifact '{baf_name}')", cfg.c, cfg.n)
        })?;
        // Guard against stale artifact directories: the channel selection
        // baked into the BaF graph at export time must match the one the
        // edge will use, or reconstruction silently degrades.
        if let Some(baked) = &baf.spec.sel {
            anyhow::ensure!(
                *baked == sel,
                "artifact '{baf_name}' was exported with selection {:?} but \
                 channel_stats.json now yields {:?} — rebuild artifacts \
                 (`make artifacts`)",
                baked,
                sel
            );
        }
        let tail = engine.load("tail_b1")?;
        Ok(CloudNode { engine, baf, tail, sel, cfg })
    }

    pub fn engine(&self) -> &Rc<Engine> {
        &self.engine
    }

    /// Decode a frame into the dequantized subset tensor (1, H, W, C) and
    /// the quantized form (for consolidation).
    pub fn decode_frame(&self, frame: &[u8]) -> Result<(Tensor, QuantizedTensor)> {
        let parsed = container::parse(frame)?;
        anyhow::ensure!(
            parsed.channels == self.cfg.c,
            "frame C={} but pipeline C={}",
            parsed.channels,
            self.cfg.c
        );
        let q = container::unpack(&parsed).context("frame payload decode")?;
        let zhat_chw = quant::dequantize(&q);
        let zhat = chw_to_hwc(&zhat_chw);
        let (h, w, c) = (q.h, q.w, q.c);
        Ok((zhat.reshape(&[1, h, w, c]), q))
    }

    /// BaF-predict, consolidate, and run the tail for one decoded frame.
    pub fn infer(&self, zhat_b1: &Tensor, q: &QuantizedTensor) -> Result<(Vec<Box2D>, CloudTrace)> {
        let mut clock = StageClock::new();
        let m = self.engine.manifest();
        let z_tilde = self
            .baf
            .run(&[zhat_b1])?
            .reshape(&[m.z_shape.0, m.z_shape.1, m.z_shape.2]);
        clock.lap("cloud_baf");

        let (z_final, cons_rate) = self.consolidate(z_tilde, q);
        clock.lap("cloud_consolidate");

        let head = self
            .tail
            .run(&[&z_final.clone().reshape(&[1, m.z_shape.0, m.z_shape.1, m.z_shape.2])])?
            .reshape(&[m.grid, m.grid, m.head_channels]);
        clock.lap("cloud_tail");

        let boxes = postprocess(&head, m);
        clock.lap("cloud_post");

        Ok((
            boxes,
            CloudTrace {
                z_tilde: z_final,
                consolidation_rate: cons_rate,
                stages: clock.stages().to_vec(),
            },
        ))
    }

    /// Full cloud pipeline: frame bytes -> detections.
    pub fn process(&self, frame: &[u8]) -> Result<(Vec<Box2D>, CloudTrace)> {
        let (zhat, q) = self.decode_frame(frame)?;
        self.infer(&zhat, &q)
    }

    /// Eq. 6 on the transmitted channels; returns (tensor, changed rate).
    fn consolidate(&self, mut z_tilde: Tensor, q: &QuantizedTensor) -> (Tensor, f64) {
        if !self.cfg.consolidate {
            return (z_tilde, 0.0);
        }
        let predicted = gather_channels_hwc_to_chw(&z_tilde, &self.sel);
        let cons = quant::consolidate(&predicted, q);
        let changed = cons
            .data()
            .iter()
            .zip(predicted.data())
            .filter(|(a, b)| a != b)
            .count() as f64
            / cons.len() as f64;
        scatter_channels_chw_into_hwc(&cons, &self.sel, &mut z_tilde);
        (z_tilde, changed)
    }
}
