//! Single-threaded composition of edge + cloud for the accuracy/rate
//! experiments (E1/E2/E6), plus the cloud-only baseline the paper
//! compares against.
//!
//! This in-process composition is the accuracy ground truth; the same
//! edge and cloud nodes also run split across two processes with the
//! `crate::net` TCP transport between them (see
//! [`super::server::run_server`] with `ServerConfig::listen` and
//! [`super::edge::run_edge_client`]) — the frames on the wire are
//! byte-identical to the ones handed over in memory here.

use super::cloud::CloudNode;
use super::edge::EdgeNode;
use crate::config::PipelineConfig;
use crate::data::Sample;
use crate::eval::{evaluate, postprocess, Box2D, ImageEval, MapResult};
use crate::runtime::Engine;
use crate::selection::ChannelStats;
use crate::tensor::Tensor;
use anyhow::Result;
use std::rc::Rc;

/// Result of one image through the full system.
#[derive(Debug, Clone)]
pub struct PipelineOutput {
    pub boxes: Vec<Box2D>,
    pub frame_bytes: usize,
    pub consolidation_rate: f64,
    /// (stage, microseconds) across both nodes in order.
    pub stages: Vec<(&'static str, f64)>,
}

/// Edge + cloud sharing one engine (single accelerator context) —
/// the configuration every accuracy experiment uses.
pub struct Pipeline {
    pub edge: EdgeNode,
    pub cloud: CloudNode,
}

impl Pipeline {
    pub fn new(engine: Rc<Engine>, cfg: PipelineConfig) -> Result<Self> {
        let stats = ChannelStats::load(&cfg.artifact_dir)?;
        let m = engine.manifest();
        stats.validate(m.p_channels, m.q_channels)?;
        let edge = EdgeNode::new(Rc::clone(&engine), &stats, cfg.clone())?;
        let sel = edge.sel.clone();
        let cloud = CloudNode::new(engine, sel, cfg)?;
        Ok(Pipeline { edge, cloud })
    }

    /// Convenience constructor that builds the engine too.
    pub fn open(cfg: PipelineConfig) -> Result<Self> {
        let engine = Rc::new(Engine::new(&cfg.artifact_dir)?);
        Self::new(engine, cfg)
    }

    pub fn process(&self, image: &Tensor) -> Result<PipelineOutput> {
        let (frame, et) = self.edge.process(image)?;
        let (boxes, ct) = self.cloud.process(&frame)?;
        let mut stages = et.stages;
        stages.extend(ct.stages);
        Ok(PipelineOutput {
            boxes,
            frame_bytes: frame.len(),
            consolidation_rate: ct.consolidation_rate,
            stages,
        })
    }

    /// Evaluate mAP + mean rate over a set of samples.
    pub fn evaluate_set(&self, samples: &[Sample]) -> Result<(MapResult, f64)> {
        anyhow::ensure!(
            !samples.is_empty(),
            "evaluate_set called with an empty sample slice — the mean \
             rate would be 0/0"
        );
        let mut evals = Vec::with_capacity(samples.len());
        let mut total_bytes = 0usize;
        for s in samples {
            let out = self.process(&s.image)?;
            total_bytes += out.frame_bytes;
            evals.push(ImageEval {
                detections: out.boxes,
                ground_truth: s.boxes.iter().map(|&b| b.into()).collect(),
            });
        }
        let m = self.cloud.engine().manifest();
        Ok((
            evaluate(&evals, m.num_classes),
            total_bytes as f64 / samples.len() as f64,
        ))
    }
}

/// The cloud-only baseline: the unmodified detector run end to end
/// (monolith artifact). Its mAP is the paper's benchmark line in Fig. 3,
/// and its *input image* compressed size is the rate reference in Fig. 4.
pub struct CloudOnly {
    engine: Rc<Engine>,
}

impl CloudOnly {
    pub fn new(engine: Rc<Engine>) -> Self {
        CloudOnly { engine }
    }

    pub fn process(&self, image: &Tensor) -> Result<Vec<Box2D>> {
        let m = self.engine.manifest();
        let img = image.clone().reshape(&[1, m.image_size, m.image_size, 3]);
        let head = self
            .engine
            .run("monolith_b1", &[&img])?
            .reshape(&[m.grid, m.grid, m.head_channels]);
        Ok(postprocess(&head, m))
    }

    pub fn evaluate_set(&self, samples: &[Sample]) -> Result<MapResult> {
        anyhow::ensure!(
            !samples.is_empty(),
            "evaluate_set called with an empty sample slice"
        );
        let mut evals = Vec::with_capacity(samples.len());
        for s in samples {
            evals.push(ImageEval {
                detections: self.process(&s.image)?,
                ground_truth: s.boxes.iter().map(|&b| b.into()).collect(),
            });
        }
        Ok(evaluate(&evals, self.engine.manifest().num_classes))
    }

    /// Rate reference for Fig. 4: the input image itself, 8-bit
    /// quantized per channel and losslessly coded with the same codec
    /// machinery (the "compressed image input to an unmodified network").
    pub fn image_bytes(&self, image: &Tensor) -> usize {
        use crate::codec::container;
        use crate::quant::quantize;
        use crate::tensor::hwc_to_chw;
        let chw = hwc_to_chw(image);
        let q = quantize(&chw, 8);
        container::pack(&q, crate::codec::CodecKind::Tlc, 0).len()
    }
}
