//! L3 coordinator: the collaborative-intelligence runtime.
//!
//! * `edge` / `cloud` — the two halves of the split pipeline (Fig. 1).
//! * `pipeline` — single-threaded composition for accuracy experiments,
//!   plus the cloud-only baseline.
//! * `batcher` — deadline+capacity dynamic batching.
//! * `server` — the pipelined multi-threaded serving demo with Poisson
//!   arrivals, decode workers, batched cloud inference and backpressure.
//!
//! The edge→cloud hop runs in-process (mpsc) by default; with
//! `ServerConfig::listen` / `::connect` set, the same stages talk over
//! the `crate::net` TCP transport instead (`run_server` accepts frames,
//! `run_edge_client` produces and ships them).

pub mod batcher;
pub mod cloud;
pub mod edge;
pub mod ingress;
pub mod pipeline;
pub mod server;

pub use cloud::{CloudNode, CloudTrace};
pub use ingress::{IngressQueue, PopOutcome, PushOutcome};
pub use edge::{run_edge_client, EdgeClientReport, EdgeNode, EdgeTrace};
pub use pipeline::{CloudOnly, Pipeline, PipelineOutput};
pub use server::{run_server, ServerReport};
