//! The pipelined serving demo (E5): Poisson arrivals -> edge thread ->
//! decode workers -> dynamic batcher -> cloud inference -> metrics.
//!
//! Thread topology (PJRT engines are thread-confined, so each inference
//! stage owns its own `Engine`, mirroring one accelerator context per
//! process):
//!
//! ```text
//!  [arrival gen + edge node]            (1 thread, Engine #1)
//!        | bounded channel (backpressure)
//!  [decode dispatcher: parse/entropy/dequant]  (1 thread + stripe pool)
//!        | bounded channel
//!  [dynamic batcher + cloud infer + post]   (1 thread, Engine #2)
//!        | channel
//!  [collector: latency accounting]          (main thread)
//! ```
//!
//! The decode stage is a single dispatcher that fans the *stripes* of
//! each v2 frame across a `decode_workers`-wide [`WorkerPool`] — one
//! frame's entropy decode is split across cores, cutting per-frame
//! latency (p95) instead of only aggregate throughput. v1 frames are a
//! single stripe and decode inline on the dispatcher. A shared
//! [`ScratchPool`] recycles frame byte-buffers and bin planes between
//! the edge, decode, and cloud stages, so steady-state serving does not
//! allocate per frame in the codec layer (`scratch_hits` /
//! `scratch_misses` in the exported metrics show the reuse rate).
//!
//! With `ServerConfig::listen` set, the first stage is replaced by a
//! [`crate::net::FrameReceiver`] thread: frames arrive over TCP from a
//! remote edge ([`super::edge::run_edge_client`]) instead of being
//! produced in-process, `t_arrival` becomes the first wire byte of each
//! message (so the reported p50/p95 *include* transport time), and
//! wire-rejected messages are accounted as `frames_dropped`. The decode
//! dispatcher, batcher, and collector are identical in both modes.
//!
//! In TCP mode a bounded [`super::ingress::IngressQueue`] sits between
//! the receiver thread and the decode dispatcher so the receiver never
//! blocks on a slow pipeline. Overload becomes a measured signal
//! instead of opaque sender timeouts: a full queue sheds the oldest
//! frame past its `shed_deadline_ms` budget (`frames_shed`) or, when
//! even the oldest frame is still live, answers BUSY on the wire so
//! the edge sheds instead (`frames_busy`). The collector's
//! conservation law is `completed + dropped + shed + busy ==
//! num_requests` — every request id ends in exactly one bucket.

use super::batcher::{next_batch, BatchOutcome};
use super::ingress::{IngressQueue, PopOutcome, PushOutcome};
use crate::codec::scratch::ScratchPool;
use crate::config::{PipelineConfig, ServerConfig};
use crate::runtime::pool::WorkerPool;
use crate::coordinator::cloud::CloudNode;
use crate::coordinator::edge::EdgeNode;
use crate::data;
use crate::json::Value;
use crate::metrics::Registry;
use crate::quant::QuantizedTensor;
use crate::runtime::{Engine, Manifest};
use crate::selection::ChannelStats;
use crate::tensor::Tensor;
use anyhow::{Context, Result};
use std::sync::mpsc;
use std::sync::Arc;
use std::time::{Duration, Instant};

/// A request travelling through the pipeline.
struct FrameMsg {
    id: usize,
    frame: Vec<u8>,
    t_arrival: Instant,
    /// When the frame finished the edge stage (in-process mode) or was
    /// fully received off the wire (TCP mode). The decode dispatcher
    /// charges `t0 - t_edge_done` to the `2_decode_wait` histogram —
    /// the time a frame sat in the bounded queue before decoding.
    t_edge_done: Instant,
}

struct DecodedMsg {
    id: usize,
    /// (1, H, W, C) dequantized subset.
    zhat: Tensor,
    q: QuantizedTensor,
    t_arrival: Instant,
    t_decoded: Instant,
}

/// Summary of one serving run.
#[derive(Debug)]
pub struct ServerReport {
    pub requests: usize,
    /// Frames dropped by the decode stage (corrupt/truncated); the run
    /// still completes — `requests` counts completions + drops + sheds
    /// + BUSY refusals.
    pub dropped: usize,
    /// Frames shed from the ingress queue under overload (accepted off
    /// the wire, then evicted past their deadline). TCP mode only.
    pub shed: usize,
    /// Frames refused with a BUSY verdict (shed at the edge before
    /// entering the pipeline). TCP mode only.
    pub busy: usize,
    pub wall_seconds: f64,
    pub throughput_rps: f64,
    pub mean_batch_size: f64,
    pub metrics: Value,
    pub table: String,
}

/// Run the serving pipeline to completion.
pub fn run_server(pcfg: &PipelineConfig, scfg: &ServerConfig) -> Result<ServerReport> {
    let stats = ChannelStats::load(&pcfg.artifact_dir)?;
    let sel = stats.select(pcfg.policy, pcfg.c);
    let registry = Arc::new(Registry::default());

    // Pre-generate the request images (cycled from the eval split).
    let pool = data::eval_set(64.min(scfg.num_requests.max(1)));
    let images: Vec<Tensor> = pool.iter().map(|s| s.image.clone()).collect();

    let (frame_tx, frame_rx) = mpsc::sync_channel::<FrameMsg>(scfg.queue_depth);
    let (dec_tx, dec_rx) = mpsc::sync_channel::<DecodedMsg>(scfg.queue_depth);
    let (done_tx, done_rx) = mpsc::channel::<(usize, Instant, Instant, usize)>();

    // one scratch pool shared by edge encode, stripe decode, and the
    // cloud stage's bin recycling — the frame/bin buffers circulate
    let scratch = Arc::new(ScratchPool::new());

    let t_start = Instant::now();
    std::thread::scope(|scope| -> Result<()> {
        if let Some(listen) = scfg.listen.clone() {
            // ---- net receiver thread: frames arrive over TCP ----
            // Replaces the in-process edge stage: a remote edge client
            // (`run_edge_client`, `baf serve --connect`) does frontend
            // inference + encode on its side of the wire. t_arrival is
            // the first wire byte, so the collector's p50/p95 include
            // transport time.
            //
            // The receiver never blocks on the decode pipeline: admitted
            // frames land in the bounded ingress queue (overload policy
            // in `super::ingress`) and a drain thread forwards them to
            // the decode dispatcher's channel.
            let ingress =
                Arc::new(IngressQueue::<FrameMsg>::new(scfg.ingress_depth));
            {
                let ingress = Arc::clone(&ingress);
                let scfg = scfg.clone();
                let registry = Arc::clone(&registry);
                let scratch = Arc::clone(&scratch);
                scope.spawn(move || {
                    let cfg = crate::net::NetConfig::default();
                    let dropped_c = registry.counter("frames_dropped");
                    let mut rx = match crate::net::FrameReceiver::bind(&listen, cfg) {
                        Ok(rx) => rx,
                        Err(e) => {
                            log::error!("net: bind {listen} failed: {e}");
                            // nothing can arrive: account every request as
                            // dropped so the collector terminates
                            dropped_c.add(scfg.num_requests as u64);
                            ingress.close();
                            return;
                        }
                    };
                    let recv_h = registry.histogram("0_net_recv");
                    let shed_c = registry.counter("frames_shed");
                    let busy_c = registry.counter("frames_busy");
                    let budget = Duration::from_millis(scfg.shed_deadline_ms);
                    let mut accounted = 0usize;
                    let mut strikes = 0u32;
                    while accounted < scfg.num_requests {
                        let outcome = rx.recv_admit(&mut |_received| {
                            ingress.can_accept(Instant::now())
                        });
                        match outcome {
                            Ok(r) => {
                                strikes = 0;
                                recv_h.record_us(
                                    r.t_done
                                        .saturating_duration_since(r.t_first_byte)
                                        .as_secs_f64()
                                        * 1e6,
                                );
                                let msg = FrameMsg {
                                    id: accounted,
                                    frame: r.frame,
                                    t_arrival: r.t_first_byte,
                                    t_edge_done: r.t_done,
                                };
                                match ingress.push(msg, r.t_first_byte + budget) {
                                    PushOutcome::Accepted { shed: Some(old) } => {
                                        // the victim's request id is spent;
                                        // the collector counts it via
                                        // `frames_shed`
                                        log::warn!(
                                            "ingress: shedding frame {} (past \
                                             its {budget:?} budget)",
                                            old.id,
                                        );
                                        shed_c.inc();
                                        scratch.put_u8(old.frame);
                                    }
                                    PushOutcome::Accepted { shed: None } => {}
                                    PushOutcome::Rejected(msg) => {
                                        // only reachable if the queue was
                                        // closed under us; shed rather than
                                        // lose the id
                                        shed_c.inc();
                                        scratch.put_u8(msg.frame);
                                    }
                                }
                                accounted += 1;
                            }
                            // admission refused: the sender got BUSY and
                            // sheds at the edge; the request id is spent
                            // on both ends
                            Err(crate::net::Error::Busy) => {
                                busy_c.inc();
                                accounted += 1;
                            }
                            // a wire-rejected message consumed a request slot
                            // on the edge (the sender sees the NACK): count
                            // it as a drop so the run stays fully accounted
                            Err(e @ crate::net::Error::Protocol(_))
                            | Err(e @ crate::net::Error::TooLarge { .. }) => {
                                log::warn!("net: rejecting frame: {e}");
                                dropped_c.inc();
                                accounted += 1;
                            }
                            // the edge disconnected (done, or reconnecting
                            // after a fault): the next recv re-accepts
                            Err(crate::net::Error::ConnClosed { .. }) => {}
                            Err(e) => {
                                // accept/read timeouts and socket errors: a
                                // few in a row mean the edge is gone for good
                                strikes += 1;
                                if strikes >= 3 {
                                    log::warn!(
                                        "net: idle after {e}; abandoning {} request(s)",
                                        scfg.num_requests - accounted
                                    );
                                    break;
                                }
                            }
                        }
                    }
                    if accounted < scfg.num_requests {
                        dropped_c.add((scfg.num_requests - accounted) as u64);
                    }
                    rx.stats().export_receiver_into(&registry);
                    // no more pushes: once the backlog drains the drain
                    // thread sees Closed and drops frame_tx
                    ingress.close();
                });
            }
            // ---- ingress drain thread: queue -> decode dispatcher ----
            scope.spawn(move || {
                loop {
                    match ingress.pop(Duration::from_millis(100)) {
                        PopOutcome::Item(msg) => {
                            // blocking here is fine: backpressure lands on
                            // the queue, whose shed policy keeps the
                            // receiver responsive
                            if frame_tx.send(msg).is_err() {
                                break;
                            }
                        }
                        PopOutcome::TimedOut => continue,
                        PopOutcome::Closed => break,
                    }
                }
                // frame_tx dropped here -> decode workers drain and stop
            });
        } else {
            // ---- edge thread: arrivals + frontend + encode ----
            let pcfg = pcfg.clone();
            let scfg = scfg.clone();
            let stats = &stats;
            let registry = Arc::clone(&registry);
            let scratch = Arc::clone(&scratch);
            scope.spawn(move || {
                let run = || -> Result<()> {
                    let engine =
                        std::rc::Rc::new(Engine::new(&pcfg.artifact_dir)?);
                    let mut edge = EdgeNode::new(engine, stats, pcfg.clone())?;
                    edge.use_scratch(Arc::clone(&scratch));
                    let mut rng = crate::util::SplitMix64::new(0xA221);
                    // deterministic fault injection (scfg.corrupt_rate of
                    // frames are mangled in "transit") to exercise the
                    // decode stage's drop path end to end
                    let mut fault_rng = crate::util::SplitMix64::new(0xFA11);
                    let mut corruptor =
                        crate::codec::faultgen::Corruptor::new(0xC011A95E);
                    let injected_c = registry.counter("frames_corrupted_injected");
                    let edge_h = registry.histogram("1_edge_total");
                    let mut next_arrival = Instant::now();
                    for id in 0..scfg.num_requests {
                        // MMPP-2 (or Poisson) arrivals; the rate schedule
                        // lives in ServerConfig so the TCP edge client
                        // presents identical load
                        let rate = scfg.arrival_rate_for(id);
                        next_arrival += Duration::from_secs_f64(rng.next_exp(rate));
                        let now = Instant::now();
                        if next_arrival > now {
                            std::thread::sleep(next_arrival - now);
                        }
                        let t_arrival = Instant::now();
                        let img = &images[id % images.len()];
                        let (mut frame, _trace) = edge.process(img)?;
                        if scfg.corrupt_rate > 0.0
                            && fault_rng.next_f64() < scfg.corrupt_rate
                        {
                            frame = corruptor.corrupt(&frame);
                            injected_c.inc();
                        }
                        let t_edge_done = Instant::now();
                        edge_h.record_us(
                            (t_edge_done - t_arrival).as_secs_f64() * 1e6,
                        );
                        // sync_channel send == backpressure on the edge
                        frame_tx
                            .send(FrameMsg { id, frame, t_arrival, t_edge_done })
                            .ok();
                    }
                    Ok(())
                };
                if let Err(e) = run() {
                    log::error!("edge thread failed: {e:#}");
                }
                // frame_tx dropped here -> decode workers drain and stop
            });
        }

        // ---- decode dispatcher: one thread, stripes fanned over a pool ----
        // Intra-frame parallelism: a v2 frame's K stripes decode
        // concurrently across `decode_workers` threads, so a single
        // frame's latency shrinks (the p95 lever) rather than only the
        // stage's aggregate throughput. v1 frames (one stripe) decode
        // inline with no pool overhead.
        {
            let dec_tx = dec_tx.clone();
            let registry = Arc::clone(&registry);
            let scratch = Arc::clone(&scratch);
            let expect_c = pcfg.c;
            let workers = WorkerPool::new(scfg.decode_workers.max(1));
            scope.spawn(move || {
                let h = registry.histogram("2_decode");
                let wait_h = registry.histogram("2_decode_wait");
                let dropped_c = registry.counter("frames_dropped");
                let frames_c = registry.counter("frames_decoded");
                let stripes_c = registry.counter("stripes_decoded");
                while let Ok(msg) = frame_rx.recv() {
                    let t0 = Instant::now();
                    // time spent queued between edge/receive and decode
                    wait_h.record_us(
                        t0.saturating_duration_since(msg.t_edge_done)
                            .as_secs_f64()
                            * 1e6,
                    );
                    // a corrupt or truncated frame is dropped and counted
                    // — the server keeps serving
                    let q = match crate::codec::container::parse(&msg.frame)
                        .and_then(|parsed| {
                            stripes_c.add(parsed.stripes.len() as u64);
                            crate::codec::container::unpack_with(
                                &parsed, &workers, &scratch,
                            )
                        }) {
                        Ok(q) => q,
                        Err(e) => {
                            log::warn!("decode: dropping frame {}: {e}", msg.id);
                            dropped_c.inc();
                            scratch.put_u8(msg.frame);
                            continue;
                        }
                    };
                    // frame bytes are spent; recycle the buffer for encode
                    scratch.put_u8(msg.frame);
                    if q.c != expect_c {
                        log::warn!(
                            "decode: dropping frame {}: C={} but pipeline \
                             expects C={expect_c}",
                            msg.id,
                            q.c,
                        );
                        dropped_c.inc();
                        scratch.put_u16(q.bins);
                        continue;
                    }
                    frames_c.inc();
                    let zhat_chw = crate::quant::dequantize(&q);
                    let zhat = crate::tensor::chw_to_hwc(&zhat_chw)
                        .reshape(&[1, q.h, q.w, expect_c]);
                    h.record_us(t0.elapsed().as_secs_f64() * 1e6);
                    dec_tx
                        .send(DecodedMsg {
                            id: msg.id,
                            zhat,
                            q,
                            t_arrival: msg.t_arrival,
                            t_decoded: Instant::now(),
                        })
                        .ok();
                }
            });
        }
        drop(dec_tx);

        // ---- cloud inference thread: batcher + BaF + tail ----
        {
            let pcfg = pcfg.clone();
            let scfg = scfg.clone();
            let sel = sel.clone();
            let registry = Arc::clone(&registry);
            let scratch = Arc::clone(&scratch);
            let done_tx = done_tx.clone();
            scope.spawn(move || {
                let run = || -> Result<()> {
                    let engine = std::rc::Rc::new(Engine::new(&pcfg.artifact_dir)?);
                    let cloud =
                        CloudNode::new(std::rc::Rc::clone(&engine), sel.clone(), pcfg.clone())?;
                    // batch executables when available for this (C, n)
                    let baf8 = engine
                        .load(&Manifest::baf_name(pcfg.c, pcfg.n, 8))
                        .ok();
                    let tail8 = engine.load("tail_b8").ok();
                    let infer_h = registry.histogram("4_cloud_infer");
                    let queue_h = registry.histogram("3_batch_wait");
                    let batch_c = registry.counter("batches");
                    let item_c = registry.counter("batched_items");
                    let m = engine.manifest().clone();
                    let (zh, zw, zc) = m.z_shape;
                    loop {
                        let outcome = next_batch(
                            &dec_rx,
                            scfg.batch_cap.max(1),
                            Duration::from_micros(scfg.batch_deadline_us),
                            Duration::from_millis(200),
                        );
                        let batch = match outcome {
                            BatchOutcome::Batch(b) => b,
                            BatchOutcome::Idle => continue,
                            BatchOutcome::Closed => break,
                        };
                        batch_c.inc();
                        item_c.add(batch.len() as u64);
                        let t0 = Instant::now();
                        for msg in &batch {
                            queue_h.record_us(
                                (t0 - msg.t_decoded).as_secs_f64() * 1e6,
                            );
                        }
                        if let (Some(baf8), Some(tail8), true) =
                            (baf8.as_ref(), tail8.as_ref(), batch.len() > 1)
                        {
                            // pad to batch 8, one PJRT call for BaF, one
                            // for the tail; consolidation per item.
                            let cin = pcfg.c;
                            let mut zin = Tensor::zeros(&[8, zh, zw, cin]);
                            for (k, msg) in batch.iter().enumerate() {
                                let src = msg.zhat.data();
                                let stride = zh * zw * cin;
                                zin.data_mut()[k * stride..(k + 1) * stride]
                                    .copy_from_slice(src);
                            }
                            let zt8 = baf8.run(&[&zin])?;
                            let stride = zh * zw * zc;
                            let mut zt_final = Tensor::zeros(&[8, zh, zw, zc]);
                            let mut cons_planes = Vec::with_capacity(batch.len());
                            for (k, msg) in batch.iter().enumerate() {
                                let mut zt = Tensor::from_vec(
                                    &[zh, zw, zc],
                                    zt8.data()[k * stride..(k + 1) * stride].to_vec(),
                                );
                                if pcfg.consolidate {
                                    let pred = crate::tensor::gather_channels_hwc_to_chw(
                                        &zt, &sel,
                                    );
                                    let cons = crate::quant::consolidate(&pred, &msg.q);
                                    crate::tensor::scatter_channels_chw_into_hwc(
                                        &cons, &sel, &mut zt,
                                    );
                                }
                                cons_planes.push(zt.data().to_vec());
                                zt_final.data_mut()[k * stride..(k + 1) * stride]
                                    .copy_from_slice(cons_planes[k].as_slice());
                            }
                            let heads = tail8.run(&[&zt_final])?;
                            let hstride = m.grid * m.grid * m.head_channels;
                            for (k, msg) in batch.iter().enumerate() {
                                let head = Tensor::from_vec(
                                    &[m.grid, m.grid, m.head_channels],
                                    heads.data()[k * hstride..(k + 1) * hstride].to_vec(),
                                );
                                let boxes = crate::eval::postprocess(&head, &m);
                                done_tx
                                    .send((msg.id, msg.t_arrival, Instant::now(), boxes.len()))
                                    .ok();
                            }
                        } else {
                            for msg in &batch {
                                let (boxes, _trace) = cloud.infer(&msg.zhat, &msg.q)?;
                                done_tx
                                    .send((msg.id, msg.t_arrival, Instant::now(), boxes.len()))
                                    .ok();
                            }
                        }
                        // bins consumed (consolidation done): recycle them
                        // so the decode stage's next unpack is allocation-free
                        for msg in batch {
                            scratch.put_u16(msg.q.bins);
                        }
                        infer_h.record_us(t0.elapsed().as_secs_f64() * 1e6);
                    }
                    Ok(())
                };
                if let Err(e) = run() {
                    log::error!("cloud thread failed: {e:#}");
                }
            });
        }
        drop(done_tx);

        // ---- collector (this thread) ----
        // Completions arrive on done_rx; drops, sheds, and BUSY refusals
        // are only visible through counters, so run until every request
        // is accounted for or the pipeline shuts down (channel closes
        // when edge -> decode -> cloud have all drained). Conservation:
        // every request id ends in exactly one bucket.
        let e2e = registry.histogram("5_e2e");
        let dropped_c = registry.counter("frames_dropped");
        let shed_c = registry.counter("frames_shed");
        let busy_c = registry.counter("frames_busy");
        let mut completed = 0usize;
        while let Ok((_id, t_arrival, t_done, _nboxes)) = done_rx.recv() {
            e2e.record_us((t_done - t_arrival).as_secs_f64() * 1e6);
            completed += 1;
            let accounted = completed
                + dropped_c.get() as usize
                + shed_c.get() as usize
                + busy_c.get() as usize;
            if accounted >= scfg.num_requests {
                break;
            }
        }
        let dropped = dropped_c.get() as usize;
        let shed = shed_c.get() as usize;
        let busy = busy_c.get() as usize;
        anyhow::ensure!(
            completed + dropped + shed + busy == scfg.num_requests,
            "served {completed} + dropped {dropped} + shed {shed} + busy \
             {busy} of {} requests",
            scfg.num_requests
        );
        Ok(())
    })
    .context("server run")?;

    // surface buffer-reuse effectiveness in the exported metrics: at
    // steady state hits dominate and misses stay flat (each miss is one
    // real allocation somewhere in the codec layer)
    let sstats = scratch.stats();
    registry.counter("scratch_hits").add(sstats.hits);
    registry.counter("scratch_misses").add(sstats.misses);
    registry.counter("scratch_returned").add(sstats.returned);

    let wall = t_start.elapsed().as_secs_f64();
    let batches = registry.counter("batches").get().max(1);
    let items = registry.counter("batched_items").get();
    let dropped = registry.counter("frames_dropped").get() as usize;
    let shed = registry.counter("frames_shed").get() as usize;
    let busy = registry.counter("frames_busy").get() as usize;
    Ok(ServerReport {
        requests: scfg.num_requests,
        dropped,
        shed,
        busy,
        wall_seconds: wall,
        throughput_rps: scfg.num_requests as f64 / wall,
        mean_batch_size: items as f64 / batches as f64,
        metrics: registry.export(),
        table: registry.table(),
    })
}
