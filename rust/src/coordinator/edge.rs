//! The edge (mobile) node: everything that runs on-device (Fig. 1, left).
//!
//! image -> layers 1..l (frontend artifact, conv+BN, *pre*-activation Z)
//!       -> select C channels (static Eq. 2–3 table)
//!       -> n-bit per-channel quantization (Eq. 4)
//!       -> tile + entropy-code + frame (container)

use crate::codec::container;
use crate::codec::scratch::ScratchPool;
use crate::config::PipelineConfig;
use crate::quant;
use crate::runtime::pool::WorkerPool;
use crate::runtime::{Engine, Executable};
use crate::selection::ChannelStats;
use crate::tensor::{gather_channels_hwc_to_chw, Tensor};
use crate::util::StageClock;
use anyhow::Result;
use std::rc::Rc;
use std::sync::Arc;

/// Edge-side stage outputs (for diagnostics and tests).
#[derive(Debug, Clone)]
pub struct EdgeTrace {
    /// Split-layer BN output, (H, W, P), pre-activation.
    pub z: Tensor,
    /// Compressed frame size in bytes (the quantity Fig. 4 plots).
    pub frame_bytes: usize,
    /// Stripe count actually packed into the frame (after clamping to
    /// the available stripe units); 1 means a classic v1 frame.
    pub stripes: usize,
    /// Per-stage latency, microseconds.
    pub stages: Vec<(&'static str, f64)>,
}

/// The edge node. Thread-confined (owns PJRT state via `Engine`); the
/// encode stage itself fans stripes out over `pool` when
/// `cfg.stripes > 1`.
pub struct EdgeNode {
    engine: Rc<Engine>,
    frontend: Rc<Executable>,
    pub sel: Vec<usize>,
    pub cfg: PipelineConfig,
    /// Worker pool for intra-frame (striped) encode parallelism.
    pool: WorkerPool,
    /// Reusable encode buffers; share one across stages via
    /// [`Self::use_scratch`] to recycle frame buffers process-wide.
    scratch: Arc<ScratchPool>,
}

impl EdgeNode {
    pub fn new(engine: Rc<Engine>, stats: &ChannelStats, cfg: PipelineConfig) -> Result<Self> {
        let frontend = engine.load("frontend_b1")?;
        let sel = stats.select(cfg.policy, cfg.c);
        let pool = WorkerPool::new(cfg.stripes.max(1));
        let scratch = Arc::new(ScratchPool::new());
        Ok(EdgeNode { engine, frontend, sel, cfg, pool, scratch })
    }

    pub fn engine(&self) -> &Rc<Engine> {
        &self.engine
    }

    /// Swap in a shared scratch pool (e.g. the server's, so frame
    /// buffers recycled by the decode stage flow back into encode).
    pub fn use_scratch(&mut self, scratch: Arc<ScratchPool>) {
        self.scratch = scratch;
    }

    /// Run the full edge pipeline on one image (H, W, 3).
    pub fn process(&self, image: &Tensor) -> Result<(Vec<u8>, EdgeTrace)> {
        let mut clock = StageClock::new();
        let m = self.engine.manifest();
        let img_b1 = image.clone().reshape(&[1, m.image_size, m.image_size, 3]);
        let z = self
            .frontend
            .run(&[&img_b1])?
            .reshape(&[m.z_shape.0, m.z_shape.1, m.z_shape.2]);
        clock.lap("edge_infer");

        let planes = gather_channels_hwc_to_chw(&z, &self.sel);
        clock.lap("edge_select");

        let q = quant::quantize(&planes, self.cfg.n);
        clock.lap("edge_quant");

        // stripes > 1 selects the v2 striped container: each stripe is
        // entropy-coded concurrently on the pool, buffers from scratch
        let stripes = if self.cfg.stripes > 1 {
            let units = if self.cfg.codec == crate::codec::CodecKind::TlcIc {
                q.c
            } else {
                crate::tile::grid_for(q.c).1
            };
            self.cfg.stripes.clamp(1, units.max(1))
        } else {
            1
        };
        let frame = if stripes > 1 {
            container::pack_v2_with(
                &q,
                self.cfg.codec,
                self.cfg.qp,
                stripes,
                &self.pool,
                &self.scratch,
            )
        } else {
            container::pack(&q, self.cfg.codec, self.cfg.qp)
        };
        clock.lap("edge_encode");

        let trace = EdgeTrace {
            z,
            frame_bytes: frame.len(),
            stripes,
            stages: clock.stages().to_vec(),
        };
        Ok((frame, trace))
    }
}
