//! The edge (mobile) node: everything that runs on-device (Fig. 1, left).
//!
//! image -> layers 1..l (frontend artifact, conv+BN, *pre*-activation Z)
//!       -> select C channels (static Eq. 2–3 table)
//!       -> n-bit per-channel quantization (Eq. 4)
//!       -> tile + entropy-code + frame (container)

use crate::codec::container;
use crate::config::PipelineConfig;
use crate::quant;
use crate::runtime::{Engine, Executable};
use crate::selection::ChannelStats;
use crate::tensor::{gather_channels_hwc_to_chw, Tensor};
use crate::util::StageClock;
use anyhow::Result;
use std::rc::Rc;

/// Edge-side stage outputs (for diagnostics and tests).
#[derive(Debug, Clone)]
pub struct EdgeTrace {
    /// Split-layer BN output, (H, W, P), pre-activation.
    pub z: Tensor,
    /// Compressed frame size in bytes (the quantity Fig. 4 plots).
    pub frame_bytes: usize,
    /// Per-stage latency, microseconds.
    pub stages: Vec<(&'static str, f64)>,
}

/// The edge node. Thread-confined (owns PJRT state via `Engine`).
pub struct EdgeNode {
    engine: Rc<Engine>,
    frontend: Rc<Executable>,
    pub sel: Vec<usize>,
    pub cfg: PipelineConfig,
}

impl EdgeNode {
    pub fn new(engine: Rc<Engine>, stats: &ChannelStats, cfg: PipelineConfig) -> Result<Self> {
        let frontend = engine.load("frontend_b1")?;
        let sel = stats.select(cfg.policy, cfg.c);
        Ok(EdgeNode { engine, frontend, sel, cfg })
    }

    pub fn engine(&self) -> &Rc<Engine> {
        &self.engine
    }

    /// Run the full edge pipeline on one image (H, W, 3).
    pub fn process(&self, image: &Tensor) -> Result<(Vec<u8>, EdgeTrace)> {
        let mut clock = StageClock::new();
        let m = self.engine.manifest();
        let img_b1 = image.clone().reshape(&[1, m.image_size, m.image_size, 3]);
        let z = self
            .frontend
            .run(&[&img_b1])?
            .reshape(&[m.z_shape.0, m.z_shape.1, m.z_shape.2]);
        clock.lap("edge_infer");

        let planes = gather_channels_hwc_to_chw(&z, &self.sel);
        clock.lap("edge_select");

        let q = quant::quantize(&planes, self.cfg.n);
        clock.lap("edge_quant");

        let frame = container::pack(&q, self.cfg.codec, self.cfg.qp);
        clock.lap("edge_encode");

        let trace = EdgeTrace {
            z,
            frame_bytes: frame.len(),
            stages: clock.stages().to_vec(),
        };
        Ok((frame, trace))
    }
}
