//! The edge (mobile) node: everything that runs on-device (Fig. 1, left).
//!
//! image -> layers 1..l (frontend artifact, conv+BN, *pre*-activation Z)
//!       -> select C channels (static Eq. 2–3 table)
//!       -> n-bit per-channel quantization (Eq. 4)
//!       -> tile + entropy-code + frame (container)

use crate::codec::container;
use crate::codec::scratch::ScratchPool;
use crate::config::{PipelineConfig, ServerConfig};
use crate::metrics::Registry;
use crate::quant;
use crate::runtime::pool::WorkerPool;
use crate::runtime::{Engine, Executable};
use crate::selection::ChannelStats;
use crate::tensor::{gather_channels_hwc_to_chw, Tensor};
use crate::util::StageClock;
use anyhow::Result;
use std::rc::Rc;
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Edge-side stage outputs (for diagnostics and tests).
#[derive(Debug, Clone)]
pub struct EdgeTrace {
    /// Split-layer BN output, (H, W, P), pre-activation.
    pub z: Tensor,
    /// Compressed frame size in bytes (the quantity Fig. 4 plots).
    pub frame_bytes: usize,
    /// Stripe count actually packed into the frame (after clamping to
    /// the available stripe units); 1 means a classic v1 frame.
    pub stripes: usize,
    /// Per-stage latency, microseconds.
    pub stages: Vec<(&'static str, f64)>,
}

/// The edge node. Thread-confined (owns PJRT state via `Engine`); the
/// encode stage itself fans stripes out over `pool` when
/// `cfg.stripes > 1`.
pub struct EdgeNode {
    engine: Rc<Engine>,
    frontend: Rc<Executable>,
    pub sel: Vec<usize>,
    pub cfg: PipelineConfig,
    /// Worker pool for intra-frame (striped) encode parallelism.
    pool: WorkerPool,
    /// Reusable encode buffers; share one across stages via
    /// [`Self::use_scratch`] to recycle frame buffers process-wide.
    scratch: Arc<ScratchPool>,
}

impl EdgeNode {
    pub fn new(engine: Rc<Engine>, stats: &ChannelStats, cfg: PipelineConfig) -> Result<Self> {
        let frontend = engine.load("frontend_b1")?;
        let sel = stats.select(cfg.policy, cfg.c);
        let pool = WorkerPool::new(cfg.stripes.max(1));
        let scratch = Arc::new(ScratchPool::new());
        Ok(EdgeNode { engine, frontend, sel, cfg, pool, scratch })
    }

    pub fn engine(&self) -> &Rc<Engine> {
        &self.engine
    }

    /// Swap in a shared scratch pool (e.g. the server's, so frame
    /// buffers recycled by the decode stage flow back into encode).
    pub fn use_scratch(&mut self, scratch: Arc<ScratchPool>) {
        self.scratch = scratch;
    }

    /// Run the full edge pipeline on one image (H, W, 3).
    pub fn process(&self, image: &Tensor) -> Result<(Vec<u8>, EdgeTrace)> {
        let mut clock = StageClock::new();
        let m = self.engine.manifest();
        let img_b1 = image.clone().reshape(&[1, m.image_size, m.image_size, 3]);
        let z = self
            .frontend
            .run(&[&img_b1])?
            .reshape(&[m.z_shape.0, m.z_shape.1, m.z_shape.2]);
        clock.lap("edge_infer");

        let planes = gather_channels_hwc_to_chw(&z, &self.sel);
        clock.lap("edge_select");

        let q = quant::quantize(&planes, self.cfg.n);
        clock.lap("edge_quant");

        // stripes > 1 selects the v2 striped container: each stripe is
        // entropy-coded concurrently on the pool, buffers from scratch
        let stripes = if self.cfg.stripes > 1 {
            let units = if self.cfg.codec == crate::codec::CodecKind::TlcIc {
                q.c
            } else {
                crate::tile::grid_for(q.c).1
            };
            self.cfg.stripes.clamp(1, units.max(1))
        } else {
            1
        };
        let frame = if stripes > 1 {
            container::pack_v2_with(
                &q,
                self.cfg.codec,
                self.cfg.qp,
                stripes,
                &self.pool,
                &self.scratch,
            )
        } else {
            container::pack(&q, self.cfg.codec, self.cfg.qp)
        };
        clock.lap("edge_encode");

        let trace = EdgeTrace {
            z,
            frame_bytes: frame.len(),
            stripes,
            stages: clock.stages().to_vec(),
        };
        Ok((frame, trace))
    }
}

/// Summary of one TCP edge-client run (`baf serve --connect ADDR`).
///
/// Every request id lands in exactly one bucket: `sent + rejected +
/// busy + shed + failed == num_requests` (the client-side half of the
/// transport conservation law).
#[derive(Debug)]
pub struct EdgeClientReport {
    /// Frames acked by the server.
    pub sent: usize,
    /// Frames the server rejected at the wire layer (NACK). Only
    /// non-zero when `corrupt_rate` injects wire faults.
    pub rejected: usize,
    /// Frames the server refused with BUSY (its ingress queue was full
    /// of still-live frames): shed at the edge, never retransmitted.
    pub busy: usize,
    /// Frames shed locally by the open circuit breaker (the link was
    /// down long enough that retrying each frame would only add load).
    pub shed: usize,
    /// Frames that exhausted the reconnect budget without a verdict
    /// (link down or flapping).
    pub failed: usize,
    /// Wire bytes shipped (acked messages only).
    pub bytes: u64,
    /// Reconnect attempts performed by the sender.
    pub reconnects: u64,
    pub wall_seconds: f64,
    pub metrics: crate::json::Value,
    pub table: String,
}

/// Run the edge half of the split pipeline against a remote server:
/// the same arrival process, frontend inference, and encode stage as
/// the in-process edge thread in [`super::server::run_server`], but
/// frames leave over a [`crate::net::FrameSender`] instead of an mpsc
/// channel. The counterpart server runs with `ServerConfig::listen`
/// set.
///
/// `corrupt_rate` here mangles frames *before* the wire layer wraps
/// them, so the container CRC (not the wire CRC) is what the server's
/// decode stage trips on — exactly the lossy-channel scenario of the
/// paper. A server NACK (wire-level reject), a BUSY refusal, a
/// breaker-shed frame, or a decode-stage drop all consume the request
/// id, keeping both ends' accounting aligned; transport faults degrade
/// the run (counted buckets) instead of aborting it.
pub fn run_edge_client(
    pcfg: &PipelineConfig,
    scfg: &ServerConfig,
    connect: &str,
) -> Result<EdgeClientReport> {
    let stats = ChannelStats::load(&pcfg.artifact_dir)?;
    let registry = Registry::default();
    let engine = Rc::new(Engine::new(&pcfg.artifact_dir)?);
    let mut edge = EdgeNode::new(engine, &stats, pcfg.clone())?;
    edge.use_scratch(Arc::new(ScratchPool::new()));

    let pool = crate::data::eval_set(64.min(scfg.num_requests.max(1)));
    let images: Vec<Tensor> = pool.iter().map(|s| s.image.clone()).collect();

    let mut tx = crate::net::FrameSender::connect(connect, crate::net::NetConfig::default())
        .map_err(|e| anyhow::anyhow!("connecting to {connect}: {e}"))?;

    let mut rng = crate::util::SplitMix64::new(0xA221);
    let mut fault_rng = crate::util::SplitMix64::new(0xFA11);
    let mut corruptor = crate::codec::faultgen::Corruptor::new(0xC011A95E);
    let injected_c = registry.counter("frames_corrupted_injected");
    let rejected_c = registry.counter("net_frames_nacked");
    let edge_h = registry.histogram("1_edge_total");
    let send_h = registry.histogram("1_net_send");

    let failed_c = registry.counter("net_frames_send_failed");

    let t_start = Instant::now();
    let mut sent = 0usize;
    let mut rejected = 0usize;
    let mut busy = 0usize;
    let mut shed = 0usize;
    let mut failed = 0usize;
    let mut next_arrival = Instant::now();
    for id in 0..scfg.num_requests {
        next_arrival +=
            Duration::from_secs_f64(rng.next_exp(scfg.arrival_rate_for(id)));
        let now = Instant::now();
        if next_arrival > now {
            std::thread::sleep(next_arrival - now);
        }
        let t_arrival = Instant::now();
        let img = &images[id % images.len()];
        let (mut frame, _trace) = edge.process(img)?;
        if scfg.corrupt_rate > 0.0 && fault_rng.next_f64() < scfg.corrupt_rate {
            frame = corruptor.corrupt(&frame);
            injected_c.inc();
        }
        let t_edge_done = Instant::now();
        edge_h.record_us((t_edge_done - t_arrival).as_secs_f64() * 1e6);
        match tx.send(&frame) {
            Ok(()) => {
                sent += 1;
                send_h.record_us(t_edge_done.elapsed().as_secs_f64() * 1e6);
            }
            // the server refused the message at the wire layer (NACK —
            // something between the sockets mangled it): its decode
            // stage never sees the frame, but the request id is spent
            // on both ends, keeping the accounting aligned
            Err(crate::net::Error::Protocol(e)) => {
                log::warn!("edge client: frame {id} rejected: {e}");
                rejected += 1;
                rejected_c.inc();
            }
            // the server's ingress is full of still-live frames: this
            // frame is shed at the edge (the server accounted it too),
            // and the client moves on without retransmitting
            Err(e @ crate::net::Error::Busy) => {
                log::warn!("edge client: frame {id} shed: {e}");
                busy += 1;
            }
            // the breaker is open: the link has been down for a while,
            // so the frame is shed instantly instead of burning a full
            // reconnect budget on it
            Err(e @ crate::net::Error::BreakerOpen) => {
                log::debug!("edge client: frame {id} shed: {e}");
                shed += 1;
            }
            // transient transport failure that exhausted the reconnect
            // budget: the frame is lost, the run continues — a flapping
            // link must degrade the edge client, not kill it
            Err(e) => {
                log::warn!("edge client: frame {id} failed: {e}");
                failed += 1;
                failed_c.inc();
            }
        }
    }
    tx.stats().export_sender_into(&registry);

    let wall = t_start.elapsed().as_secs_f64();
    let st = tx.stats();
    Ok(EdgeClientReport {
        sent,
        rejected,
        busy,
        shed,
        failed,
        bytes: st.bytes,
        reconnects: st.reconnects,
        wall_seconds: wall,
        metrics: registry.export(),
        table: registry.table(),
    })
}
