//! The dynamic batcher: deadline + capacity batching of queued work.
//!
//! Policy (the same one vLLM-style servers use for request batching): the
//! first item of a batch opens a window of `deadline`; the batch closes
//! when either `cap` items have arrived or the window expires. A closed
//! batch is returned immediately; an idle batcher blocks on the first
//! item (with an overall `recv_timeout` so servers can drain and stop).

use std::sync::mpsc::{Receiver, RecvTimeoutError};
use std::time::{Duration, Instant};

/// Outcome of one `next_batch` call.
#[derive(Debug, PartialEq, Eq)]
pub enum BatchOutcome<T> {
    /// A non-empty batch (1..=cap items).
    Batch(Vec<T>),
    /// Channel closed and drained — the server should shut down.
    Closed,
    /// No traffic within the idle timeout (caller may loop again).
    Idle,
}

/// Pull the next dynamic batch from a channel.
// baf-lint: allow(unbounded-alloc) -- cap is the server's own batching config (trusted, small), not wire input
pub fn next_batch<T>(
    rx: &Receiver<T>,
    cap: usize,
    deadline: Duration,
    idle_timeout: Duration,
) -> BatchOutcome<T> {
    debug_assert!(cap >= 1);
    // wait for the first item
    let first = match rx.recv_timeout(idle_timeout) {
        Ok(item) => item,
        Err(RecvTimeoutError::Timeout) => return BatchOutcome::Idle,
        Err(RecvTimeoutError::Disconnected) => return BatchOutcome::Closed,
    };
    let mut batch = Vec::with_capacity(cap);
    batch.push(first);
    let close_at = Instant::now() + deadline;
    while batch.len() < cap {
        let now = Instant::now();
        if now >= close_at {
            break;
        }
        match rx.recv_timeout(close_at - now) {
            Ok(item) => batch.push(item),
            Err(RecvTimeoutError::Timeout) => break,
            Err(RecvTimeoutError::Disconnected) => break, // ship what we have
        }
    }
    BatchOutcome::Batch(batch)
}

#[cfg(test)]
mod tests {
    #![allow(clippy::unwrap_used, clippy::expect_used)]

    use super::*;
    use std::sync::mpsc;
    use std::thread;

    #[test]
    fn fills_to_cap_when_queue_is_hot() {
        let (tx, rx) = mpsc::channel();
        for i in 0..10 {
            tx.send(i).unwrap();
        }
        match next_batch(&rx, 4, Duration::from_millis(50), Duration::from_millis(50)) {
            BatchOutcome::Batch(b) => assert_eq!(b, vec![0, 1, 2, 3]),
            other => panic!("{other:?}"),
        }
        match next_batch(&rx, 4, Duration::from_millis(50), Duration::from_millis(50)) {
            BatchOutcome::Batch(b) => assert_eq!(b, vec![4, 5, 6, 7]),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn closes_at_deadline_with_partial_batch() {
        let (tx, rx) = mpsc::channel();
        tx.send(1).unwrap();
        let t0 = Instant::now();
        match next_batch(&rx, 8, Duration::from_millis(20), Duration::from_millis(500)) {
            BatchOutcome::Batch(b) => {
                assert_eq!(b, vec![1]);
                assert!(t0.elapsed() >= Duration::from_millis(18));
                assert!(t0.elapsed() < Duration::from_millis(200));
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn reports_idle_then_closed() {
        let (tx, rx) = mpsc::channel::<u32>();
        assert_eq!(
            next_batch(&rx, 4, Duration::from_millis(5), Duration::from_millis(10)),
            BatchOutcome::Idle
        );
        drop(tx);
        assert_eq!(
            next_batch(&rx, 4, Duration::from_millis(5), Duration::from_millis(10)),
            BatchOutcome::Closed
        );
    }

    #[test]
    fn late_arrivals_join_open_window() {
        let (tx, rx) = mpsc::channel();
        tx.send(0).unwrap();
        let sender = thread::spawn(move || {
            thread::sleep(Duration::from_millis(5));
            tx.send(1).unwrap();
            thread::sleep(Duration::from_millis(5));
            tx.send(2).unwrap();
        });
        match next_batch(&rx, 8, Duration::from_millis(60), Duration::from_millis(60)) {
            BatchOutcome::Batch(b) => assert_eq!(b, vec![0, 1, 2]),
            other => panic!("{other:?}"),
        }
        sender.join().unwrap();
    }

    #[test]
    fn cap_one_disables_batching() {
        let (tx, rx) = mpsc::channel();
        tx.send(7).unwrap();
        tx.send(8).unwrap();
        match next_batch(&rx, 1, Duration::from_millis(50), Duration::from_millis(50)) {
            BatchOutcome::Batch(b) => assert_eq!(b, vec![7]),
            other => panic!("{other:?}"),
        }
    }
}
