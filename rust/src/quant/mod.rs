//! Quantization (Eq. 4–5) and consolidation (Eq. 6) — Rust hot path.
//!
//! Semantics are pinned to the Python oracles in
//! `python/compile/kernels/ref.py` (checked via the kernel goldens): the
//! per-channel min/max side info is rounded to f16 *before* quantization,
//! round-half-away-from-zero matches `jnp.round`'s behaviour on the
//! non-negative normalized values used here, and constant channels
//! quantize to all-zeros.

use crate::tensor::Tensor;
use crate::util::f16::saturate_to_f16;

/// Per-channel quantizer parameters (the bitstream side info, C*32 bits).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ChannelRange {
    /// f16-rounded channel minimum (m_p in the paper).
    pub min: f32,
    /// f16-rounded channel maximum (M_p).
    pub max: f32,
}

impl ChannelRange {
    #[inline]
    pub fn span(&self) -> f32 {
        self.max - self.min
    }
}

/// Quantized channel planes: values in [0, 2^n - 1], channel-major.
#[derive(Debug, Clone)]
pub struct QuantizedTensor {
    /// Bin indices, shape (C, H, W), each < 2^n.
    pub bins: Vec<u16>,
    pub c: usize,
    pub h: usize,
    pub w: usize,
    /// Bit depth n (1..=16 supported end to end).
    pub n: u8,
    pub ranges: Vec<ChannelRange>,
}

impl QuantizedTensor {
    #[inline]
    pub fn levels(&self) -> u32 {
        (1u32 << self.n) - 1
    }

    pub fn plane(&self, ch: usize) -> &[u16] {
        &self.bins[ch * self.h * self.w..(ch + 1) * self.h * self.w]
    }
}

/// `jnp.round` rounds half to even; on the normalized value grid produced
/// by Eq. 4 the inputs virtually never land exactly on .5, but we match
/// the semantics anyway so goldens are bit-exact.
#[inline]
fn round_half_even(x: f32) -> f32 {
    let r = x.round(); // half away from zero
    if (x - x.trunc()).abs() == 0.5 && (r as i64) % 2 != 0 {
        r - (r - x).signum()
    } else {
        r
    }
}

/// Eq. 4: quantize a channel-major (C, H, W) tensor to n bits per channel.
pub fn quantize(z: &Tensor, n: u8) -> QuantizedTensor {
    assert!((1..=16).contains(&n), "n out of range: {n}");
    let s = z.shape();
    assert_eq!(s.len(), 3);
    let (c, h, w) = (s[0], s[1], s[2]);
    let levels = ((1u32 << n) - 1) as f32;
    let mut bins = vec![0u16; c * h * w];
    let mut ranges = Vec::with_capacity(c);
    for ch in 0..c {
        let plane = &z.data()[ch * h * w..(ch + 1) * h * w];
        let mut mn = f32::INFINITY;
        let mut mx = f32::NEG_INFINITY;
        for &v in plane {
            mn = mn.min(v);
            mx = mx.max(v);
        }
        let mn = saturate_to_f16(mn);
        let mx = saturate_to_f16(mx);
        let span = mx - mn;
        let range = ChannelRange { min: mn, max: mx };
        let out = &mut bins[ch * h * w..(ch + 1) * h * w];
        if span > 0.0 {
            let scale = levels / span;
            for (o, &v) in out.iter_mut().zip(plane) {
                let q = round_half_even((v - mn) * scale).clamp(0.0, levels);
                *o = q as u16;
            }
        } // else: all zeros (constant channel)
        ranges.push(range);
    }
    QuantizedTensor { bins, c, h, w, n, ranges }
}

/// Eq. 5: inverse quantization back to a channel-major f32 tensor.
pub fn dequantize(q: &QuantizedTensor) -> Tensor {
    let levels = q.levels() as f32;
    let mut out = vec![0f32; q.bins.len()];
    for ch in 0..q.c {
        let r = q.ranges[ch];
        let span = r.span();
        let plane = q.plane(ch);
        let dst = &mut out[ch * q.h * q.w..(ch + 1) * q.h * q.w];
        for (d, &b) in dst.iter_mut().zip(plane) {
            *d = b as f32 / levels * span + r.min;
        }
    }
    Tensor::from_vec(&[q.c, q.h, q.w], out)
}

/// Eq. 6: consolidate BaF predictions of the transmitted channels.
///
/// `z_tilde` is the BaF prediction of the same C channels, channel-major
/// (C, H, W); the result keeps z-tilde where it falls inside the decoded
/// bin and clamps it to the nearest bin boundary otherwise — i.e. an
/// elementwise clip to `[m + (q-0.5)*step, m + (q+0.5)*step]`. Constant
/// channels are pinned to their (single) transmitted value.
pub fn consolidate(z_tilde: &Tensor, q: &QuantizedTensor) -> Tensor {
    let s = z_tilde.shape();
    assert_eq!(s, &[q.c, q.h, q.w], "consolidate shape mismatch");
    let levels = q.levels() as f32;
    let mut out = vec![0f32; z_tilde.len()];
    for ch in 0..q.c {
        let r = q.ranges[ch];
        let span = r.span();
        let plane = q.plane(ch);
        let src = &z_tilde.data()[ch * q.h * q.w..(ch + 1) * q.h * q.w];
        let dst = &mut out[ch * q.h * q.w..(ch + 1) * q.h * q.w];
        if span > 0.0 {
            let step = span / levels;
            for ((d, &zt), &b) in dst.iter_mut().zip(src).zip(plane) {
                let lo = r.min + (b as f32 - 0.5) * step;
                let hi = r.min + (b as f32 + 0.5) * step;
                *d = zt.clamp(lo, hi);
            }
        } else {
            dst.fill(r.min);
        }
    }
    Tensor::from_vec(&[q.c, q.h, q.w], out)
}

/// Fraction of elements the consolidation actually changed — a useful
/// diagnostic: high values mean the BaF net disagrees with the decoded
/// bins a lot (low n or undertrained model).
pub fn consolidation_rate(z_tilde: &Tensor, q: &QuantizedTensor) -> f64 {
    let cons = consolidate(z_tilde, q);
    let changed = cons
        .data()
        .iter()
        .zip(z_tilde.data())
        .filter(|(a, b)| a != b)
        .count();
    changed as f64 / cons.len() as f64
}

#[cfg(test)]
mod tests {
    #![allow(clippy::unwrap_used, clippy::expect_used)]

    use super::*;
    use crate::util::SplitMix64;

    fn random_chw(c: usize, h: usize, w: usize, seed: u64) -> Tensor {
        let mut r = SplitMix64::new(seed);
        Tensor::from_vec(
            &[c, h, w],
            (0..c * h * w).map(|_| r.next_f32() * 6.0 - 3.0).collect(),
        )
    }

    #[test]
    fn quantize_dequantize_error_bounded_by_half_step() {
        for n in [1u8, 2, 4, 8, 12] {
            let z = random_chw(4, 8, 8, n as u64);
            let q = quantize(&z, n);
            let zh = dequantize(&q);
            for ch in 0..4 {
                let r = q.ranges[ch];
                let step = r.span() / q.levels() as f32;
                for y in 0..8 {
                    for x in 0..8 {
                        let err = (z.at3(ch, y, x) - zh.at3(ch, y, x)).abs();
                        // f16 rounding of min/max can cost at most ~half a
                        // step extra at the edges.
                        assert!(
                            err <= step * 1.01 + 1e-4,
                            "n={n} err={err} step={step}"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn bins_cover_full_range() {
        let z = random_chw(2, 16, 16, 9);
        let q = quantize(&z, 4);
        let mx = q.bins.iter().max().copied().unwrap();
        let mn = q.bins.iter().min().copied().unwrap();
        assert_eq!(mx, 15);
        assert_eq!(mn, 0);
    }

    #[test]
    fn constant_channel_roundtrips_exactly() {
        let z = Tensor::from_vec(&[1, 2, 2], vec![0.75; 4]);
        let q = quantize(&z, 8);
        assert!(q.bins.iter().all(|&b| b == 0));
        let zh = dequantize(&q);
        for v in zh.data() {
            assert!((v - 0.75).abs() < 1e-3); // f16 rounding of 0.75 is exact
        }
        let zt = Tensor::from_vec(&[1, 2, 2], vec![0.9, 0.7, 0.75, -1.0]);
        let cons = consolidate(&zt, &q);
        assert!(cons.data().iter().all(|&v| (v - zh.data()[0]).abs() < 1e-6));
    }

    #[test]
    fn consolidate_is_identity_inside_bins() {
        let z = random_chw(3, 8, 8, 4);
        let q = quantize(&z, 8);
        let zh = dequantize(&q);
        // the dequantized values are bin centers -> consolidation keeps them
        let cons = consolidate(&zh, &q);
        assert_eq!(cons, zh);
    }

    #[test]
    fn consolidate_clamps_outside_bins() {
        let z = Tensor::from_vec(&[1, 1, 2], vec![0.0, 1.0]);
        let q = quantize(&z, 2); // levels = 3, step = 1/3
        // push predictions far out of their bins
        let zt = Tensor::from_vec(&[1, 1, 2], vec![0.9, 0.1]);
        let cons = consolidate(&zt, &q);
        let step = 1.0 / 3.0;
        assert!((cons.data()[0] - 0.5 * step).abs() < 1e-4); // clamp to hi of bin 0
        assert!((cons.data()[1] - (1.0 - 0.5 * step)).abs() < 1e-4); // lo of bin 3
    }

    #[test]
    fn consolidation_rate_behaves() {
        let z = random_chw(2, 8, 8, 5);
        let q = quantize(&z, 6);
        let zh = dequantize(&q);
        assert_eq!(consolidation_rate(&zh, &q), 0.0);
        let mut far = zh.clone();
        for v in far.data_mut() {
            *v += 100.0;
        }
        assert_eq!(consolidation_rate(&far, &q), 1.0);
    }
}
