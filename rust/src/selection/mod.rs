//! Channel selection (paper §3.1): load the offline Eq. 2–3 ordering and
//! expose the selection policies used by the E6 ablation.
//!
//! The correlation-greedy order is computed at build time in Python (on
//! the L1 Pallas corr kernel) and shipped via `channel_stats.json`;
//! selection at serving time is a static table lookup — zero request-path
//! cost, exactly as the paper argues.

use crate::json::{self, Value};
use crate::util::SplitMix64;
use anyhow::{anyhow, Context, Result};
use std::path::Path;

/// Selection policies (E6 ablation: corr vs variance vs random).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Policy {
    /// The paper's Eq. 2–3 correlation-greedy ordering.
    Correlation,
    /// Highest-variance channels first.
    Variance,
    /// Uniform random subset (seeded, for reproducibility).
    Random(u64),
    /// First C channels in index order (the trivial baseline).
    FirstC,
}

impl Policy {
    pub fn parse(name: &str) -> Result<Policy> {
        Ok(match name {
            "corr" | "correlation" => Policy::Correlation,
            "var" | "variance" => Policy::Variance,
            "first" => Policy::FirstC,
            s if s.starts_with("random") => {
                let seed = s.strip_prefix("random:").and_then(|v| v.parse().ok());
                Policy::Random(seed.unwrap_or(1))
            }
            other => anyhow::bail!("unknown selection policy '{other}'"),
        })
    }

    pub fn name(&self) -> String {
        match self {
            Policy::Correlation => "correlation".into(),
            Policy::Variance => "variance".into(),
            Policy::Random(s) => format!("random:{s}"),
            Policy::FirstC => "first".into(),
        }
    }
}

/// Split-layer BN parameters (needed by diagnostics/tools; the inverse-BN
/// itself is baked into the BaF artifacts).
#[derive(Debug, Clone)]
pub struct BnParams {
    pub gamma: Vec<f32>,
    pub beta: Vec<f32>,
    pub mean: Vec<f32>,
    pub var: Vec<f32>,
}

/// The offline channel statistics produced by `python/compile/stats.py`.
#[derive(Debug, Clone)]
pub struct ChannelStats {
    pub p_channels: usize,
    pub q_channels: usize,
    /// Correlation-greedy order (take the first C).
    pub order: Vec<usize>,
    /// Per-channel total correlation scores (Eq. 3 objective).
    pub rho_total: Vec<f64>,
    /// Variance-descending order (ablation).
    pub variance_order: Vec<usize>,
    pub variance: Vec<f64>,
    pub bn: BnParams,
    pub z_min: f32,
    pub z_max: f32,
}

impl ChannelStats {
    /// Load `<dir>/channel_stats.json`.
    pub fn load(artifact_dir: &Path) -> Result<Self> {
        let v = json::from_file(&artifact_dir.join("channel_stats.json"))
            .context("loading channel stats")?;
        let vecf = |val: &Value, key: &str| -> Result<Vec<f64>> {
            val.req(key)?.as_f64_vec().ok_or_else(|| anyhow!("bad {key}"))
        };
        let bn_obj = v.req("bn")?;
        let bn_vec = |key: &str| -> Result<Vec<f32>> {
            Ok(vecf(bn_obj, key)?.into_iter().map(|x| x as f32).collect())
        };
        Ok(ChannelStats {
            p_channels: v.req("p_channels")?.as_usize().unwrap_or(0),
            q_channels: v.req("q_channels")?.as_usize().unwrap_or(0),
            order: v
                .req("order")?
                .as_usize_vec()
                .ok_or_else(|| anyhow!("bad order"))?,
            rho_total: vecf(&v, "rho_total")?,
            variance_order: v
                .req("variance_order")?
                .as_usize_vec()
                .ok_or_else(|| anyhow!("bad variance_order"))?,
            variance: vecf(&v, "variance")?,
            bn: BnParams {
                gamma: bn_vec("gamma")?,
                beta: bn_vec("beta")?,
                mean: bn_vec("mean")?,
                var: bn_vec("var")?,
            },
            z_min: v.req("z_min")?.as_f64().unwrap_or(0.0) as f32,
            z_max: v.req("z_max")?.as_f64().unwrap_or(0.0) as f32,
        })
    }

    /// The first C channels under a policy.
    pub fn select(&self, policy: Policy, c: usize) -> Vec<usize> {
        assert!(c <= self.p_channels, "C={c} > P={}", self.p_channels);
        match policy {
            Policy::Correlation => self.order[..c].to_vec(),
            Policy::Variance => self.variance_order[..c].to_vec(),
            Policy::FirstC => (0..c).collect(),
            Policy::Random(seed) => {
                let mut idx: Vec<usize> = (0..self.p_channels).collect();
                let mut rng = SplitMix64::new(seed);
                rng.shuffle(&mut idx);
                idx.truncate(c);
                idx
            }
        }
    }

    /// Sanity validation against a manifest's geometry.
    pub fn validate(&self, p_channels: usize, q_channels: usize) -> Result<()> {
        if self.p_channels != p_channels || self.q_channels != q_channels {
            anyhow::bail!(
                "channel stats geometry ({}, {}) != manifest ({}, {})",
                self.p_channels,
                self.q_channels,
                p_channels,
                q_channels
            );
        }
        let mut seen = vec![false; self.p_channels];
        for &ch in &self.order {
            if ch >= self.p_channels || seen[ch] {
                anyhow::bail!("order is not a permutation");
            }
            seen[ch] = true;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fake_stats() -> ChannelStats {
        ChannelStats {
            p_channels: 8,
            q_channels: 4,
            order: vec![3, 1, 7, 0, 2, 6, 5, 4],
            rho_total: vec![0.5; 8],
            variance_order: vec![0, 1, 2, 3, 4, 5, 6, 7],
            variance: vec![1.0; 8],
            bn: BnParams {
                gamma: vec![1.0; 8],
                beta: vec![0.0; 8],
                mean: vec![0.0; 8],
                var: vec![1.0; 8],
            },
            z_min: -1.0,
            z_max: 1.0,
        }
    }

    #[test]
    fn policies_select_c_distinct_channels() {
        let st = fake_stats();
        for p in [
            Policy::Correlation,
            Policy::Variance,
            Policy::FirstC,
            Policy::Random(9),
        ] {
            let sel = st.select(p, 4);
            assert_eq!(sel.len(), 4);
            let mut s = sel.clone();
            s.sort_unstable();
            s.dedup();
            assert_eq!(s.len(), 4, "{p:?} returned duplicates");
        }
        assert_eq!(st.select(Policy::Correlation, 3), vec![3, 1, 7]);
        assert_eq!(st.select(Policy::FirstC, 2), vec![0, 1]);
    }

    #[test]
    fn random_policy_is_seed_stable() {
        let st = fake_stats();
        assert_eq!(st.select(Policy::Random(5), 4), st.select(Policy::Random(5), 4));
        assert_ne!(st.select(Policy::Random(5), 8), st.select(Policy::Random(6), 8));
    }

    #[test]
    fn validate_checks_permutation() {
        let mut st = fake_stats();
        assert!(st.validate(8, 4).is_ok());
        assert!(st.validate(16, 4).is_err());
        st.order[0] = 1; // duplicate
        assert!(st.validate(8, 4).is_err());
    }

    #[test]
    fn policy_parse_roundtrip() {
        for name in ["corr", "variance", "first", "random:7"] {
            let p = Policy::parse(name).unwrap();
            assert_eq!(Policy::parse(&p.name()).unwrap(), p);
        }
        assert!(Policy::parse("pca").is_err());
    }
}
