//! Typed run configuration, loadable from JSON (`configs/*.json`) with
//! CLI overrides layered on top (see `cli`).

use crate::codec::CodecKind;
use crate::json::{self, Value};
use crate::selection::Policy;
use anyhow::{bail, Result};
use std::path::{Path, PathBuf};

/// Configuration of the compression pipeline (one (C, n, codec) operating
/// point of the paper's system).
#[derive(Debug, Clone)]
pub struct PipelineConfig {
    /// Artifact directory (HLO + manifest + stats).
    pub artifact_dir: PathBuf,
    /// Number of transmitted channels C (must have a trained BaF model).
    pub c: usize,
    /// Quantizer bit depth n.
    pub n: u8,
    /// Payload codec for the tiled image.
    pub codec: CodecKind,
    /// QP for lossy codecs (ignored by lossless ones).
    pub qp: u8,
    /// Channel-selection policy (paper = Correlation).
    pub policy: Policy,
    /// Apply Eq. 6 consolidation (paper = true; ablation E6 flips it).
    pub consolidate: bool,
    /// Stripe count K for the v2 striped container (1 = classic v1
    /// single-stream frames). Clamped to the available stripe units at
    /// pack time; stripes encode/decode concurrently.
    pub stripes: usize,
}

impl Default for PipelineConfig {
    fn default() -> Self {
        PipelineConfig {
            artifact_dir: crate::runtime::default_artifact_dir(),
            c: 16,
            n: 8,
            codec: CodecKind::Tlc,
            qp: 0,
            policy: Policy::Correlation,
            consolidate: true,
            stripes: 1,
        }
    }
}

/// Configuration of the serving demo / E5 bench.
#[derive(Debug, Clone)]
pub struct ServerConfig {
    /// Dynamic batcher: max requests per batch (1 disables batching).
    pub batch_cap: usize,
    /// Dynamic batcher: max wait for a batch to fill, microseconds.
    pub batch_deadline_us: u64,
    /// Poisson arrival rate, requests/second.
    pub arrival_rate: f64,
    /// Total requests to serve.
    pub num_requests: usize,
    /// Cloud-side decode worker threads (entropy decode + dequant).
    pub decode_workers: usize,
    /// Bounded queue depth between stages (backpressure).
    pub queue_depth: usize,
    /// Arrival process: interleaves ON periods at `burst_factor` x rate
    /// with OFF periods so the mean rate stays `arrival_rate` (a simple
    /// MMPP-2). 1.0 = plain Poisson.
    pub burst_factor: f64,
    /// Fraction of frames to corrupt in flight (fault injection for the
    /// robustness demo; 0.0 disables). Corrupt frames must be dropped
    /// and counted, never crash the server.
    pub corrupt_rate: f64,
    /// TCP serving mode, cloud side: accept edge frames on this address
    /// (e.g. `127.0.0.1:7878`). `None` keeps the in-process mpsc edge.
    pub listen: Option<String>,
    /// TCP serving mode, edge side: ship frames to a listening server
    /// at this address instead of running the local pipeline.
    pub connect: Option<String>,
    /// TCP serving mode: capacity of the bounded ingress queue between
    /// the receiver thread and the decode dispatcher. When it fills,
    /// the overload policy in [`crate::coordinator::ingress`] decides
    /// between shedding the oldest expired frame and answering BUSY.
    pub ingress_depth: usize,
    /// TCP serving mode: per-frame latency budget, milliseconds. A
    /// queued frame older than this is shed-eligible when the ingress
    /// queue is full (drop-oldest past deadline).
    pub shed_deadline_ms: u64,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            batch_cap: 8,
            batch_deadline_us: 2000,
            arrival_rate: 200.0,
            num_requests: 512,
            decode_workers: 2,
            queue_depth: 64,
            burst_factor: 1.0,
            corrupt_rate: 0.0,
            listen: None,
            connect: None,
            ingress_depth: 256,
            shed_deadline_ms: 250,
        }
    }
}

fn set_if<T>(slot: &mut T, v: Option<T>) {
    if let Some(v) = v {
        *slot = v;
    }
}

impl PipelineConfig {
    /// Overlay fields present in a JSON object onto `self`.
    ///
    /// Out-of-range values are rejected with an error naming the field
    /// and the offending value (they used to be truncated silently with
    /// `as u8`, which turned e.g. `"n": 257` into n=1).
    pub fn apply(&mut self, v: &Value) -> Result<()> {
        if let Some(s) = v.get("artifact_dir").and_then(Value::as_str) {
            self.artifact_dir = PathBuf::from(s);
        }
        if let Some(c) = v.get("c").and_then(Value::as_usize) {
            if c == 0 {
                bail!("config field 'c': must be >= 1, got {c}");
            }
            self.c = c;
        }
        if let Some(n) = v.get("n").and_then(Value::as_i64) {
            if !(1..=16).contains(&n) {
                bail!("config field 'n': bit depth must be in 1..=16, got {n}");
            }
            self.n = n as u8;
        }
        if let Some(s) = v.get("codec").and_then(Value::as_str) {
            self.codec = CodecKind::from_name(s)?;
        }
        if let Some(qp) = v.get("qp").and_then(Value::as_i64) {
            if !(0..=255).contains(&qp) {
                bail!("config field 'qp': must be in 0..=255, got {qp}");
            }
            self.qp = qp as u8;
        }
        if let Some(s) = v.get("policy").and_then(Value::as_str) {
            self.policy = Policy::parse(s)?;
        }
        set_if(&mut self.consolidate, v.get("consolidate").and_then(Value::as_bool));
        if let Some(k) = v.get("stripes").and_then(Value::as_i64) {
            if !(1..=1024).contains(&k) {
                bail!("config field 'stripes': must be in 1..=1024, got {k}");
            }
            self.stripes = k as usize;
        }
        Ok(())
    }

    pub fn load(path: &Path) -> Result<Self> {
        let mut cfg = Self::default();
        let v = json::from_file(path)?;
        cfg.apply(&v)?;
        if let Some(server) = v.get("server") {
            // tolerated here so one file can hold both sections
            let _ = server;
        }
        Ok(cfg)
    }
}

impl ServerConfig {
    /// Overlay fields present in a JSON object onto `self`, rejecting
    /// out-of-range values with an error that names the field.
    pub fn apply(&mut self, v: &Value) -> Result<()> {
        if let Some(b) = v.get("batch_cap").and_then(Value::as_usize) {
            if b == 0 {
                bail!("config field 'batch_cap': must be >= 1, got {b}");
            }
            self.batch_cap = b;
        }
        set_if(
            &mut self.batch_deadline_us,
            v.get("batch_deadline_us").and_then(Value::as_i64).map(|x| x as u64),
        );
        set_if(&mut self.arrival_rate, v.get("arrival_rate").and_then(Value::as_f64));
        set_if(&mut self.num_requests, v.get("num_requests").and_then(Value::as_usize));
        if let Some(w) = v.get("decode_workers").and_then(Value::as_usize) {
            if w == 0 {
                bail!("config field 'decode_workers': must be >= 1, got {w}");
            }
            self.decode_workers = w;
        }
        set_if(&mut self.queue_depth, v.get("queue_depth").and_then(Value::as_usize));
        set_if(&mut self.burst_factor, v.get("burst_factor").and_then(Value::as_f64));
        if let Some(r) = v.get("corrupt_rate").and_then(Value::as_f64) {
            if !(0.0..=1.0).contains(&r) {
                bail!("config field 'corrupt_rate': must be in 0.0..=1.0, got {r}");
            }
            self.corrupt_rate = r;
        }
        if let Some(s) = v.get("listen").and_then(Value::as_str) {
            self.listen = Some(s.to_string());
        }
        if let Some(s) = v.get("connect").and_then(Value::as_str) {
            self.connect = Some(s.to_string());
        }
        if let Some(d) = v.get("ingress_depth").and_then(Value::as_usize) {
            if d == 0 {
                bail!("config field 'ingress_depth': must be >= 1, got {d}");
            }
            self.ingress_depth = d;
        }
        set_if(
            &mut self.shed_deadline_ms,
            v.get("shed_deadline_ms").and_then(Value::as_i64).map(|x| x as u64),
        );
        Ok(())
    }

    /// Instantaneous arrival rate for request `id` under the MMPP-2
    /// arrival process: alternate ON phases at `burst_factor` x rate
    /// with OFF phases every 16 requests, the OFF rate chosen so the
    /// harmonic mean of the two phase rates equals `arrival_rate`.
    /// `burst_factor <= 1.0` degenerates to plain Poisson. Shared by
    /// the in-process edge thread and the TCP edge client so both
    /// serving modes present identical load.
    pub fn arrival_rate_for(&self, id: usize) -> f64 {
        let bf = self.burst_factor;
        if bf <= 1.0 {
            return self.arrival_rate;
        }
        let on_phase = (id / 16) % 2 == 0;
        if on_phase {
            self.arrival_rate * bf
        } else {
            self.arrival_rate * bf / (2.0 * bf - 1.0)
        }
    }

    pub fn load(path: &Path) -> Result<Self> {
        let mut cfg = Self::default();
        let v = json::from_file(path)?;
        cfg.apply(v.get("server").unwrap_or(&v))?;
        Ok(cfg)
    }
}

#[cfg(test)]
mod tests {
    #![allow(clippy::unwrap_used, clippy::expect_used)]

    use super::*;
    use crate::json::parse;

    #[test]
    fn pipeline_overlay() {
        let mut cfg = PipelineConfig::default();
        let v = parse(r#"{"c": 32, "n": 6, "codec": "mic", "qp": 20, "policy": "variance", "consolidate": false, "stripes": 4}"#).unwrap();
        cfg.apply(&v).unwrap();
        assert_eq!(cfg.c, 32);
        assert_eq!(cfg.n, 6);
        assert_eq!(cfg.codec, CodecKind::Mic);
        assert_eq!(cfg.qp, 20);
        assert_eq!(cfg.policy, Policy::Variance);
        assert!(!cfg.consolidate);
        assert_eq!(cfg.stripes, 4);
    }

    #[test]
    fn stripes_validated() {
        let mut cfg = PipelineConfig::default();
        assert_eq!(cfg.stripes, 1);
        let err = cfg.apply(&parse(r#"{"stripes": 0}"#).unwrap()).unwrap_err();
        assert!(err.to_string().contains("'stripes'"), "{err}");
        assert!(cfg.apply(&parse(r#"{"stripes": 4096}"#).unwrap()).is_err());
        assert!(cfg.apply(&parse(r#"{"stripes": 8}"#).unwrap()).is_ok());
        assert_eq!(cfg.stripes, 8);
    }

    #[test]
    fn partial_overlay_keeps_defaults() {
        let mut cfg = PipelineConfig::default();
        cfg.apply(&parse(r#"{"c": 8}"#).unwrap()).unwrap();
        assert_eq!(cfg.c, 8);
        assert_eq!(cfg.n, 8);
        assert_eq!(cfg.codec, CodecKind::Tlc);
    }

    #[test]
    fn bad_codec_name_errors() {
        let mut cfg = PipelineConfig::default();
        assert!(cfg.apply(&parse(r#"{"codec": "h264"}"#).unwrap()).is_err());
    }

    #[test]
    fn server_overlay() {
        let mut cfg = ServerConfig::default();
        cfg.apply(&parse(r#"{"batch_cap": 4, "arrival_rate": 50.5}"#).unwrap())
            .unwrap();
        assert_eq!(cfg.batch_cap, 4);
        assert_eq!(cfg.arrival_rate, 50.5);
        assert_eq!(cfg.num_requests, 512);
        assert_eq!(cfg.corrupt_rate, 0.0);
    }

    #[test]
    fn transport_addresses_overlay() {
        let mut cfg = ServerConfig::default();
        assert!(cfg.listen.is_none() && cfg.connect.is_none());
        cfg.apply(&parse(r#"{"listen": "0.0.0.0:7878"}"#).unwrap()).unwrap();
        assert_eq!(cfg.listen.as_deref(), Some("0.0.0.0:7878"));
        cfg.apply(&parse(r#"{"connect": "10.0.0.2:7878"}"#).unwrap()).unwrap();
        assert_eq!(cfg.connect.as_deref(), Some("10.0.0.2:7878"));
    }

    #[test]
    fn ingress_overlay_and_validation() {
        let mut cfg = ServerConfig::default();
        assert_eq!(cfg.ingress_depth, 256);
        assert_eq!(cfg.shed_deadline_ms, 250);
        cfg.apply(&parse(r#"{"ingress_depth": 8, "shed_deadline_ms": 50}"#).unwrap())
            .unwrap();
        assert_eq!(cfg.ingress_depth, 8);
        assert_eq!(cfg.shed_deadline_ms, 50);
        let err = cfg.apply(&parse(r#"{"ingress_depth": 0}"#).unwrap()).unwrap_err();
        assert!(err.to_string().contains("'ingress_depth'"), "{err}");
        assert_eq!(cfg.ingress_depth, 8);
    }

    #[test]
    fn mmpp_rate_alternates_and_degenerates_to_poisson() {
        let mut cfg = ServerConfig { arrival_rate: 100.0, ..Default::default() };
        assert_eq!(cfg.arrival_rate_for(0), 100.0);
        assert_eq!(cfg.arrival_rate_for(999), 100.0);
        cfg.burst_factor = 4.0;
        let on = cfg.arrival_rate_for(0); // ids 0..16 are the ON phase
        let off = cfg.arrival_rate_for(16);
        assert_eq!(on, 400.0);
        assert!(off < 100.0, "OFF phase must run below the mean rate");
        // harmonic mean of the phase rates equals the configured mean
        let hm = 2.0 / (1.0 / on + 1.0 / off);
        assert!((hm - 100.0).abs() < 1e-9, "harmonic mean {hm}");
    }

    #[test]
    fn out_of_range_values_name_the_field() {
        let mut cfg = PipelineConfig::default();
        let err = cfg.apply(&parse(r#"{"n": 257}"#).unwrap()).unwrap_err();
        assert!(err.to_string().contains("'n'"), "{err}");
        assert!(err.to_string().contains("257"), "{err}");
        assert_eq!(cfg.n, 8, "rejected overlay must not mutate the field");
        let err = cfg.apply(&parse(r#"{"c": 0}"#).unwrap()).unwrap_err();
        assert!(err.to_string().contains("'c'"), "{err}");

        let mut scfg = ServerConfig::default();
        let err = scfg
            .apply(&parse(r#"{"corrupt_rate": 1.5}"#).unwrap())
            .unwrap_err();
        assert!(err.to_string().contains("'corrupt_rate'"), "{err}");
        assert!(scfg.apply(&parse(r#"{"corrupt_rate": 0.1}"#).unwrap()).is_ok());
        assert_eq!(scfg.corrupt_rate, 0.1);
        assert!(scfg.apply(&parse(r#"{"decode_workers": 0}"#).unwrap()).is_err());
    }
}
