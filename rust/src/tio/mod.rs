//! `.npy` (NumPy v1.0) reader/writer — the python⇄rust tensor interchange.
//!
//! Only what the golden files and tools need: little-endian `<f4` / `<i4`
//! / `<i8`, C-order. Anything else is rejected loudly.
//!
//! Allocation bounds: the declared header length is capped at
//! [`MAX_HEADER_LEN`] and the declared element count (shape product,
//! computed with overflow checks) at [`crate::codec::MAX_DECODED_SAMPLES`]
//! — a corrupt or hostile header errors with a typed
//! [`crate::codec::Error::LimitExceeded`] instead of driving a huge `vec!`
//! allocation.

use crate::tensor::Tensor;
use anyhow::{anyhow, bail, Context, Result};
use std::io::{Read, Write};
use std::path::Path;

const MAGIC: &[u8; 6] = b"\x93NUMPY";

/// Upper bound on the declared npy header length (the real headers this
/// crate writes/reads are < 1 KiB; 1 MiB leaves huge margin).
pub const MAX_HEADER_LEN: usize = 1 << 20;

/// Typed payload of an `.npy` file.
#[derive(Debug, Clone)]
pub enum Npy {
    F32 { shape: Vec<usize>, data: Vec<f32> },
    I32 { shape: Vec<usize>, data: Vec<i32> },
    I64 { shape: Vec<usize>, data: Vec<i64> },
}

impl Npy {
    pub fn shape(&self) -> &[usize] {
        match self {
            Npy::F32 { shape, .. } | Npy::I32 { shape, .. } | Npy::I64 { shape, .. } => shape,
        }
    }

    pub fn into_tensor(self) -> Result<Tensor> {
        match self {
            Npy::F32 { shape, data } => Ok(Tensor::from_vec(&shape, data)),
            _ => bail!("expected f32 npy"),
        }
    }

    pub fn into_i32(self) -> Result<(Vec<usize>, Vec<i32>)> {
        match self {
            Npy::I32 { shape, data } => Ok((shape, data)),
            _ => bail!("expected i32 npy"),
        }
    }
}

/// Read an `.npy` file.
pub fn read(path: &Path) -> Result<Npy> {
    let mut f = std::fs::File::open(path)
        .with_context(|| format!("opening {}", path.display()))?;
    let mut magic = [0u8; 8];
    f.read_exact(&mut magic)?;
    if &magic[..6] != MAGIC {
        bail!("{}: not an npy file", path.display());
    }
    let (major, _minor) = (magic[6], magic[7]);
    let header_len = if major == 1 {
        let mut b = [0u8; 2];
        f.read_exact(&mut b)?;
        u16::from_le_bytes(b) as usize
    } else {
        let mut b = [0u8; 4];
        f.read_exact(&mut b)?;
        u32::from_le_bytes(b) as usize
    };
    if header_len > MAX_HEADER_LEN {
        return Err(crate::codec::Error::LimitExceeded {
            what: "npy header bytes",
            requested: header_len,
            limit: MAX_HEADER_LEN,
        }
        .into());
    }
    let mut header = vec![0u8; header_len];
    f.read_exact(&mut header)?;
    let header = String::from_utf8(header)?;

    let descr = extract(&header, "'descr':")?;
    let fortran = extract(&header, "'fortran_order':")?;
    if fortran.trim_start().starts_with("True") {
        bail!("{}: fortran order unsupported", path.display());
    }
    let shape = parse_shape(&header)?;
    let count = shape
        .iter()
        .try_fold(1usize, |acc, &d| acc.checked_mul(d))
        .ok_or(crate::codec::Error::LimitExceeded {
            what: "npy shape product",
            requested: usize::MAX,
            limit: crate::codec::MAX_DECODED_SAMPLES,
        })?;
    if count > crate::codec::MAX_DECODED_SAMPLES {
        return Err(crate::codec::Error::LimitExceeded {
            what: "npy element count",
            requested: count,
            limit: crate::codec::MAX_DECODED_SAMPLES,
        }
        .into());
    }

    let mut payload = Vec::new();
    f.read_to_end(&mut payload)?;

    let descr = descr.trim().trim_matches(|c| c == '\'' || c == '"');
    match descr {
        "<f4" => {
            ensure_len(&payload, count * 4, path)?;
            // decode exactly `count` elements: a payload longer than the
            // declared shape (corrupt header) must not yield a tensor
            // whose data length disagrees with its shape
            let data = payload
                .chunks_exact(4)
                .take(count)
                .map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
                .collect();
            Ok(Npy::F32 { shape, data })
        }
        "<i4" => {
            ensure_len(&payload, count * 4, path)?;
            let data = payload
                .chunks_exact(4)
                .take(count)
                .map(|b| i32::from_le_bytes([b[0], b[1], b[2], b[3]]))
                .collect();
            Ok(Npy::I32 { shape, data })
        }
        "<i8" => {
            ensure_len(&payload, count * 8, path)?;
            let data = payload
                .chunks_exact(8)
                .take(count)
                .map(|b| i64::from_le_bytes(b.try_into().unwrap()))
                .collect();
            Ok(Npy::I64 { shape, data })
        }
        other => bail!("{}: unsupported dtype {other}", path.display()),
    }
}

/// Write an f32 tensor as `.npy` v1.0.
pub fn write_f32(path: &Path, t: &Tensor) -> Result<()> {
    let mut f = std::fs::File::create(path)
        .with_context(|| format!("creating {}", path.display()))?;
    let shape_str = match t.shape().len() {
        1 => format!("({},)", t.shape()[0]),
        _ => format!(
            "({})",
            t.shape().iter().map(|d| d.to_string()).collect::<Vec<_>>().join(", ")
        ),
    };
    let mut header = format!(
        "{{'descr': '<f4', 'fortran_order': False, 'shape': {shape_str}, }}"
    );
    // pad so that magic(8) + len(2) + header is a multiple of 64
    let unpadded = 8 + 2 + header.len() + 1;
    let pad = (64 - unpadded % 64) % 64;
    header.push_str(&" ".repeat(pad));
    header.push('\n');
    f.write_all(MAGIC)?;
    f.write_all(&[1, 0])?;
    f.write_all(&(header.len() as u16).to_le_bytes())?;
    f.write_all(header.as_bytes())?;
    for v in t.data() {
        f.write_all(&v.to_le_bytes())?;
    }
    Ok(())
}

fn ensure_len(payload: &[u8], want: usize, path: &Path) -> Result<()> {
    if payload.len() < want {
        bail!("{}: truncated payload ({} < {want})", path.display(), payload.len());
    }
    Ok(())
}

fn extract<'a>(header: &'a str, key: &str) -> Result<&'a str> {
    let start = header
        .find(key)
        .ok_or_else(|| anyhow!("npy header missing {key}"))?
        + key.len();
    let rest = &header[start..];
    let end = rest.find(',').unwrap_or(rest.len());
    Ok(&rest[..end])
}

fn parse_shape(header: &str) -> Result<Vec<usize>> {
    let raw = header
        .find("'shape':")
        .ok_or_else(|| anyhow!("npy header missing shape"))?;
    let rest = &header[raw + 8..];
    // find the ')' *after* the '(' — searching the whole string could
    // yield close < open on garbage like `'shape': )(` and panic the
    // reversed slice below
    let open = rest.find('(').ok_or_else(|| anyhow!("bad shape"))?;
    let body = &rest[open + 1..];
    let close = body.find(')').ok_or_else(|| anyhow!("bad shape"))?;
    let inner = &body[..close];
    let mut out = Vec::new();
    for part in inner.split(',') {
        let p = part.trim();
        if p.is_empty() {
            continue;
        }
        out.push(p.parse::<usize>().map_err(|_| anyhow!("bad shape dim '{p}'"))?);
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn write_read_roundtrip() {
        let dir = std::env::temp_dir().join("baf_tio_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("t.npy");
        let t = Tensor::from_vec(&[2, 3], vec![1.0, -2.5, 3.0, 0.0, 1e-7, 6.0]);
        write_f32(&path, &t).unwrap();
        let back = read(&path).unwrap().into_tensor().unwrap();
        assert_eq!(back, t);
    }

    #[test]
    fn scalar_rank1_roundtrip() {
        let dir = std::env::temp_dir().join("baf_tio_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("r1.npy");
        let t = Tensor::from_vec(&[4], vec![9.0, 8.0, 7.0, 6.0]);
        write_f32(&path, &t).unwrap();
        let back = read(&path).unwrap();
        assert_eq!(back.shape(), &[4]);
    }

    #[test]
    fn rejects_non_npy() {
        let dir = std::env::temp_dir().join("baf_tio_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("junk.npy");
        std::fs::write(&path, b"not numpy at all").unwrap();
        assert!(read(&path).is_err());
    }

    /// Hand-build an npy v2.0 file with an arbitrary declared header
    /// length and header text (v2 uses a u32 length, so it can declare
    /// absurd values).
    fn hostile_npy(declared_header_len: u32, header: &str) -> Vec<u8> {
        let mut out = Vec::new();
        out.extend_from_slice(MAGIC);
        out.extend_from_slice(&[2, 0]);
        out.extend_from_slice(&declared_header_len.to_le_bytes());
        out.extend_from_slice(header.as_bytes());
        out
    }

    #[test]
    fn oversized_header_len_is_a_typed_limit_error() {
        let dir = std::env::temp_dir().join("baf_tio_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bigheader.npy");
        // declares a 1 GiB header; the file itself stays tiny
        std::fs::write(&path, hostile_npy(1 << 30, "")).unwrap();
        let err = read(&path).expect_err("must reject");
        let codec_err = err
            .downcast_ref::<crate::codec::Error>()
            .expect("typed codec error");
        assert!(matches!(
            codec_err,
            crate::codec::Error::LimitExceeded { what: "npy header bytes", .. }
        ));
    }

    #[test]
    fn oversized_shape_is_a_typed_limit_error() {
        let dir = std::env::temp_dir().join("baf_tio_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bigshape.npy");
        // shape product (2^30) is far over MAX_DECODED_SAMPLES but does
        // not overflow usize — hits the element-count cap
        let header =
            "{'descr': '<f4', 'fortran_order': False, 'shape': (32768, 32768), }\n";
        std::fs::write(
            &path,
            hostile_npy(header.len() as u32, header),
        )
        .unwrap();
        let err = read(&path).expect_err("must reject");
        let codec_err = err
            .downcast_ref::<crate::codec::Error>()
            .expect("typed codec error");
        assert!(matches!(
            codec_err,
            crate::codec::Error::LimitExceeded { what: "npy element count", .. }
        ));
    }

    #[test]
    fn overflowing_shape_product_is_a_typed_limit_error() {
        let dir = std::env::temp_dir().join("baf_tio_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("overflowshape.npy");
        // product overflows usize; checked_mul must catch it, not wrap
        let header = "{'descr': '<f4', 'fortran_order': False, \
                      'shape': (18446744073709551615, 16), }\n";
        std::fs::write(
            &path,
            hostile_npy(header.len() as u32, header),
        )
        .unwrap();
        let err = read(&path).expect_err("must reject");
        assert!(err.downcast_ref::<crate::codec::Error>().is_some());
    }
}
