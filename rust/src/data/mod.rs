//! Data substrate: the ShapeWorld procedural detection dataset (the
//! COCO-2014 substitute) and evaluation-set helpers.

pub mod render;
mod shapeworld;

pub use shapeworld::{
    generate, image_seed, GtBox, Sample, CLASS_NAMES, IMG, NUM_CLASSES,
};

use crate::util::pool::parallel_map;

/// Generate `count` consecutive samples in parallel (deterministic:
/// ShapeWorld is random-access by image index).
pub fn generate_batch(dataset_seed: u64, start: usize, count: usize) -> Vec<Sample> {
    parallel_map(count, 8, |i| generate(dataset_seed, start + i))
}

/// The canonical held-out evaluation split used by every experiment.
/// (Training uses dataset_seed 0xD5EA5ED; calibration 0xCA11B / 0x5EED —
/// all distinct, mirroring the paper's train/val separation.)
pub const EVAL_SEED: u64 = 0xE7A1;

pub fn eval_set(count: usize) -> Vec<Sample> {
    generate_batch(EVAL_SEED, 0, count)
}
