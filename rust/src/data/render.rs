//! Debug rendering: write ShapeWorld images (with boxes) as binary PPM.
//!
//! Pure diagnostics — lets a human eyeball what the detector sees and
//! what it predicts (`baf render`), with detections drawn over ground
//! truth. PPM (P6) needs no image library.

use crate::eval::Box2D;
use crate::tensor::Tensor;
use anyhow::Result;
use std::io::Write;
use std::path::Path;

/// Convert an (H, W, 3) f32 [0,1] tensor to 8-bit RGB.
fn to_rgb8(img: &Tensor) -> (usize, usize, Vec<u8>) {
    let s = img.shape();
    assert_eq!(s.len(), 3);
    assert_eq!(s[2], 3);
    let (h, w) = (s[0], s[1]);
    let data = img
        .data()
        .iter()
        .map(|&v| (v.clamp(0.0, 1.0) * 255.0).round() as u8)
        .collect();
    (h, w, data)
}

fn draw_rect(buf: &mut [u8], w: usize, h: usize, bx: &Box2D, color: [u8; 3]) {
    let x0 = bx.x0.max(0.0) as usize;
    let y0 = bx.y0.max(0.0) as usize;
    let x1 = (bx.x1.min(w as f32 - 1.0)) as usize;
    let y1 = (bx.y1.min(h as f32 - 1.0)) as usize;
    let mut put = |x: usize, y: usize| {
        if x < w && y < h {
            let off = (y * w + x) * 3;
            buf[off..off + 3].copy_from_slice(&color);
        }
    };
    for x in x0..=x1 {
        put(x, y0);
        put(x, y1);
    }
    for y in y0..=y1 {
        put(x0, y);
        put(x1, y);
    }
}

/// Write image + ground truth (white) + detections (per-class colors).
pub fn write_ppm(
    path: &Path,
    img: &Tensor,
    ground_truth: &[Box2D],
    detections: &[Box2D],
) -> Result<()> {
    const CLASS_COLORS: [[u8; 3]; 4] =
        [[255, 64, 64], [64, 255, 64], [64, 64, 255], [255, 255, 64]];
    let (h, w, mut rgb) = to_rgb8(img);
    for g in ground_truth {
        draw_rect(&mut rgb, w, h, g, [255, 255, 255]);
    }
    for d in detections {
        draw_rect(&mut rgb, w, h, d, CLASS_COLORS[d.class % 4]);
    }
    let mut f = std::fs::File::create(path)?;
    write!(f, "P6\n{w} {h}\n255\n")?;
    f.write_all(&rgb)?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ppm_has_correct_size_and_header() {
        let dir = std::env::temp_dir().join("baf_render_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("t.ppm");
        let img = Tensor::zeros(&[8, 16, 3]);
        let gt = Box2D { x0: 1.0, y0: 1.0, x1: 5.0, y1: 5.0, score: 1.0, class: 0 };
        write_ppm(&path, &img, &[gt], &[]).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        assert!(bytes.starts_with(b"P6\n16 8\n255\n"));
        assert_eq!(bytes.len(), 12 + 16 * 8 * 3);
        // the GT outline is white
        let header = 12;
        let px = |x: usize, y: usize| {
            let off = header + (y * 16 + x) * 3;
            [bytes[off], bytes[off + 1], bytes[off + 2]]
        };
        assert_eq!(px(1, 1), [255, 255, 255]);
        assert_eq!(px(7, 7), [0, 0, 0]);
    }
}
