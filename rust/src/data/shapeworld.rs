//! ShapeWorld generator — Rust twin of `python/compile/dataset.py`.
//!
//! The Python module is the normative specification (see its docstring
//! for the full draw layout); this implementation must match it
//! bit-for-bit, which `tests/golden.rs` verifies against
//! `artifacts/golden/dataset*.{json,npy}`.

use crate::tensor::Tensor;
use crate::util::prng::{mix, GAMMA};

pub const IMG: usize = 64;
pub const CHANNELS: usize = 3;
pub const NUM_CLASSES: usize = 4;
pub const CLASS_NAMES: [&str; 4] = ["circle", "square", "triangle", "cross"];
const NOISE_BASE: u64 = 39;

/// Ground-truth box: pixel coordinates, x1/y1 exclusive.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct GtBox {
    pub x0: f32,
    pub y0: f32,
    pub x1: f32,
    pub y1: f32,
    pub class: usize,
}

/// One generated image + ground truth.
#[derive(Debug, Clone, Default)]
pub struct Sample {
    /// (64, 64, 3) HWC f32 in [0, 1].
    pub image: Tensor,
    pub boxes: Vec<GtBox>,
}

/// Per-image stream seed (random access by index).
#[inline]
pub fn image_seed(dataset_seed: u64, index: usize) -> u64 {
    dataset_seed ^ (index as u64).wrapping_mul(GAMMA)
}

/// Draw `j` (0-indexed) of the stream with seed `s` (counter-based form).
#[inline]
fn draw(s: u64, j: u64) -> u64 {
    mix(s.wrapping_add((j + 1).wrapping_mul(GAMMA)))
}

#[inline]
fn draw_f32(s: u64, j: u64) -> f32 {
    (draw(s, j) >> 40) as f32 * (1.0 / (1u32 << 24) as f32)
}

#[inline]
fn draw_range(s: u64, j: u64, lo: i64, hi: i64) -> i64 {
    lo + (draw(s, j) % (hi - lo) as u64) as i64
}

/// Generate image `index` of the dataset with `dataset_seed`.
pub fn generate(dataset_seed: u64, index: usize) -> Sample {
    let s = image_seed(dataset_seed, index);

    // Background colors and shape count (draws 0..6).
    let mut c0 = [0f32; 3];
    let mut c1 = [0f32; 3];
    for ch in 0..3 {
        c0[ch] = 0.10f32 + 0.55f32 * draw_f32(s, ch as u64);
        c1[ch] = 0.10f32 + 0.55f32 * draw_f32(s, 3 + ch as u64);
    }
    let nshapes = draw_range(s, 6, 1, 5) as usize;

    // Background gradient: bg[y][x][c] = c0 + (c1-c0) * (x+y)/126.
    let mut img = vec![0f32; IMG * IMG * CHANNELS];
    for y in 0..IMG {
        for x in 0..IMG {
            let t = (x + y) as f32 * (1.0 / 126.0f32);
            for ch in 0..3 {
                img[(y * IMG + x) * 3 + ch] = c0[ch] + (c1[ch] - c0[ch]) * t;
            }
        }
    }

    // Shapes (draws 7 + k*8 ..).
    let mut boxes = Vec::with_capacity(nshapes);
    for k in 0..nshapes {
        let base = 7 + (k as u64) * 8;
        let class = draw_range(s, base, 0, 4) as usize;
        let size = draw_range(s, base + 1, 10, 29);
        let half = size / 2;
        let cx = draw_range(s, base + 2, half + 1, IMG as i64 - half);
        let cy = draw_range(s, base + 3, half + 1, IMG as i64 - half);
        let mut color = [0f32; 3];
        for ch in 0..3 {
            color[ch] = 0.25f32 + 0.75f32 * draw_f32(s, base + 4 + ch as u64);
        }
        // slot base+7 is reserved (layout parity with Python).

        for y in 0..IMG as i64 {
            for x in 0..IMG as i64 {
                let dx = x - cx;
                let dyc = y - cy;
                let inside = match class {
                    0 => dx * dx + dyc * dyc <= half * half,
                    1 => dx.abs() <= half && dyc.abs() <= half,
                    2 => {
                        let dy = y - (cy - half);
                        dy >= 0 && dy <= 2 * half && dx.abs() <= dy.div_euclid(2)
                    }
                    _ => {
                        let t = (half / 3).max(1);
                        (dx.abs() <= t && dyc.abs() <= half)
                            || (dyc.abs() <= t && dx.abs() <= half)
                    }
                };
                if inside {
                    let off = ((y as usize) * IMG + x as usize) * 3;
                    img[off..off + 3].copy_from_slice(&color);
                }
            }
        }
        boxes.push(GtBox {
            x0: (cx - half) as f32,
            y0: (cy - half) as f32,
            x1: (cx + half + 1) as f32,
            y1: (cy + half + 1) as f32,
            class,
        });
    }

    // Noise (draws 39.., row-major y,x,c) + clip.
    for (j, v) in img.iter_mut().enumerate() {
        let f = draw_f32(s, NOISE_BASE + j as u64);
        *v = (*v + (f - 0.5f32) * 0.04f32).clamp(0.0, 1.0);
    }

    Sample { image: Tensor::from_vec(&[IMG, IMG, CHANNELS], img), boxes }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_and_index_sensitive() {
        let a = generate(7, 0);
        let b = generate(7, 0);
        let c = generate(7, 1);
        assert_eq!(a.image, b.image);
        assert_ne!(a.image, c.image);
        assert_eq!(a.boxes, b.boxes);
    }

    #[test]
    fn pixel_range_and_shape() {
        let s = generate(123, 5);
        assert_eq!(s.image.shape(), &[IMG, IMG, CHANNELS]);
        assert!(s.image.data().iter().all(|&v| (0.0..=1.0).contains(&v)));
    }

    #[test]
    fn boxes_within_frame_and_classes_valid() {
        for i in 0..20 {
            let s = generate(99, i);
            assert!(!s.boxes.is_empty() && s.boxes.len() <= 4);
            for b in &s.boxes {
                assert!(b.x0 >= 0.0 && b.y0 >= 0.0);
                assert!(b.x1 <= IMG as f32 && b.y1 <= IMG as f32);
                assert!(b.x1 > b.x0 && b.y1 > b.y0);
                assert!(b.class < NUM_CLASSES);
            }
        }
    }

    #[test]
    fn shapes_are_painted() {
        // The first box's center pixel must not equal the pure background
        // unless a later shape overdrew it — just check that *some* pixels
        // changed vs a no-shape render (statistically certain).
        let s = generate(5, 3);
        let b = &s.boxes[s.boxes.len() - 1]; // last shape is never overdrawn
        let cx = ((b.x0 + b.x1) / 2.0) as usize;
        let cy = ((b.y0 + b.y1) / 2.0) as usize;
        let px = s.image.at3(cy, cx, 0);
        assert!(px > 0.2, "center pixel should carry shape color, got {px}");
    }
}
