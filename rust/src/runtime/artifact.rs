//! Artifact manifest: what `python -m compile.aot` exported.

use crate::json::{self, Value};
use anyhow::{anyhow, Context, Result};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

/// One exported HLO computation.
#[derive(Debug, Clone)]
pub struct ArtifactSpec {
    pub name: String,
    pub file: PathBuf,
    /// Input shapes in declaration order.
    pub inputs: Vec<Vec<usize>>,
    /// Output shape.
    pub output: Vec<usize>,
    /// Stage kind: frontend | tail | monolith | baf | fused.
    pub stage: String,
    /// Number of transmitted channels (baf/fused only).
    pub c: Option<usize>,
    /// Quantizer depth the model was trained for (baf/fused only).
    pub n: Option<u8>,
    pub batch: usize,
    /// Static channel selection baked into the graph (baf/fused only).
    pub sel: Option<Vec<usize>>,
}

/// The full artifact manifest plus model geometry.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub image_size: usize,
    pub grid: usize,
    pub cell: usize,
    pub anchors: Vec<(f32, f32)>,
    pub num_classes: usize,
    pub head_channels: usize,
    pub p_channels: usize,
    pub q_channels: usize,
    pub z_shape: (usize, usize, usize),
    pub leaky_slope: f32,
    pub artifacts: BTreeMap<String, ArtifactSpec>,
}

impl Manifest {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: &Path) -> Result<Self> {
        let v = json::from_file(&dir.join("manifest.json"))
            .context("loading artifact manifest (run `make artifacts` first?)")?;
        let usize_of = |key: &str| -> Result<usize> {
            v.req(key)?.as_usize().ok_or_else(|| anyhow!("bad {key}"))
        };
        let anchors = v
            .req("anchors")?
            .as_arr()
            .ok_or_else(|| anyhow!("bad anchors"))?
            .iter()
            .map(|a| {
                let p = a.as_f64_vec().ok_or_else(|| anyhow!("bad anchor"))?;
                Ok((p[0] as f32, p[1] as f32))
            })
            .collect::<Result<Vec<_>>>()?;
        let z = v
            .req("z_shape")?
            .as_usize_vec()
            .ok_or_else(|| anyhow!("bad z_shape"))?;
        let mut artifacts = BTreeMap::new();
        for (name, spec) in
            v.req("artifacts")?.as_obj().ok_or_else(|| anyhow!("bad artifacts"))?
        {
            artifacts.insert(name.clone(), parse_spec(dir, name, spec)?);
        }
        Ok(Manifest {
            dir: dir.to_path_buf(),
            image_size: usize_of("image_size")?,
            grid: usize_of("grid")?,
            cell: usize_of("cell")?,
            anchors,
            num_classes: usize_of("num_classes")?,
            head_channels: usize_of("head_channels")?,
            p_channels: usize_of("p_channels")?,
            q_channels: usize_of("q_channels")?,
            z_shape: (z[0], z[1], z[2]),
            leaky_slope: v.req("leaky_slope")?.as_f64().unwrap_or(0.1) as f32,
            artifacts,
        })
    }

    pub fn spec(&self, name: &str) -> Result<&ArtifactSpec> {
        self.artifacts
            .get(name)
            .ok_or_else(|| anyhow!("artifact '{name}' not in manifest"))
    }

    /// The BaF artifact name for a (C, n, batch) triple.
    pub fn baf_name(c: usize, n: u8, batch: usize) -> String {
        format!("baf_c{c}_n{n}_b{batch}")
    }

    /// All (C, n) pairs with an exported batch-1 BaF model.
    pub fn baf_variants(&self) -> Vec<(usize, u8)> {
        let mut out: Vec<(usize, u8)> = self
            .artifacts
            .values()
            .filter(|s| s.stage == "baf" && s.batch == 1)
            .filter_map(|s| Some((s.c?, s.n?)))
            .collect();
        out.sort_unstable();
        out
    }
}

fn parse_spec(dir: &Path, name: &str, v: &Value) -> Result<ArtifactSpec> {
    let file = v.req("file")?.as_str().ok_or_else(|| anyhow!("bad file"))?;
    let inputs = v
        .req("inputs")?
        .as_arr()
        .ok_or_else(|| anyhow!("bad inputs"))?
        .iter()
        .map(|s| s.as_usize_vec().ok_or_else(|| anyhow!("bad input shape")))
        .collect::<Result<Vec<_>>>()?;
    Ok(ArtifactSpec {
        name: name.to_string(),
        file: dir.join(file),
        inputs,
        output: v
            .req("output")?
            .as_usize_vec()
            .ok_or_else(|| anyhow!("bad output shape"))?,
        stage: v.req("stage")?.as_str().unwrap_or("").to_string(),
        c: v.get("c").and_then(Value::as_usize),
        n: v.get("n").and_then(Value::as_i64).map(|x| x as u8),
        batch: v.get("batch").and_then(Value::as_usize).unwrap_or(1),
        sel: v.get("sel").and_then(Value::as_usize_vec),
    })
}
