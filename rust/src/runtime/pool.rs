//! Scoped worker pool for intra-frame parallelism (the striped codec).
//!
//! Deliberately tiny: no queues, no long-lived threads, no dependencies —
//! just `std::thread::scope` fan-out over a slice of jobs. That matches
//! the workload exactly: a frame arrives, its K stripes are known up
//! front, each stripe is coded independently, and the frame is done when
//! the scope joins. Spawning a scoped thread is cheap relative to the
//! entropy-coding work of a stripe (tens of microseconds vs milliseconds
//! for realistic tensors), so a persistent pool would buy nothing while
//! costing shutdown and lifetime complexity.
//!
//! The pool carries a no-panic contract like the decode path it serves
//! (the inner deny below overrides the crate-level allow on `runtime`):
//! jobs communicate failure by writing a `Result` into their own job
//! struct, never by panicking across the scope boundary.

#![deny(clippy::unwrap_used, clippy::expect_used)]

/// A scoped fan-out executor with a fixed degree of parallelism.
#[derive(Debug, Clone, Copy)]
pub struct WorkerPool {
    threads: usize,
}

impl WorkerPool {
    /// A pool that runs jobs on up to `threads` concurrent scoped
    /// threads. `threads == 1` means run inline on the caller's thread
    /// (zero spawn overhead), which is also the fallback for `0`.
    pub fn new(threads: usize) -> Self {
        WorkerPool { threads: threads.max(1) }
    }

    /// Sized to the machine: one thread per available core.
    pub fn with_default_parallelism() -> Self {
        let threads = std::thread::available_parallelism()
            .map(std::num::NonZeroUsize::get)
            .unwrap_or(1);
        Self::new(threads)
    }

    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Run `f(index, &mut item)` for every item, fanning the slice out
    /// across up to `threads` scoped threads. Items never move: each
    /// thread owns a disjoint `chunks_mut` slice, so `T` needs `Send`
    /// but not `Sync`, and results are written in place.
    ///
    /// With one thread (or one item) this runs inline with no spawn.
    pub fn for_each_mut<T, F>(&self, items: &mut [T], f: F)
    where
        T: Send,
        F: Fn(usize, &mut T) + Sync,
    {
        let n = items.len();
        if self.threads <= 1 || n <= 1 {
            for (i, item) in items.iter_mut().enumerate() {
                f(i, item);
            }
            return;
        }
        let chunk = n.div_ceil(self.threads.min(n));
        std::thread::scope(|scope| {
            for (c, items) in items.chunks_mut(chunk).enumerate() {
                let f = &f;
                scope.spawn(move || {
                    for (i, item) in items.iter_mut().enumerate() {
                        f(c * chunk + i, item);
                    }
                });
            }
        });
    }

    /// Produce `n` values by running `f(index)` across the pool.
    pub fn map<T, F>(&self, n: usize, f: F) -> Vec<T>
    where
        T: Send,
        F: Fn(usize) -> T + Sync,
    {
        let mut slots: Vec<Option<T>> = (0..n).map(|_| None).collect();
        self.for_each_mut(&mut slots, |i, slot| *slot = Some(f(i)));
        slots.into_iter().flatten().collect()
    }
}

#[cfg(test)]
mod tests {
    #![allow(clippy::unwrap_used, clippy::expect_used)]

    use super::*;

    #[test]
    fn for_each_visits_every_item_once_with_its_index() {
        for threads in [1usize, 2, 3, 8, 64] {
            let pool = WorkerPool::new(threads);
            let mut items: Vec<(usize, usize)> = (0..17).map(|i| (i, 0)).collect();
            pool.for_each_mut(&mut items, |i, item| {
                assert_eq!(i, item.0, "index must match slot");
                item.1 += 1;
            });
            assert!(items.iter().all(|&(_, hits)| hits == 1), "threads={threads}");
        }
    }

    #[test]
    fn map_preserves_order() {
        let pool = WorkerPool::new(4);
        let out = pool.map(23, |i| i * i);
        assert_eq!(out, (0..23).map(|i| i * i).collect::<Vec<_>>());
    }

    #[test]
    fn zero_threads_and_empty_input_are_fine() {
        let pool = WorkerPool::new(0);
        assert_eq!(pool.threads(), 1);
        let mut empty: Vec<u8> = Vec::new();
        pool.for_each_mut(&mut empty, |_, _| {});
        assert!(pool.map(0, |i| i).is_empty());
    }

    #[test]
    fn more_threads_than_items_still_covers_all() {
        let pool = WorkerPool::new(16);
        let mut items = vec![0u32; 3];
        pool.for_each_mut(&mut items, |i, item| *item = i as u32 + 1);
        assert_eq!(items, vec![1, 2, 3]);
    }

    #[test]
    fn default_parallelism_is_at_least_one() {
        assert!(WorkerPool::with_default_parallelism().threads() >= 1);
    }
}
