//! The PJRT execution engine: load HLO text -> compile once -> execute.
//!
//! Pattern follows /opt/xla-example/load_hlo.rs: HLO *text* is the
//! interchange format (the bundled xla_extension 0.5.1 rejects jax>=0.5's
//! 64-bit-id serialized protos; the text parser reassigns ids).
//!
//! Threading: the `xla` crate wrappers hold raw pointers and are !Send,
//! so an `Engine` is thread-confined by construction. The coordinator
//! gives each stage thread its own `Engine` (edge / cloud-infer), which
//! also models the deployment reality of one accelerator context per
//! process. Executables are compiled lazily and cached by artifact name.

use super::artifact::{ArtifactSpec, Manifest};
use crate::tensor::Tensor;
use anyhow::{anyhow, bail, Context, Result};
use std::cell::RefCell;
use std::collections::HashMap;
use std::path::Path;
use std::rc::Rc;
use std::time::Instant;

/// A compiled artifact ready to execute.
pub struct Executable {
    pub spec: ArtifactSpec,
    exe: xla::PjRtLoadedExecutable,
}

impl Executable {
    /// Execute with f32 tensors; shapes must match the spec exactly.
    pub fn run(&self, inputs: &[&Tensor]) -> Result<Tensor> {
        if inputs.len() != self.spec.inputs.len() {
            bail!(
                "{}: expected {} inputs, got {}",
                self.spec.name,
                self.spec.inputs.len(),
                inputs.len()
            );
        }
        let mut literals = Vec::with_capacity(inputs.len());
        for (t, want) in inputs.iter().zip(&self.spec.inputs) {
            if t.shape() != want.as_slice() {
                bail!(
                    "{}: input shape {:?} != expected {:?}",
                    self.spec.name,
                    t.shape(),
                    want
                );
            }
            // §Perf: single-copy literal creation (vec1 + reshape would
            // materialize the buffer twice per input)
            // SAFETY: the slice reinterprets t.data()'s f32 buffer as
            // bytes: same allocation, len scaled by size_of::<f32>, and
            // f32 has no padding or invalid bit patterns as u8. The
            // borrow of `t` outlives `bytes` (consumed by create_* in
            // this iteration).
            let bytes = unsafe {
                std::slice::from_raw_parts(
                    t.data().as_ptr() as *const u8,
                    t.data().len() * std::mem::size_of::<f32>(),
                )
            };
            literals.push(xla::Literal::create_from_shape_and_untyped_data(
                xla::ElementType::F32,
                want,
                bytes,
            )?);
        }
        let result = self.exe.execute::<xla::Literal>(&literals)?[0][0]
            .to_literal_sync()?;
        // aot.py lowers with return_tuple=True -> unwrap the 1-tuple.
        let out = result.to_tuple1()?;
        let values = out.to_vec::<f32>()?;
        Ok(Tensor::from_vec(&self.spec.output, values))
    }
}

/// A PJRT CPU client plus a lazy cache of compiled artifacts.
pub struct Engine {
    client: xla::PjRtClient,
    manifest: Manifest,
    cache: RefCell<HashMap<String, Rc<Executable>>>,
}

impl Engine {
    /// Create a CPU engine over the artifact directory.
    pub fn new(artifact_dir: &Path) -> Result<Self> {
        let manifest = Manifest::load(artifact_dir)?;
        let client = xla::PjRtClient::cpu()?;
        log::debug!(
            "PJRT client up: platform={} devices={}",
            client.platform_name(),
            client.device_count()
        );
        Ok(Engine { client, manifest, cache: RefCell::new(HashMap::new()) })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Compile (or fetch from cache) an artifact by manifest name.
    pub fn load(&self, name: &str) -> Result<Rc<Executable>> {
        if let Some(e) = self.cache.borrow().get(name) {
            return Ok(Rc::clone(e));
        }
        let spec = self.manifest.spec(name)?.clone();
        let t0 = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(
            spec.file
                .to_str()
                .ok_or_else(|| anyhow!("non-utf8 path {:?}", spec.file))?,
        )
        .with_context(|| format!("parsing HLO text {:?}", spec.file))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling artifact '{name}'"))?;
        log::info!(
            "compiled '{name}' in {:.1} ms",
            t0.elapsed().as_secs_f64() * 1e3
        );
        let executable = Rc::new(Executable { spec, exe });
        self.cache.borrow_mut().insert(name.to_string(), Rc::clone(&executable));
        Ok(executable)
    }

    /// Convenience: load + run in one call.
    pub fn run(&self, name: &str, inputs: &[&Tensor]) -> Result<Tensor> {
        self.load(name)?.run(inputs)
    }

    /// Number of compiled artifacts currently cached.
    pub fn compiled_count(&self) -> usize {
        self.cache.borrow().len()
    }
}
