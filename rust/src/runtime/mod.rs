//! PJRT runtime: artifact manifest + lazy-compiling execution engine.
//!
//! This is the only module that touches the `xla` crate; everything above
//! it works in plain `Tensor`s. Python never runs here — artifacts were
//! AOT-lowered at build time by `python/compile/aot.py`.

mod artifact;
mod engine;
pub mod pool;

pub use artifact::{ArtifactSpec, Manifest};
pub use engine::{Engine, Executable};

use std::path::PathBuf;

/// Default artifact directory: `$BAF_ARTIFACTS` or `./artifacts`.
pub fn default_artifact_dir() -> PathBuf {
    std::env::var_os("BAF_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from("artifacts"))
}
