//! The rule engine: function spans, test-region detection, the five
//! structural rules, and suppression-annotation handling.
//!
//! Rules (names are what `// baf-lint: allow(<rule>) -- <reason>` takes):
//!
//! * `panic-macro` — `panic!` / `unreachable!` / `todo!` /
//!   `unimplemented!` anywhere in a contract module (encoders included:
//!   their assert-style panics are sanctioned by ROADMAP but must carry
//!   a written suppression reason).
//! * `raw-index` — `x[...]` with a non-constant index inside a decode
//!   function (constant = numeric literals, SCREAMING consts, `..`
//!   ranges and arithmetic thereof).
//! * `unchecked-len-arith` — `+ - * <<` (or their assign forms)
//!   directly on a length-shaped identifier (`len`, `*_len`, `count`,
//!   `offset`, `n_*`, `.len()`) inside a decode function; use
//!   `checked_*` / `saturating_*` / `wrapping_*` method forms instead
//!   (method calls don't trip the rule — there is no bare operator).
//! * `unbounded-alloc` — `Vec::with_capacity(n)` / `vec![_; n]` /
//!   `.resize(n, _)` with a non-literal size in a decode function that
//!   never mentions a cap (`MAX_DECODED_SAMPLES`, `MAX_FRAME_LEN`,
//!   `MAX_HEADER_LEN`, or the checked helpers that enforce them).
//! * `truncating-cast` — `<length-shaped> as u8/u16/u32/i8/i16/i32`
//!   inside a decode function.
//! * `unsafe-without-safety-comment` — an `unsafe` token (block, fn, or
//!   impl) outside test code with no comment containing `SAFETY:`
//!   within the five lines above it. Tree-wide, not just contract
//!   modules.
//! * `bad-suppression` — an `allow(...)` annotation with no
//!   `-- <reason>` text; every suppression must say *why*.

use super::contract;
use super::lexer::{is_keyword, TokKind, Token};

/// One rule hit at a source line.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Finding {
    pub rule: &'static str,
    pub line: usize,
    pub msg: String,
}

/// A `fn` item's location: token indices of its body braces plus the
/// line span used for function-level suppressions.
#[derive(Debug, Clone)]
pub struct FnSpan {
    pub name: String,
    /// Line holding the `fn` keyword.
    pub fn_line: usize,
    /// Code-token index of the opening `{`.
    pub body_start: usize,
    /// Code-token index of the matching `}`.
    pub body_end: usize,
    /// Line of the matching `}`.
    pub end_line: usize,
}

fn match_delim(code: &[Token], start: usize, open: &str, close: &str) -> usize {
    let mut depth = 0usize;
    let mut x = start;
    while x < code.len() {
        let t = &code[x];
        if t.kind == TokKind::Punct && t.text == open {
            depth += 1;
        } else if t.kind == TokKind::Punct && t.text == close {
            depth = depth.saturating_sub(1);
            if depth == 0 {
                return x;
            }
        }
        x += 1;
    }
    code.len().saturating_sub(1)
}

fn match_brace(code: &[Token], start: usize) -> usize {
    match_delim(code, start, "{", "}")
}

fn match_bracket(code: &[Token], start: usize) -> usize {
    match_delim(code, start, "[", "]")
}

fn match_paren(code: &[Token], start: usize) -> usize {
    match_delim(code, start, "(", ")")
}

/// Every named `fn` with a body, in source order.
pub fn fn_spans(code: &[Token]) -> Vec<FnSpan> {
    let mut spans = Vec::new();
    for x in 0..code.len() {
        if code[x].kind != TokKind::Ident || code[x].text != "fn" {
            continue;
        }
        let name = match code.get(x + 1) {
            Some(t) if t.kind == TokKind::Ident => t.text.clone(),
            _ => continue,
        };
        // find the body `{` (or `;` for trait/extern declarations)
        let mut y = x + 1;
        let mut body = None;
        while y < code.len() {
            if code[y].kind == TokKind::Punct {
                if code[y].text == "{" {
                    body = Some(y);
                    break;
                }
                if code[y].text == ";" {
                    break;
                }
            }
            y += 1;
        }
        let Some(body) = body else { continue };
        let end = match_brace(code, body);
        spans.push(FnSpan {
            name,
            fn_line: code[x].line,
            body_start: body,
            body_end: end,
            end_line: code.get(end).map_or(code[x].line, |t| t.line),
        });
    }
    spans
}

/// The innermost function whose body contains code-token index `ci`
/// (nested fns shadow their parents).
pub fn innermost_fn<'a>(spans: &'a [FnSpan], ci: usize) -> Option<&'a FnSpan> {
    spans
        .iter()
        .filter(|s| s.body_start <= ci && ci <= s.body_end)
        .min_by_key(|s| s.body_end - s.body_start)
}

/// Code-token index ranges covered by `#[cfg(test)]` / `#[test]` items —
/// test code is exempt from every rule (it builds hostile inputs and
/// unwraps on purpose).
pub fn test_regions(code: &[Token]) -> Vec<(usize, usize)> {
    let mut regions = Vec::new();
    let mut x = 0usize;
    while x < code.len() {
        let starts_attr = code[x].kind == TokKind::Punct
            && code[x].text == "#"
            && code.get(x + 1).is_some_and(|t| t.text == "[");
        if !starts_attr {
            x += 1;
            continue;
        }
        // collect this attribute's tokens to the matching ]
        let mut depth = 0usize;
        let mut y = x + 1;
        let mut attr: Vec<&str> = Vec::new();
        while y < code.len() {
            let t = &code[y];
            if t.kind == TokKind::Punct && t.text == "[" {
                depth += 1;
            } else if t.kind == TokKind::Punct && t.text == "]" {
                depth -= 1;
                if depth == 0 {
                    break;
                }
            }
            attr.push(&t.text);
            y += 1;
        }
        let inner: Vec<&str> = attr.iter().skip(1).copied().collect();
        let is_test = (inner.contains(&"cfg") && inner.contains(&"test"))
            || inner == ["test"];
        if !is_test {
            x += 1;
            continue;
        }
        // skip any further attributes, then the item to its matching
        // brace (or `;` for braceless items)
        let mut z = y + 1;
        while z + 1 < code.len()
            && code[z].text == "#"
            && code[z + 1].text == "["
        {
            let mut d2 = 0usize;
            let mut w = z + 1;
            while w < code.len() {
                if code[w].text == "[" {
                    d2 += 1;
                } else if code[w].text == "]" {
                    d2 -= 1;
                    if d2 == 0 {
                        break;
                    }
                }
                w += 1;
            }
            z = w + 1;
        }
        let mut w = z;
        while w < code.len() {
            if code[w].kind == TokKind::Punct && code[w].text == "{" {
                w = match_brace(code, w);
                break;
            }
            if code[w].kind == TokKind::Punct && code[w].text == ";" {
                break;
            }
            w += 1;
        }
        regions.push((x, w));
        x = w + 1;
    }
    regions
}

pub fn in_test(regions: &[(usize, usize)], ci: usize) -> bool {
    regions.iter().any(|&(a, b)| a <= ci && ci <= b)
}

/// Are the tokens strictly between indices `a` and `b` a compile-time
/// constant expression (numbers, SCREAMING consts, ranges, arithmetic)?
fn index_is_const(code: &[Token], a: usize, b: usize) -> bool {
    for t in code.iter().take(b).skip(a + 1) {
        match t.kind {
            TokKind::Num => {}
            TokKind::Punct
                if matches!(
                    t.text.as_str(),
                    ".." | "..=" | "+" | "-" | "*" | "/" | "(" | ")"
                ) => {}
            TokKind::Ident if contract::is_const_ident(&t.text) => {}
            _ => return false,
        }
    }
    true
}

fn fn_has_cap(code: &[Token], f: &FnSpan) -> bool {
    code[f.body_start..=f.body_end.min(code.len().saturating_sub(1))]
        .iter()
        .any(|t| t.kind == TokKind::Ident && contract::CAP_IDENTS.contains(&t.text.as_str()))
}

/// Run every rule over one file's token stream. `contract` enables the
/// module-contract rules; the `unsafe` hygiene rule always runs.
pub fn analyze(
    toks: &[Token],
    code: &[Token],
    spans: &[FnSpan],
    tregions: &[(usize, usize)],
    contract_file: bool,
) -> Vec<Finding> {
    let mut findings = Vec::new();

    // unsafe hygiene: a SAFETY: comment must appear within 5 lines above
    // (multi-line comments count for every line they span)
    let safety_spans: Vec<(usize, usize)> = toks
        .iter()
        .filter(|t| t.kind == TokKind::Comment && t.text.contains("SAFETY:"))
        .map(|t| {
            let extra = t.text.bytes().filter(|&b| b == b'\n').count();
            (t.line, t.line + extra)
        })
        .collect();
    for (x, t) in code.iter().enumerate() {
        if t.kind == TokKind::Ident && t.text == "unsafe" && !in_test(tregions, x) {
            let lo = t.line.saturating_sub(5).max(1);
            let covered = safety_spans.iter().any(|&(a, b)| a <= t.line && b >= lo);
            if !covered {
                findings.push(Finding {
                    rule: "unsafe-without-safety-comment",
                    line: t.line,
                    msg: "`unsafe` with no // SAFETY: comment within 5 lines above"
                        .to_string(),
                });
            }
        }
    }
    if !contract_file {
        return findings;
    }

    for (x, t) in code.iter().enumerate() {
        if in_test(tregions, x) {
            continue;
        }
        let f = innermost_fn(spans, x);
        let in_decode = f.is_some_and(|s| contract::is_decode_fn(&s.name));

        // panic-macro: module-wide in contract files
        if t.kind == TokKind::Ident
            && contract::PANIC_MACROS.contains(&t.text.as_str())
            && code.get(x + 1).is_some_and(|n| n.text == "!")
        {
            findings.push(Finding {
                rule: "panic-macro",
                line: t.line,
                msg: format!("`{}!` in no-panic module", t.text),
            });
        }

        if !in_decode {
            continue;
        }

        // raw-index
        if t.kind == TokKind::Punct && t.text == "[" && x > 0 {
            let p = &code[x - 1];
            let is_recv = (p.kind == TokKind::Ident && !is_keyword(&p.text))
                || (p.kind == TokKind::Punct && (p.text == "]" || p.text == ")"));
            if is_recv {
                let b = match_bracket(code, x);
                if !index_is_const(code, x, b) {
                    findings.push(Finding {
                        rule: "raw-index",
                        line: t.line,
                        msg: "non-constant index in decode path".to_string(),
                    });
                }
            }
        }

        // unchecked-len-arith
        if t.kind == TokKind::Punct
            && matches!(
                t.text.as_str(),
                "+" | "-" | "*" | "<<" | "+=" | "-=" | "*=" | "<<="
            )
        {
            let mut hit: Option<String> = None;
            if x > 0
                && code[x - 1].kind == TokKind::Ident
                && contract::is_len_shaped(&code[x - 1].text)
            {
                hit = Some(code[x - 1].text.clone());
            } else if x >= 3
                && code[x - 1].text == ")"
                && code[x - 2].text == "("
                && code[x - 3].kind == TokKind::Ident
                && contract::is_len_shaped(&code[x - 3].text)
            {
                hit = Some(format!("{}()", code[x - 3].text));
            } else if code.get(x + 1).is_some_and(|n| {
                n.kind == TokKind::Ident && contract::is_len_shaped(&n.text)
            }) {
                hit = Some(code[x + 1].text.clone());
            }
            if let Some(name) = hit {
                findings.push(Finding {
                    rule: "unchecked-len-arith",
                    line: t.line,
                    msg: format!(
                        "`{}` on length-shaped `{name}` outside checked_*",
                        t.text
                    ),
                });
            }
        }

        // unbounded-alloc: with_capacity / vec![_; n] / resize
        if t.kind == TokKind::Ident
            && t.text == "with_capacity"
            && code.get(x + 1).is_some_and(|n| n.text == "(")
        {
            let b = match_paren(code, x + 1);
            if !index_is_const(code, x + 1, b) {
                if let Some(f) = f {
                    if !fn_has_cap(code, f) {
                        findings.push(Finding {
                            rule: "unbounded-alloc",
                            line: t.line,
                            msg: "with_capacity not dominated by a MAX_* cap".to_string(),
                        });
                    }
                }
            }
        }
        if t.kind == TokKind::Ident
            && t.text == "vec"
            && code.get(x + 1).is_some_and(|n| n.text == "!")
            && code.get(x + 2).is_some_and(|n| n.text == "[")
        {
            let b = match_bracket(code, x + 2);
            let semi = (x + 3..b).find(|&y| {
                code[y].kind == TokKind::Punct && code[y].text == ";"
            });
            if let (Some(semi), Some(f)) = (semi, f) {
                if !index_is_const(code, semi, b) && !fn_has_cap(code, f) {
                    findings.push(Finding {
                        rule: "unbounded-alloc",
                        line: t.line,
                        msg: "vec![_; n] not dominated by a MAX_* cap".to_string(),
                    });
                }
            }
        }
        if t.kind == TokKind::Ident
            && t.text == "resize"
            && code.get(x + 1).is_some_and(|n| n.text == "(")
        {
            let b = match_paren(code, x + 1);
            let comma = (x + 2..b)
                .find(|&y| code[y].kind == TokKind::Punct && code[y].text == ",")
                .unwrap_or(b);
            if !index_is_const(code, x + 1, comma) {
                if let Some(f) = f {
                    if !fn_has_cap(code, f) {
                        findings.push(Finding {
                            rule: "unbounded-alloc",
                            line: t.line,
                            msg: "resize not dominated by a MAX_* cap".to_string(),
                        });
                    }
                }
            }
        }

        // truncating-cast
        if t.kind == TokKind::Ident
            && t.text == "as"
            && code.get(x + 1).is_some_and(|n| {
                n.kind == TokKind::Ident
                    && contract::NARROW_INTS.contains(&n.text.as_str())
            })
        {
            let target = code[x + 1].text.clone();
            let mut y = x;
            let mut hops = 0usize;
            while y > 0 && hops < 5 {
                y -= 1;
                let c = &code[y];
                if c.kind == TokKind::Ident {
                    if contract::is_len_shaped(&c.text) {
                        findings.push(Finding {
                            rule: "truncating-cast",
                            line: t.line,
                            msg: format!(
                                "`{} as {target}` may truncate a length",
                                c.text
                            ),
                        });
                    }
                    break;
                }
                if c.kind == TokKind::Punct
                    && matches!(c.text.as_str(), "(" | ")" | ".")
                {
                    hops += 1;
                    continue;
                }
                break;
            }
        }
    }
    findings
}

/// A `// baf-lint: allow(<rules>) -- <reason>` annotation and the lines
/// it covers: its own line; for an own-line comment, the next code line;
/// and if that line starts a `fn`, the whole function span.
#[derive(Debug, Clone)]
pub struct Annotation {
    pub rules: Vec<String>,
    pub reason: Option<String>,
    pub line: usize,
    next_code_line: Option<usize>,
    fn_range: Option<(usize, usize)>,
}

impl Annotation {
    pub fn covers(&self, rule: &str, line: usize) -> bool {
        if !self.rules.iter().any(|r| r == rule) {
            return false;
        }
        line == self.line
            || self.next_code_line == Some(line)
            || self.fn_range.is_some_and(|(a, b)| a <= line && line <= b)
    }
}

/// Parse `baf-lint: allow(rule-a, rule-b) -- reason` out of a comment.
fn parse_allow(comment: &str) -> Option<(Vec<String>, Option<String>)> {
    let at = comment.find("baf-lint:")?;
    let rest = comment[at + "baf-lint:".len()..].trim_start();
    let rest = rest.strip_prefix("allow(")?;
    let close = rest.find(')')?;
    let inside = &rest[..close];
    let valid = !inside.is_empty()
        && inside.chars().all(|c| {
            c.is_ascii_lowercase() || c.is_ascii_digit() || c == ',' || c == '-' || c == ' '
        });
    if !valid {
        return None;
    }
    let rules: Vec<String> = inside
        .split(',')
        .map(|r| r.trim().to_string())
        .filter(|r| !r.is_empty())
        .collect();
    if rules.is_empty() {
        return None;
    }
    let after = rest[close + 1..].trim_start();
    let reason = after
        .strip_prefix("--")
        .map(|r| r.trim().to_string())
        .filter(|r| !r.is_empty());
    Some((rules, reason))
}

/// Every annotation in a file, with coverage resolved against the code
/// lines and function spans.
pub fn collect_annotations(
    toks: &[Token],
    code: &[Token],
    spans: &[FnSpan],
) -> Vec<Annotation> {
    let mut code_lines: Vec<usize> = code.iter().map(|t| t.line).collect();
    code_lines.sort_unstable();
    code_lines.dedup();
    let mut anns = Vec::new();
    for t in toks {
        if t.kind != TokKind::Comment {
            continue;
        }
        let Some((rules, reason)) = parse_allow(&t.text) else { continue };
        let own_line = code_lines.binary_search(&t.line).is_err();
        let next_code_line = if own_line {
            code_lines
                .iter()
                .copied()
                .find(|&l| l > t.line)
        } else {
            None
        };
        let fn_range = next_code_line.and_then(|nxt| {
            spans
                .iter()
                .find(|s| s.fn_line == nxt)
                .map(|s| (s.fn_line, s.end_line))
        });
        anns.push(Annotation { rules, reason, line: t.line, next_code_line, fn_range });
    }
    anns
}

#[cfg(test)]
mod tests {
    #![allow(clippy::unwrap_used, clippy::expect_used)]

    use super::*;
    use crate::lint::lexer::{code_toks, lex};

    fn run(src: &str) -> Vec<Finding> {
        let toks = lex(src);
        let code = code_toks(&toks);
        let spans = fn_spans(&code);
        let tregions = test_regions(&code);
        analyze(&toks, &code, &spans, &tregions, true)
    }

    fn rules_of(src: &str) -> Vec<&'static str> {
        run(src).into_iter().map(|f| f.rule).collect()
    }

    #[test]
    fn fn_spans_and_nesting() {
        let code = code_toks(&lex(
            "fn outer() { let x = 1; fn inner_decode() { x[i]; } }",
        ));
        let spans = fn_spans(&code);
        assert_eq!(spans.len(), 2);
        assert_eq!(spans[0].name, "outer");
        assert_eq!(spans[1].name, "inner_decode");
        // a token inside inner resolves to inner
        let xi = code
            .iter()
            .position(|t| t.text == "[")
            .unwrap();
        assert_eq!(innermost_fn(&spans, xi).unwrap().name, "inner_decode");
    }

    #[test]
    fn decode_scoping_gates_the_structural_rules() {
        // same body: flagged in a decode fn, ignored in an encode fn
        assert_eq!(rules_of("fn decode(i: usize) { x[i]; }"), vec!["raw-index"]);
        assert!(rules_of("fn encode(i: usize) { x[i]; }").is_empty());
        // constant indices pass
        assert!(rules_of("fn decode() { x[3]; y[HEADER_LEN + 4]; z[0..4]; }").is_empty());
    }

    #[test]
    fn len_arithmetic_and_casts() {
        assert_eq!(
            rules_of("fn parse(payload_len: usize) { let x = payload_len + 1; }"),
            vec!["unchecked-len-arith"]
        );
        assert_eq!(
            rules_of("fn parse(v: &[u8]) { let x = v.len() * 2; }"),
            vec!["unchecked-len-arith"]
        );
        assert!(rules_of(
            "fn parse(payload_len: usize) { let x = payload_len.checked_add(1); }"
        )
        .is_empty());
        assert_eq!(
            rules_of("fn parse(frame_len: usize) { let x = frame_len as u32; }"),
            vec!["truncating-cast"]
        );
        assert!(rules_of("fn parse(frame_len: usize) { let x = frame_len as u64; }")
            .is_empty());
    }

    #[test]
    fn alloc_rule_respects_caps() {
        assert_eq!(
            rules_of("fn parse(n2: usize) { let v = Vec::with_capacity(n2); }"),
            vec!["unbounded-alloc"]
        );
        assert!(rules_of(
            "fn parse(n2: usize) { if n2 > MAX_FRAME_LEN { return; } \
             let v = Vec::with_capacity(n2); }"
        )
        .is_empty());
        assert_eq!(rules_of("fn parse(n2: usize) { let v = vec![0u8; n2]; }"),
            vec!["unbounded-alloc"]);
        assert!(rules_of("fn parse() { let v = vec![0u8; 16]; }").is_empty());
        assert_eq!(
            rules_of("fn parse(n2: usize, v: &mut Vec<u8>) { v.resize(n2, 0); }"),
            vec!["unbounded-alloc"]
        );
    }

    #[test]
    fn panic_rule_is_module_wide_and_tests_are_exempt() {
        assert_eq!(rules_of("fn encode() { panic!(\"boom\"); }"), vec!["panic-macro"]);
        assert!(rules_of(
            "#[cfg(test)] mod tests { fn any() { panic!(\"ok in tests\"); x[i]; } }"
        )
        .is_empty());
    }

    #[test]
    fn unsafe_rule_wants_safety_comments() {
        let toks = lex("fn f() { unsafe { w(); } }");
        let code = code_toks(&toks);
        let f = analyze(&toks, &code, &fn_spans(&code), &[], false);
        assert_eq!(f.len(), 1);
        assert_eq!(f[0].rule, "unsafe-without-safety-comment");
        let toks = lex("// SAFETY: w is fine\nfn f() { unsafe { w(); } }");
        let code = code_toks(&toks);
        assert!(analyze(&toks, &code, &fn_spans(&code), &[], false).is_empty());
        // more than 5 lines away no longer counts
        let toks = lex("// SAFETY: too far\n\n\n\n\n\n\nfn f() { unsafe { w(); } }");
        let code = code_toks(&toks);
        assert_eq!(analyze(&toks, &code, &fn_spans(&code), &[], false).len(), 1);
    }

    #[test]
    fn annotations_cover_line_next_line_and_fn() {
        let src = "\
// baf-lint: allow(raw-index) -- bounded by construction
fn decode(i: usize) {
    x[i];
}
fn parse(i: usize) { y[i]; } // baf-lint: allow(raw-index) -- same line
fn validate(i: usize) { z[i]; }
";
        let toks = lex(src);
        let code = code_toks(&toks);
        let spans = fn_spans(&code);
        let anns = collect_annotations(&toks, &code, &spans);
        assert_eq!(anns.len(), 2);
        // fn-level: covers the whole decode body
        assert!(anns[0].covers("raw-index", 3));
        assert!(!anns[0].covers("raw-index", 6));
        assert!(!anns[0].covers("panic-macro", 3));
        // same-line
        assert!(anns[1].covers("raw-index", 5));
        assert!(anns[1].reason.is_some());
    }

    #[test]
    fn allow_without_reason_is_parsed_but_reasonless() {
        let (rules, reason) =
            parse_allow("// baf-lint: allow(panic-macro, raw-index)").unwrap();
        assert_eq!(rules, vec!["panic-macro", "raw-index"]);
        assert!(reason.is_none());
        let (_, reason) =
            parse_allow("// baf-lint: allow(raw-index) -- why not").unwrap();
        assert_eq!(reason.as_deref(), Some("why not"));
        assert!(parse_allow("// just a comment").is_none());
    }
}
