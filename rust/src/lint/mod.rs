//! `baf-lint`: a dependency-free static analysis gate for the decode
//! path's no-panic and bounded-allocation contracts.
//!
//! The repo's robustness story (ROADMAP "Error handling & robustness")
//! promises that hostile bytes entering through `codec`, `net`,
//! `coordinator`, `metrics`, or `runtime::pool` produce typed errors,
//! never panics or unbounded allocations. Clippy's `unwrap_used` /
//! `expect_used` denies (see `lib.rs`) cover only two panic vectors;
//! this module lexes the tree itself and enforces the rest at the
//! source level: panic macros, raw indexing, unchecked length
//! arithmetic, uncapped allocations, truncating casts in decode
//! functions, and `// SAFETY:` hygiene on every `unsafe` block.
//!
//! A finding is suppressible only by an inline annotation that names
//! the rule *and* states a reason:
//!
//! ```text
//! // baf-lint: allow(<rule>) -- <why this site is safe>
//! ```
//!
//! (Angle brackets are placeholders — a real annotation names the rule,
//! e.g. `raw-index`, and the reason is mandatory.)
//!
//! The annotation covers its own line, the next code line, and — when
//! that line starts a `fn` — the whole function. Reasonless allows are
//! themselves findings (`bad-suppression`), and the full suppression
//! inventory (with reasons and whether each fired) lands in the JSON
//! report, so review can audit every waiver in one place.
//!
//! The `baf_lint` binary (`rust/src/bin/baf_lint.rs`) walks `rust/src`,
//! prints a human report, writes `target/lint-report.json`, and exits
//! nonzero on any unsuppressed finding or ROADMAP constant drift.
//! `rust/src/lint/fixtures/` holds one known violation per rule; the
//! golden tests below fail the build if any rule stops firing.

pub mod contract;
pub mod lexer;
pub mod report;
pub mod rules;

pub use report::Report;

use report::{FileFinding, Suppression};
use std::fs;
use std::io;
use std::path::{Path, PathBuf};

/// Lint one file's source text into `report`. `rel` is the repo-relative
/// path (forward slashes) used for contract lookup and reporting.
pub fn lint_source(rel: &str, src: &str, report: &mut Report) {
    let toks = lexer::lex(src);
    let code = lexer::code_toks(&toks);
    let spans = rules::fn_spans(&code);
    let tregions = rules::test_regions(&code);
    let raw = rules::analyze(&toks, &code, &spans, &tregions, contract::is_contract(rel));
    let anns = rules::collect_annotations(&toks, &code, &spans);
    let mut used = vec![false; anns.len()];
    for f in raw {
        match anns.iter().position(|a| a.covers(f.rule, f.line)) {
            Some(i) => {
                used[i] = true;
                report.suppressed.push(FileFinding {
                    file: rel.to_string(),
                    rule: f.rule,
                    line: f.line,
                    msg: f.msg,
                    reason: anns[i].reason.clone(),
                });
            }
            None => report.findings.push(FileFinding {
                file: rel.to_string(),
                rule: f.rule,
                line: f.line,
                msg: f.msg,
                reason: None,
            }),
        }
    }
    for (i, a) in anns.iter().enumerate() {
        if a.reason.is_none() {
            report.findings.push(FileFinding {
                file: rel.to_string(),
                rule: "bad-suppression",
                line: a.line,
                msg: format!(
                    "allow({}) without `-- <reason>`: every suppression must say why",
                    a.rules.join(", ")
                ),
                reason: None,
            });
        }
        report.suppressions.push(Suppression {
            file: rel.to_string(),
            line: a.line,
            rules: a.rules.clone(),
            reason: a.reason.clone(),
            used: used[i],
        });
    }
}

fn walk(dir: &Path, out: &mut Vec<PathBuf>) -> io::Result<()> {
    let mut entries = fs::read_dir(dir)?.collect::<io::Result<Vec<_>>>()?;
    entries.sort_by_key(std::fs::DirEntry::file_name);
    for e in entries {
        let name = e.file_name().to_string_lossy().into_owned();
        if e.file_type()?.is_dir() {
            // fixture trees hold intentional violations for the golden
            // tests — they are exercised there, not in the real run
            if !name.contains("fixtures") {
                walk(&e.path(), out)?;
            }
        } else if name.ends_with(".rs") {
            out.push(e.path());
        }
    }
    Ok(())
}

/// Lint the whole tree under `<root>/rust/src` and cross-check the wire
/// and container constants against `<root>/ROADMAP.md`.
pub fn run(root: &Path) -> io::Result<Report> {
    let mut report = Report::default();
    let mut files = Vec::new();
    walk(&root.join("rust").join("src"), &mut files)?;
    report.files_scanned = files.len();
    for path in &files {
        let rel = path
            .strip_prefix(root)
            .unwrap_or(path)
            .to_string_lossy()
            .replace('\\', "/");
        let src = fs::read_to_string(path)?;
        lint_source(&rel, &src, &mut report);
    }
    let container = fs::read_to_string(root.join("rust/src/codec/container.rs"))?;
    let wire = fs::read_to_string(root.join("rust/src/net/wire.rs"))?;
    let roadmap = fs::read_to_string(root.join("ROADMAP.md"))?;
    report.drift = contract::check_drift(&container, &wire, &roadmap);
    report
        .findings
        .sort_by(|a, b| (a.file.as_str(), a.line, a.rule).cmp(&(b.file.as_str(), b.line, b.rule)));
    report
        .suppressed
        .sort_by(|a, b| (a.file.as_str(), a.line, a.rule).cmp(&(b.file.as_str(), b.line, b.rule)));
    Ok(report)
}

#[cfg(test)]
mod tests {
    #![allow(clippy::unwrap_used, clippy::expect_used)]

    use super::*;

    /// Lint a fixture under a synthetic contract-module path and return
    /// the report.
    fn lint_fixture(src: &str) -> Report {
        let mut report = Report::default();
        report.files_scanned = 1;
        lint_source("rust/src/codec/fixture.rs", src, &mut report);
        report
    }

    fn live(report: &Report) -> Vec<(&'static str, usize)> {
        report.findings.iter().map(|f| (f.rule, f.line)).collect()
    }

    fn suppressed(report: &Report) -> Vec<&'static str> {
        report.suppressed.iter().map(|f| f.rule).collect()
    }

    #[test]
    fn fixture_panic_macro() {
        let r = lint_fixture(include_str!("fixtures/panic_macro.rs"));
        assert_eq!(live(&r), vec![("panic-macro", 4)]);
        assert_eq!(suppressed(&r), vec!["panic-macro"]);
        assert!(r.suppressions.iter().all(|s| s.used && s.reason.is_some()));
    }

    #[test]
    fn fixture_raw_index() {
        let r = lint_fixture(include_str!("fixtures/raw_index.rs"));
        assert_eq!(live(&r), vec![("raw-index", 3)]);
        assert_eq!(suppressed(&r), vec!["raw-index"]);
    }

    #[test]
    fn fixture_len_arith() {
        let r = lint_fixture(include_str!("fixtures/len_arith.rs"));
        assert_eq!(live(&r), vec![("unchecked-len-arith", 3)]);
        assert_eq!(suppressed(&r), vec!["unchecked-len-arith"]);
    }

    #[test]
    fn fixture_unbounded_alloc() {
        let r = lint_fixture(include_str!("fixtures/unbounded_alloc.rs"));
        assert_eq!(live(&r), vec![("unbounded-alloc", 3)]);
        assert_eq!(suppressed(&r), vec!["unbounded-alloc"]);
    }

    #[test]
    fn fixture_truncating_cast() {
        let r = lint_fixture(include_str!("fixtures/truncating_cast.rs"));
        assert_eq!(live(&r), vec![("truncating-cast", 3)]);
        assert_eq!(suppressed(&r), vec!["truncating-cast"]);
    }

    #[test]
    fn fixture_unsafe_hygiene() {
        // the unsafe rule is tree-wide: lint under a non-contract path
        let mut r = Report::default();
        lint_source(
            "rust/src/util/fixture.rs",
            include_str!("fixtures/unsafe_hygiene.rs"),
            &mut r,
        );
        assert_eq!(live(&r), vec![("unsafe-without-safety-comment", 3)]);
        assert!(r.suppressed.is_empty());
    }

    #[test]
    fn fixture_suppression_inventory() {
        let r = lint_fixture(include_str!("fixtures/suppression.rs"));
        // the reasonless allow still silences its raw-index but is itself
        // a finding
        assert_eq!(live(&r), vec![("bad-suppression", 2)]);
        assert_eq!(suppressed(&r), vec!["raw-index", "raw-index"]);
        assert_eq!(r.suppressions.len(), 2);
        assert!(r.suppressions.iter().all(|s| s.used));
        assert_eq!(
            r.suppressions.iter().filter(|s| s.reason.is_some()).count(),
            1
        );
    }

    #[test]
    fn every_rule_fires_across_the_fixture_set() {
        // the build-breaking backstop: if a rule stops firing on its
        // fixture, this test names it
        let mut r = Report::default();
        for src in [
            include_str!("fixtures/panic_macro.rs"),
            include_str!("fixtures/raw_index.rs"),
            include_str!("fixtures/len_arith.rs"),
            include_str!("fixtures/unbounded_alloc.rs"),
            include_str!("fixtures/truncating_cast.rs"),
            include_str!("fixtures/unsafe_hygiene.rs"),
            include_str!("fixtures/suppression.rs"),
        ] {
            lint_source("rust/src/codec/fixture.rs", src, &mut r);
        }
        let counts = r.rule_counts();
        for rule in report::RULE_NAMES {
            if rule == "roadmap-drift" {
                continue; // exercised by contract::tests::drift_check_*
            }
            let (found, suppressed) = counts[rule];
            assert!(found + suppressed > 0, "rule `{rule}` no longer fires");
        }
    }

    #[test]
    fn fixture_report_round_trips_through_json() {
        let r = lint_fixture(include_str!("fixtures/suppression.rs"));
        let v = r.to_value();
        let back = crate::json::parse(&v.pretty(1)).unwrap();
        assert_eq!(back, v);
    }

    #[test]
    fn the_real_tree_is_clean() {
        // run the full gate in-process over the repo; CARGO_MANIFEST_DIR
        // is the repo root
        let root = Path::new(env!("CARGO_MANIFEST_DIR"));
        let report = run(root).expect("lint walk failed");
        assert!(report.files_scanned > 40, "walk found too few files");
        let msgs: Vec<String> = report
            .findings
            .iter()
            .map(|f| format!("{}:{} {} {}", f.file, f.line, f.rule, f.msg))
            .collect();
        assert!(report.findings.is_empty(), "unsuppressed findings: {msgs:#?}");
        assert!(report.drift.iter().all(|d| d.ok), "{:#?}", report.drift);
        assert!(
            report.suppressions.iter().all(|s| s.reason.is_some()),
            "reasonless suppression in tree"
        );
    }
}
