//! A small Rust lexer — just enough token structure for the lint rules.
//!
//! This is deliberately not a parser: the rules in [`super::rules`] work
//! on token patterns (an ident followed by `[`, an operator next to a
//! length-shaped name, ...), so all we need is a faithful token stream
//! with line numbers: identifiers, numbers, strings (incl. raw and byte
//! strings), char literals vs lifetimes, nested block comments, and the
//! multi-character punctuation Rust actually has. Everything is ASCII
//! driven; non-ASCII bytes only occur inside comments and strings in
//! this tree, where they are consumed opaquely.

/// Token classes the rules distinguish.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TokKind {
    Ident,
    Num,
    Str,
    Char,
    Lifetime,
    Comment,
    Punct,
}

/// One lexed token with its 1-based starting line (block comments and
/// raw strings spanning lines record the line their text *starts* on,
/// except multi-line raw strings which record their end line — the
/// rules only ever use lines of code tokens and line comments, where
/// start == end).
#[derive(Debug, Clone)]
pub struct Token {
    pub kind: TokKind,
    pub text: String,
    pub line: usize,
}

/// Multi-character punctuation, longest-match-first.
const MULTI_PUNCT: [&str; 23] = [
    "<<=", ">>=", "..=", "...", "::", "->", "=>", "==", "!=", "<=", ">=",
    "&&", "||", "+=", "-=", "*=", "/=", "%=", "^=", "|=", "&=", "<<", ">>",
    "..",
];

/// Reserved words that can precede `[` without being an indexable value
/// (so `match x { ... }` style patterns don't look like indexing).
pub const KEYWORDS: [&str; 37] = [
    "as", "break", "const", "continue", "crate", "dyn", "else", "enum",
    "extern", "false", "fn", "for", "if", "impl", "in", "let", "loop",
    "match", "mod", "move", "mut", "pub", "ref", "return", "self", "Self",
    "static", "struct", "super", "trait", "true", "type", "unsafe", "use",
    "where", "while", "async",
];

pub fn is_keyword(name: &str) -> bool {
    KEYWORDS.contains(&name) || name == "await"
}

fn is_ident_start(b: u8) -> bool {
    b.is_ascii_alphabetic() || b == b'_'
}

fn is_ident_cont(b: u8) -> bool {
    b.is_ascii_alphanumeric() || b == b'_'
}

/// Lex a source file into a token stream. Total: any byte sequence
/// produces *some* stream (unknown bytes become single puncts); the
/// lexer never panics and never loses line synchronization on the
/// comment/string classes the rules care about.
pub fn lex(src: &str) -> Vec<Token> {
    let b = src.as_bytes();
    let n = b.len();
    let mut toks = Vec::new();
    let mut i = 0usize;
    let mut line = 1usize;
    while i < n {
        let c = b[i];
        if c == b'\n' {
            line += 1;
            i += 1;
            continue;
        }
        if c == b' ' || c == b'\t' || c == b'\r' {
            i += 1;
            continue;
        }
        // line comment
        if c == b'/' && i + 1 < n && b[i + 1] == b'/' {
            let mut j = i;
            while j < n && b[j] != b'\n' {
                j += 1;
            }
            toks.push(tok(TokKind::Comment, src, i, j, line));
            i = j;
            continue;
        }
        // nested block comment
        if c == b'/' && i + 1 < n && b[i + 1] == b'*' {
            let start = line;
            let mut depth = 1usize;
            let mut j = i + 2;
            while j < n && depth > 0 {
                if b[j] == b'/' && j + 1 < n && b[j + 1] == b'*' {
                    depth += 1;
                    j += 2;
                } else if b[j] == b'*' && j + 1 < n && b[j + 1] == b'/' {
                    depth -= 1;
                    j += 2;
                } else {
                    if b[j] == b'\n' {
                        line += 1;
                    }
                    j += 1;
                }
            }
            toks.push(tok(TokKind::Comment, src, i, j, start));
            i = j;
            continue;
        }
        // raw / raw-byte string: b?r#*"
        if let Some(j) = raw_string_end(b, i) {
            let text = &src[i..j];
            line += text.bytes().filter(|&x| x == b'\n').count();
            toks.push(tok(TokKind::Str, src, i, j, line));
            i = j;
            continue;
        }
        // plain or byte string
        if c == b'"' || (c == b'b' && i + 1 < n && b[i + 1] == b'"') {
            let start = line;
            let mut j = i + if c == b'b' { 2 } else { 1 };
            while j < n {
                if b[j] == b'\\' {
                    j += 2;
                    continue;
                }
                if b[j] == b'"' {
                    j += 1;
                    break;
                }
                if b[j] == b'\n' {
                    line += 1;
                }
                j += 1;
            }
            let j = j.min(n);
            toks.push(tok(TokKind::Str, src, i, j, start));
            i = j;
            continue;
        }
        // lifetime vs char literal
        if c == b'\'' {
            let next_is_name = i + 1 < n && is_ident_start(b[i + 1]);
            let closes_as_char = i + 2 < n && b[i + 2] == b'\'';
            if next_is_name && !closes_as_char {
                let mut j = i + 1;
                while j < n && is_ident_cont(b[j]) {
                    j += 1;
                }
                toks.push(tok(TokKind::Lifetime, src, i, j, line));
                i = j;
                continue;
            }
            let mut j = i + 1;
            if j < n && b[j] == b'\\' {
                j += 2;
                // \u{...}
                if j <= n && b[j - 1] == b'u' && j < n && b[j] == b'{' {
                    while j < n && b[j] != b'}' {
                        j += 1;
                    }
                    j += 1;
                }
            } else {
                j += 1;
            }
            if j < n && b[j] == b'\'' {
                j += 1;
            }
            let j = j.min(n);
            toks.push(tok(TokKind::Char, src, i, j, line));
            i = j;
            continue;
        }
        if is_ident_start(c) {
            let mut j = i;
            while j < n && is_ident_cont(b[j]) {
                j += 1;
            }
            toks.push(tok(TokKind::Ident, src, i, j, line));
            i = j;
            continue;
        }
        if c.is_ascii_digit() {
            let mut j = i;
            if c == b'0'
                && i + 1 < n
                && (b[i + 1] == b'x' || b[i + 1] == b'b' || b[i + 1] == b'o')
            {
                j = i + 2;
                while j < n && (b[j].is_ascii_hexdigit() || b[j] == b'_') {
                    j += 1;
                }
            } else {
                while j < n && (b[j].is_ascii_digit() || b[j] == b'_') {
                    j += 1;
                }
                // fraction — but never eat the dots of a range like 0..k
                if j < n && b[j] == b'.' && j + 1 < n && b[j + 1].is_ascii_digit() {
                    j += 1;
                    while j < n && (b[j].is_ascii_digit() || b[j] == b'_') {
                        j += 1;
                    }
                }
                // exponent
                if j < n
                    && (b[j] == b'e' || b[j] == b'E')
                    && ((j + 1 < n && b[j + 1].is_ascii_digit())
                        || (j + 2 < n
                            && (b[j + 1] == b'+' || b[j + 1] == b'-')
                            && b[j + 2].is_ascii_digit()))
                {
                    j += 2;
                    while j < n && b[j].is_ascii_digit() {
                        j += 1;
                    }
                }
            }
            // type suffix (u32, f64, ...)
            while j < n && is_ident_cont(b[j]) {
                j += 1;
            }
            toks.push(tok(TokKind::Num, src, i, j, line));
            i = j;
            continue;
        }
        if let Some(op) = MULTI_PUNCT.iter().find(|op| src[i..].starts_with(**op)) {
            toks.push(Token { kind: TokKind::Punct, text: (*op).to_string(), line });
            i += op.len();
            continue;
        }
        // single punct (or an opaque non-ASCII byte run collapsed to one)
        let mut j = i + 1;
        while j < n && !src.is_char_boundary(j) {
            j += 1;
        }
        toks.push(tok(TokKind::Punct, src, i, j, line));
        i = j;
        continue;
    }
    toks
}

fn tok(kind: TokKind, src: &str, i: usize, j: usize, line: usize) -> Token {
    let j = j.min(src.len());
    let i = i.min(j);
    Token { kind, text: src.get(i..j).unwrap_or_default().to_string(), line }
}

/// If `b[i..]` starts a raw (byte) string `b?r#*"`, return the index one
/// past its closing quote+hashes (or end of input if unterminated).
fn raw_string_end(b: &[u8], i: usize) -> Option<usize> {
    let n = b.len();
    let mut j = i;
    if j < n && b[j] == b'b' {
        j += 1;
    }
    if j >= n || b[j] != b'r' {
        return None;
    }
    j += 1;
    let hash_start = j;
    while j < n && b[j] == b'#' {
        j += 1;
    }
    let hashes = j - hash_start;
    if j >= n || b[j] != b'"' {
        return None;
    }
    j += 1;
    // scan for closing `"` followed by the same number of hashes
    while j < n {
        if b[j] == b'"' {
            let mut h = 0usize;
            while h < hashes && j + 1 + h < n && b[j + 1 + h] == b'#' {
                h += 1;
            }
            if h == hashes {
                return Some(j + 1 + hashes);
            }
        }
        j += 1;
    }
    Some(n)
}

/// The comment-free token stream the structural rules run on.
pub fn code_toks(toks: &[Token]) -> Vec<Token> {
    toks.iter().filter(|t| t.kind != TokKind::Comment).cloned().collect()
}

#[cfg(test)]
mod tests {
    #![allow(clippy::unwrap_used, clippy::expect_used)]

    use super::*;

    fn kinds(src: &str) -> Vec<(TokKind, String)> {
        lex(src).into_iter().map(|t| (t.kind, t.text)).collect()
    }

    #[test]
    fn idents_numbers_and_suffixes() {
        let t = kinds("let x_len = 0x1F_u32 + 2.5e-3;");
        assert_eq!(t[0], (TokKind::Ident, "let".into()));
        assert_eq!(t[1], (TokKind::Ident, "x_len".into()));
        assert_eq!(t[3], (TokKind::Num, "0x1F_u32".into()));
        assert_eq!(t[5], (TokKind::Num, "2.5e-3".into()));
    }

    #[test]
    fn range_dots_are_not_fractions() {
        let t = kinds("0..k");
        assert_eq!(t[0], (TokKind::Num, "0".into()));
        assert_eq!(t[1], (TokKind::Punct, "..".into()));
        assert_eq!(t[2], (TokKind::Ident, "k".into()));
    }

    #[test]
    fn strings_comments_lifetimes_chars() {
        let t = kinds("'a 'x' b\"hi\" r#\"raw\"# // line\n/* b /* nest */ */");
        assert_eq!(t[0], (TokKind::Lifetime, "'a".into()));
        assert_eq!(t[1], (TokKind::Char, "'x'".into()));
        assert_eq!(t[2], (TokKind::Str, "b\"hi\"".into()));
        assert_eq!(t[3], (TokKind::Str, "r#\"raw\"#".into()));
        assert_eq!(t[4].0, TokKind::Comment);
        assert_eq!(t[5], (TokKind::Comment, "/* b /* nest */ */".into()));
    }

    #[test]
    fn lines_are_tracked_across_comments_and_strings() {
        let toks = lex("a\n/* x\ny */\nb \"s\ns\" c");
        let lines: Vec<(String, usize)> = toks
            .iter()
            .filter(|t| t.kind != TokKind::Comment)
            .map(|t| (t.text.clone(), t.line))
            .collect();
        assert_eq!(lines[0], ("a".to_string(), 1));
        assert_eq!(lines[1], ("b".to_string(), 4));
        // the string starts on line 4; `c` lands on line 5
        assert_eq!(lines[3], ("c".to_string(), 5));
    }

    #[test]
    fn multi_punct_longest_match() {
        let t = kinds("a <<= b << c <= d < e");
        let ops: Vec<String> = t
            .iter()
            .filter(|(k, _)| *k == TokKind::Punct)
            .map(|(_, s)| s.clone())
            .collect();
        assert_eq!(ops, vec!["<<=", "<<", "<=", "<"]);
    }

    #[test]
    fn hostile_fragments_never_panic() {
        for src in ["\"unterminated", "'", "'\\u{12", "r###\"never closed", "0x", "\u{1F600} €"] {
            let _ = lex(src);
        }
    }
}
