//! The lint run's result: live findings, the suppression inventory, and
//! the ROADMAP drift checks, renderable as a human report or as the
//! `target/lint-report.json` document CI archives.

use super::contract::DriftCheck;
use crate::json::Value;
use std::collections::BTreeMap;

/// Every rule name, in report order. Rule counts are emitted for all of
/// them (zeros included) so a rule that silently stops firing is visible
/// as a diff in the JSON report.
pub const RULE_NAMES: [&str; 8] = [
    "panic-macro",
    "raw-index",
    "unchecked-len-arith",
    "unbounded-alloc",
    "truncating-cast",
    "unsafe-without-safety-comment",
    "bad-suppression",
    "roadmap-drift",
];

/// One finding, located in a file (live or suppressed).
#[derive(Debug, Clone)]
pub struct FileFinding {
    pub file: String,
    pub rule: &'static str,
    pub line: usize,
    pub msg: String,
    /// The written reason, for suppressed findings.
    pub reason: Option<String>,
}

/// One `baf-lint: allow(...)` annotation found in the tree.
#[derive(Debug, Clone)]
pub struct Suppression {
    pub file: String,
    pub line: usize,
    pub rules: Vec<String>,
    pub reason: Option<String>,
    /// Did this annotation actually suppress at least one finding?
    pub used: bool,
}

/// The full result of a lint run.
#[derive(Debug, Default)]
pub struct Report {
    pub files_scanned: usize,
    /// Unsuppressed findings — any entry here fails the run.
    pub findings: Vec<FileFinding>,
    /// Findings silenced by an annotation, kept for the inventory.
    pub suppressed: Vec<FileFinding>,
    /// Every annotation in the tree, with its reason and whether it fired.
    pub suppressions: Vec<Suppression>,
    /// ROADMAP constant cross-checks (failures also appear in `findings`
    /// as `roadmap-drift`).
    pub drift: Vec<DriftCheck>,
}

impl Report {
    /// A clean run: nothing unsuppressed, every drift check green.
    pub fn clean(&self) -> bool {
        self.findings.is_empty() && self.drift.iter().all(|d| d.ok)
    }

    /// Per-rule (found, suppressed) counts over all known rules.
    pub fn rule_counts(&self) -> BTreeMap<&'static str, (usize, usize)> {
        let mut counts: BTreeMap<&'static str, (usize, usize)> =
            RULE_NAMES.iter().map(|&r| (r, (0, 0))).collect();
        for f in &self.findings {
            if let Some(c) = counts.get_mut(f.rule) {
                c.0 += 1;
            }
        }
        for f in &self.suppressed {
            if let Some(c) = counts.get_mut(f.rule) {
                c.1 += 1;
            }
        }
        // drift failures live in `drift`, not `findings`; count them here
        // so the rule table reflects them
        let failed_drift = self.drift.iter().filter(|d| !d.ok).count();
        if let Some(c) = counts.get_mut("roadmap-drift") {
            c.0 += failed_drift;
        }
        counts
    }

    /// The JSON document written to `target/lint-report.json`.
    pub fn to_value(&self) -> Value {
        let mut rules = Value::obj();
        for (rule, (found, suppressed)) in self.rule_counts() {
            let mut entry = Value::obj();
            entry.set("found", found).set("suppressed", suppressed);
            rules.set(rule, entry);
        }
        let findings: Vec<Value> = self
            .findings
            .iter()
            .map(|f| {
                let mut v = Value::obj();
                v.set("file", f.file.as_str())
                    .set("line", f.line)
                    .set("rule", f.rule)
                    .set("message", f.msg.as_str());
                v
            })
            .collect();
        let suppressions: Vec<Value> = self
            .suppressions
            .iter()
            .map(|s| {
                let mut v = Value::obj();
                v.set("file", s.file.as_str())
                    .set("line", s.line)
                    .set(
                        "rules",
                        s.rules.iter().map(|r| Value::from(r.as_str())).collect::<Vec<_>>(),
                    )
                    .set(
                        "reason",
                        s.reason.as_deref().map_or(Value::Null, Value::from),
                    )
                    .set("used", s.used);
                v
            })
            .collect();
        let drift: Vec<Value> = self
            .drift
            .iter()
            .map(|d| {
                let mut v = Value::obj();
                v.set("what", d.what.as_str())
                    .set("ok", d.ok)
                    .set("detail", d.detail.as_str());
                v
            })
            .collect();
        let mut doc = Value::obj();
        doc.set("version", 1usize)
            .set("files_scanned", self.files_scanned)
            .set("clean", self.clean())
            .set("rules", rules)
            .set("findings", findings)
            .set("suppressions", suppressions)
            .set("drift", drift);
        doc
    }

    /// The human-readable report printed by `baf_lint`.
    pub fn human(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!(
            "baf-lint: scanned {} files under rust/src\n",
            self.files_scanned
        ));
        for f in &self.findings {
            out.push_str(&format!(
                "error[{}]: {}:{}: {}\n",
                f.rule, f.file, f.line, f.msg
            ));
        }
        for d in self.drift.iter().filter(|d| !d.ok) {
            out.push_str(&format!("error[roadmap-drift]: {}: {}\n", d.what, d.detail));
        }
        out.push_str("\nrule                            found  suppressed\n");
        for (rule, (found, suppressed)) in self.rule_counts() {
            out.push_str(&format!("{rule:<32}{found:>5}  {suppressed:>10}\n"));
        }
        let unused = self.suppressions.iter().filter(|s| !s.used).count();
        out.push_str(&format!(
            "\n{} suppression(s) on record ({} unused), {} drift check(s)\n",
            self.suppressions.len(),
            unused,
            self.drift.len()
        ));
        out.push_str(if self.clean() {
            "result: CLEAN\n"
        } else {
            "result: FAIL\n"
        });
        out
    }
}

#[cfg(test)]
mod tests {
    #![allow(clippy::unwrap_used, clippy::expect_used)]

    use super::*;
    use crate::json;

    fn sample() -> Report {
        Report {
            files_scanned: 3,
            findings: vec![FileFinding {
                file: "rust/src/codec/x.rs".into(),
                rule: "raw-index",
                line: 10,
                msg: "non-constant index in decode path".into(),
                reason: None,
            }],
            suppressed: vec![FileFinding {
                file: "rust/src/codec/y.rs".into(),
                rule: "panic-macro",
                line: 4,
                msg: "`panic!` in no-panic module".into(),
                reason: Some("encoder contract".into()),
            }],
            suppressions: vec![Suppression {
                file: "rust/src/codec/y.rs".into(),
                line: 3,
                rules: vec!["panic-macro".into()],
                reason: Some("encoder contract".into()),
                used: true,
            }],
            drift: vec![DriftCheck {
                what: "wire message".into(),
                ok: true,
                detail: "ROADMAP grammar block must contain `BAFN | ver=1`".into(),
            }],
        }
    }

    #[test]
    fn counts_cover_every_rule_with_zeros() {
        let counts = sample().rule_counts();
        assert_eq!(counts.len(), RULE_NAMES.len());
        assert_eq!(counts["raw-index"], (1, 0));
        assert_eq!(counts["panic-macro"], (0, 1));
        assert_eq!(counts["truncating-cast"], (0, 0));
    }

    #[test]
    fn json_report_round_trips() {
        let v = sample().to_value();
        let text = v.pretty(1);
        let back = json::parse(&text).unwrap();
        assert_eq!(back, v);
        assert_eq!(back.get("clean").and_then(Value::as_bool), Some(false));
        assert_eq!(
            back.get("rules")
                .and_then(|r| r.get("raw-index"))
                .and_then(|r| r.get("found"))
                .and_then(Value::as_f64),
            Some(1.0)
        );
    }

    #[test]
    fn clean_requires_no_findings_and_green_drift() {
        let mut r = sample();
        assert!(!r.clean());
        r.findings.clear();
        assert!(r.clean());
        r.drift[0].ok = false;
        assert!(!r.clean());
        assert_eq!(r.rule_counts()["roadmap-drift"], (1, 0));
    }

    #[test]
    fn human_report_mentions_verdict() {
        let r = sample();
        let text = r.human();
        assert!(text.contains("error[raw-index]"));
        assert!(text.contains("result: FAIL"));
    }
}
