//! The per-module contract map and the ROADMAP constant-drift check.
//!
//! The map mirrors the clippy scoping in `rust/src/lib.rs`: the modules
//! that deny `unwrap_used`/`expect_used` there — `codec` (including
//! `codec::scratch`), `net`, `coordinator`, `metrics`, and
//! `runtime::pool` — are exactly the modules whose decode functions the
//! structural rules (raw-index, unchecked-len-arith, unbounded-alloc,
//! truncating-cast) and the module-wide panic-macro rule apply to. The
//! `unsafe`-hygiene rule runs over the whole tree regardless.

use super::lexer::{self, Token};

/// Directories whose `.rs` files carry the full no-panic contract.
pub const CONTRACT_DIRS: [&str; 4] = [
    "rust/src/codec/",
    "rust/src/net/",
    "rust/src/coordinator/",
    "rust/src/metrics/",
];

/// Individual contract files outside those directories.
pub const CONTRACT_FILES: [&str; 1] = ["rust/src/runtime/pool.rs"];

/// Is this repo-relative path under the no-panic contract?
pub fn is_contract(rel: &str) -> bool {
    CONTRACT_DIRS.iter().any(|d| rel.starts_with(d)) || CONTRACT_FILES.contains(&rel)
}

/// Name fragments that mark a function as decode-path: it consumes
/// bytes or messages that may be hostile.
pub const DECODE_PATTERNS: [&str; 10] = [
    "decode", "parse", "unpack", "validate", "check", "read", "recv",
    "from_", "next_", "get_",
];

pub fn is_decode_fn(name: &str) -> bool {
    DECODE_PATTERNS.iter().any(|p| name.contains(p))
}

/// Identifiers whose presence in a function counts as a size cap: the
/// `MAX_*` limits themselves, plus the helpers that enforce them
/// (`ImageMeta::checked_samples`, `tlc_ic::checked_total`,
/// `wire::validate_header`).
pub const CAP_IDENTS: [&str; 6] = [
    "MAX_DECODED_SAMPLES",
    "MAX_FRAME_LEN",
    "MAX_HEADER_LEN",
    "checked_samples",
    "checked_total",
    "validate_header",
];

/// Integer types an `as` cast can silently truncate a length into.
pub const NARROW_INTS: [&str; 6] = ["u8", "u16", "u32", "i8", "i16", "i32"];

/// Macros that abort instead of returning a typed error.
pub const PANIC_MACROS: [&str; 4] = ["panic", "unreachable", "todo", "unimplemented"];

const LEN_NAMES: [&str; 3] = ["len", "count", "offset"];
const LEN_SUFFIXES: [&str; 4] = ["_len", "_count", "_offset", "_off"];

/// Is this identifier length-shaped (`len`, `payload_len`, `n_tiles`,
/// `frame_count`, ...)? Arithmetic on these outside `checked_*` /
/// `saturating_*` / `wrapping_*` forms is rule `unchecked-len-arith`.
pub fn is_len_shaped(name: &str) -> bool {
    LEN_NAMES.contains(&name)
        || LEN_SUFFIXES.iter().any(|s| name.ends_with(s))
        || name.starts_with("n_")
}

/// SCREAMING_CASE identifiers are compile-time constants for the
/// const-index heuristic.
pub fn is_const_ident(name: &str) -> bool {
    name.len() >= 2
        && name.starts_with(|c: char| c.is_ascii_uppercase())
        && name
            .chars()
            .all(|c| c.is_ascii_uppercase() || c.is_ascii_digit() || c == '_')
}

/// One wire/container constant cross-checked against ROADMAP.md.
#[derive(Debug, Clone)]
pub struct DriftCheck {
    pub what: String,
    pub ok: bool,
    pub detail: String,
}

/// Cross-check the grammar blocks in ROADMAP.md against the constants
/// actually compiled into `codec::container` and `net::wire`: magic
/// strings, version bytes, and the `MAX_FRAME_LEN` multiplier. A failed
/// extraction is itself a failure — the check must never silently pass
/// because a constant moved.
pub fn check_drift(
    container_src: &str,
    wire_src: &str,
    roadmap: &str,
) -> Vec<DriftCheck> {
    let mut out = Vec::new();
    let container = lexer::code_toks(&lexer::lex(container_src));
    let wire = lexer::code_toks(&lexer::lex(wire_src));

    let c_magic = const_bytes(&container, "MAGIC");
    let c_v1 = const_num(&container, "VERSION");
    let c_v2 = const_num(&container, "VERSION2");
    let w_magic = const_bytes(&wire, "MAGIC");
    let w_v = const_num(&wire, "VERSION");
    let w_v2 = const_num(&wire, "VERSION2");
    let frame_cap = const_init_tokens(&wire, "MAX_FRAME_LEN");

    match (&c_magic, c_v1, c_v2) {
        (Some(magic), Some(v1), Some(v2)) => {
            for (name, ver) in [("container v1", v1), ("container v2", v2)] {
                let needle = format!("{magic} | ver={ver}");
                out.push(DriftCheck {
                    what: name.to_string(),
                    ok: roadmap.contains(&needle),
                    detail: format!("ROADMAP grammar block must contain `{needle}`"),
                });
            }
        }
        _ => out.push(DriftCheck {
            what: "container constants".to_string(),
            ok: false,
            detail: "could not extract MAGIC/VERSION/VERSION2 from codec::container"
                .to_string(),
        }),
    }

    match (&w_magic, w_v, w_v2) {
        (Some(magic), Some(v1), Some(v2)) => {
            for (name, ver) in [("wire v1", v1), ("wire v2", v2)] {
                let needle = format!("{magic} | ver={ver}");
                out.push(DriftCheck {
                    what: name.to_string(),
                    ok: roadmap.contains(&needle),
                    detail: format!("ROADMAP grammar block must contain `{needle}`"),
                });
            }
        }
        _ => out.push(DriftCheck {
            what: "wire constants".to_string(),
            ok: false,
            detail: "could not extract MAGIC/VERSION/VERSION2 from net::wire"
                .to_string(),
        }),
    }

    // the three verdict bytes are load-bearing for every client of the
    // protocol (a misread BUSY is an unexplained drop), so ROADMAP must
    // name each one with its hex value
    for name in ["ACK", "NACK", "BUSY"] {
        match const_num(&wire, name) {
            Some(v) => {
                let needle = format!("{name} (0x{v:02X})");
                out.push(DriftCheck {
                    what: format!("wire verdict {name}"),
                    ok: roadmap.contains(&needle),
                    detail: format!("ROADMAP must name the verdict `{needle}`"),
                });
            }
            None => out.push(DriftCheck {
                what: format!("wire verdict {name}"),
                ok: false,
                detail: format!("could not extract {name} from net::wire"),
            }),
        }
    }

    // MAX_FRAME_LEN must be `<mult> * MAX_DECODED_SAMPLES` in source and
    // ROADMAP must state the same multiplier.
    let mult = frame_cap.as_ref().and_then(|toks| match toks.as_slice() {
        [a, b, c]
            if b.as_str() == "*"
                && (a.as_str() == "MAX_DECODED_SAMPLES"
                    || c.as_str() == "MAX_DECODED_SAMPLES") =>
        {
            let num = if a.as_str() == "MAX_DECODED_SAMPLES" { c } else { a };
            num.parse::<u64>().ok()
        }
        _ => None,
    });
    match mult {
        Some(m) => {
            let needle = format!("MAX_FRAME_LEN = {m} * codec::MAX_DECODED_SAMPLES");
            out.push(DriftCheck {
                what: "wire frame cap".to_string(),
                ok: roadmap.contains(&needle),
                detail: format!("ROADMAP must state `{needle}`"),
            });
        }
        None => out.push(DriftCheck {
            what: "wire frame cap".to_string(),
            ok: false,
            detail: format!(
                "net::wire MAX_FRAME_LEN is not `N * MAX_DECODED_SAMPLES` (tokens: {:?})",
                frame_cap
            ),
        }),
    }
    out
}

/// The token texts of `const <name> ... = <init> ;`, between `=` and `;`.
fn const_init_tokens(code: &[Token], name: &str) -> Option<Vec<String>> {
    let mut x = 0usize;
    while x + 1 < code.len() {
        if code[x].text == "const" && code[x + 1].text == name {
            // scan the type annotation to `=`; a `;` inside brackets is
            // an array length (`&[u8; 4]`), only a top-level one ends
            // the item without an initializer
            let mut depth = 0usize;
            let mut y = x + 2;
            while y < code.len() && code[y].text != "=" {
                match code[y].text.as_str() {
                    "[" | "(" => depth += 1,
                    "]" | ")" => depth = depth.saturating_sub(1),
                    ";" if depth == 0 => return None,
                    _ => {}
                }
                y += 1;
            }
            let mut init = Vec::new();
            let mut z = y + 1;
            while z < code.len() && code[z].text != ";" {
                init.push(code[z].text.clone());
                z += 1;
            }
            return Some(init);
        }
        x += 1;
    }
    None
}

/// A `const <name>: ... = <num>;` integer initializer. Accepts decimal
/// and `0x` hex literals, with `_` separators (the verdict bytes are
/// written `0xA5`-style in source).
fn const_num(code: &[Token], name: &str) -> Option<u64> {
    let init = const_init_tokens(code, name)?;
    match init.as_slice() {
        [n] => parse_int_literal(n),
        _ => None,
    }
}

fn parse_int_literal(text: &str) -> Option<u64> {
    let s = text.replace('_', "");
    match s.strip_prefix("0x").or_else(|| s.strip_prefix("0X")) {
        Some(hex) => u64::from_str_radix(hex, 16).ok(),
        None => s.parse::<u64>().ok(),
    }
}

/// A `const <name>: &[u8; N] = b"....";` byte-string initializer,
/// returned as the inner text.
fn const_bytes(code: &[Token], name: &str) -> Option<String> {
    let init = const_init_tokens(code, name)?;
    init.iter().find_map(|t| {
        t.strip_prefix("b\"")
            .and_then(|s| s.strip_suffix('"'))
            .map(str::to_string)
    })
}

#[cfg(test)]
mod tests {
    #![allow(clippy::unwrap_used, clippy::expect_used)]

    use super::*;

    #[test]
    fn contract_map_mirrors_lib_rs_scoping() {
        assert!(is_contract("rust/src/codec/rc.rs"));
        assert!(is_contract("rust/src/codec/scratch.rs"));
        assert!(is_contract("rust/src/net/wire.rs"));
        assert!(is_contract("rust/src/coordinator/batcher.rs"));
        assert!(is_contract("rust/src/metrics/mod.rs"));
        assert!(is_contract("rust/src/runtime/pool.rs"));
        assert!(!is_contract("rust/src/runtime/engine.rs"));
        assert!(!is_contract("rust/src/tio/mod.rs"));
        assert!(!is_contract("rust/src/lint/rules.rs"));
    }

    #[test]
    fn identifier_classifiers() {
        for n in ["len", "payload_len", "frame_len", "count", "n_tiles", "offset", "side_off"] {
            assert!(is_len_shaped(n), "{n}");
        }
        for n in ["width", "channels", "cap", "filled", "k", "off", "bins"] {
            assert!(!is_len_shaped(n), "{n}");
        }
        assert!(is_const_ident("MAX_FRAME_LEN"));
        assert!(is_const_ident("OK"));
        assert!(!is_const_ident("K"));
        assert!(!is_const_ident("Value"));
        assert!(is_decode_fn("parse"));
        assert!(is_decode_fn("read_one"));
        assert!(is_decode_fn("next_batch"));
        assert!(!is_decode_fn("encode_into"));
        assert!(!is_decode_fn("pack_v2_with"));
    }

    #[test]
    fn drift_check_catches_mismatched_roadmap() {
        let container = r#"
            pub const MAGIC: &[u8; 4] = b"BAFT";
            pub const VERSION: u8 = 1;
            pub const VERSION2: u8 = 2;
        "#;
        let wire = r#"
            pub const MAGIC: &[u8; 4] = b"BAFN";
            pub const VERSION: u8 = 1;
            pub const VERSION2: u8 = 2;
            pub const MAX_FRAME_LEN: usize = 4 * MAX_DECODED_SAMPLES;
            pub const ACK: u8 = 0xA5;
            pub const NACK: u8 = 0x5A;
            pub const BUSY: u8 = 0xB5;
        "#;
        let good = "BAFT | ver=1 ... BAFT | ver=2 ... BAFN | ver=1 ...\n\
                    BAFN | ver=2 ... ACK (0xA5), NACK (0x5A), BUSY (0xB5)\n\
                    MAX_FRAME_LEN = 4 * codec::MAX_DECODED_SAMPLES";
        let checks = check_drift(container, wire, good);
        assert_eq!(checks.len(), 8);
        assert!(checks.iter().all(|c| c.ok), "{checks:?}");
        // a stale ROADMAP (wrong versions, wrong multiplier, no BUSY)
        // fails exactly those checks
        let stale = "BAFT | ver=1 ... BAFN | ver=1 ... BAFN | ver=2 ...\n\
                     ACK (0xA5), NACK (0x5A)\n\
                     MAX_FRAME_LEN = 2 * codec::MAX_DECODED_SAMPLES";
        let checks = check_drift(container, wire, stale);
        assert_eq!(checks.iter().filter(|c| !c.ok).count(), 3, "{checks:?}");
        // an unextractable constant is a failure, not a silent pass
        let checks = check_drift("", wire, good);
        assert!(checks.iter().any(|c| !c.ok && c.what == "container constants"));
        // a wire module missing the verdict consts is a failure too
        let old_wire = r#"
            pub const MAGIC: &[u8; 4] = b"BAFN";
            pub const VERSION: u8 = 1;
            pub const MAX_FRAME_LEN: usize = 4 * MAX_DECODED_SAMPLES;
        "#;
        let checks = check_drift(container, old_wire, good);
        assert!(checks.iter().any(|c| !c.ok && c.what == "wire constants"));
        assert!(checks.iter().any(|c| !c.ok && c.what == "wire verdict ACK"));
    }

    #[test]
    fn int_literal_parser_handles_hex_and_separators() {
        assert_eq!(parse_int_literal("42"), Some(42));
        assert_eq!(parse_int_literal("0xA5"), Some(0xA5));
        assert_eq!(parse_int_literal("0XB5"), Some(0xB5));
        assert_eq!(parse_int_literal("1_000"), Some(1000));
        assert_eq!(parse_int_literal("0x9E37_79B9"), Some(0x9E37_79B9));
        assert_eq!(parse_int_literal("ver"), None);
    }
}
