//! Fixture: the unsafe-hygiene rule must fire tree-wide.

pub unsafe fn wild_write(p: *mut u8) { *p = 1; }

pub fn commented_write(p: *mut u8) {
    // SAFETY: fixture — the caller guarantees p is valid and exclusive.
    unsafe { *p = 2 }
}
