//! Fixture: reasonless allows are `bad-suppression` findings.
// baf-lint: allow(raw-index)
pub fn decode_reasonless(bytes: &[u8], i: usize) -> u8 { bytes[i] }

// baf-lint: allow(raw-index) -- fixture: bounded by the loop condition
pub fn decode_reasoned(bytes: &[u8], i: usize) -> u8 { bytes[i] }
