//! Fixture: `panic-macro` must fire once here and be suppressible.

pub fn encode_stub(x: u32) -> u32 {
    if x > 10 { panic!("fixture violation") } else { x }
}

// baf-lint: allow(panic-macro) -- fixture: sanctioned encoder abort
pub fn encode_suppressed(x: u32) -> u32 {
    if x == 0 { unreachable!("fixture") } else { x }
}
