//! Fixture: `unbounded-alloc` must fire without a MAX_* cap in scope.

pub fn decode_stub(n_items: usize) -> Vec<u8> { Vec::with_capacity(n_items) }

// baf-lint: allow(unbounded-alloc) -- fixture: size from trusted config
pub fn decode_suppressed(n_items: usize, out: &mut Vec<u8>) { out.resize(n_items, 0); }

pub fn decode_capped(n_items: usize) -> Vec<u16> {
    let n = n_items.min(MAX_DECODED_SAMPLES);
    vec![0u16; n]
}
