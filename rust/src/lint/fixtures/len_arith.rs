//! Fixture: `unchecked-len-arith` must fire on bare length math.

pub fn parse_stub(payload_len: usize) -> usize { payload_len + 4 }

// baf-lint: allow(unchecked-len-arith) -- fixture: bounded upstream
pub fn parse_suppressed(frame_len: usize) -> usize { frame_len * 2 }

pub fn parse_checked(payload_len: usize) -> Option<usize> {
    payload_len.checked_add(4)
}
