//! Fixture: `truncating-cast` must fire on narrowing length casts.

pub fn read_stub(frame_len: usize) -> u32 { frame_len as u32 }

// baf-lint: allow(truncating-cast) -- fixture: validated < 65536 upstream
pub fn read_suppressed(body_len: usize) -> u16 { body_len as u16 }

pub fn read_widening(frame_len: usize) -> u64 { frame_len as u64 }
