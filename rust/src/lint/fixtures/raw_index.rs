//! Fixture: `raw-index` must fire on a non-constant decode index.

pub fn decode_stub(bytes: &[u8], i: usize) -> u8 { bytes[i] }

// baf-lint: allow(raw-index) -- fixture: index bounded by the caller
pub fn decode_suppressed(bytes: &[u8], i: usize) -> u8 { bytes[i] }

pub fn decode_const(bytes: &[u8]) -> u8 { bytes[0] }
