#!/usr/bin/env bash
# Tier-1 verification gate (see ROADMAP.md). Run from the repo root.
#
#   build + tests + the scoped clippy no-panic gate, then a smoke run of
#   bench_codec with JSON emission so the striped-codec acceptance
#   assertions (size parity, zero steady-state allocations, K=4 speedup
#   on >=4-core machines) and the BENCH_*.json emitter can't rot.
set -euo pipefail
cd "$(dirname "$0")/.."

cargo build --release
cargo test -q
# the transport + fuzz suites are part of `cargo test`, but name them
# explicitly so a test-harness filter or target rename can't silently
# drop them from the gate (they enforce the no-panic wire contract)
cargo test -q --test net_loopback --test transport_robustness --test json_fuzz \
    --test npy_fuzz --test decode_robustness
# short fixed-seed chaos smoke: sender -> chaos shim -> receiver ->
# ingress under a seeded loss/stall/reset/throttle schedule, asserting
# exactly-once delivery and exact conservation. The full soak runs the
# same test with BAF_CHAOS_FRAMES raised; the per-seed summary JSON
# lands in target/chaos-soak/ (archived by CI).
BAF_CHAOS_FRAMES=300 cargo test -q --test chaos_soak --test dedup_prop
cargo clippy --all-targets -- -D clippy::unwrap_used -D clippy::expect_used
# the source-level no-panic gate: zero unsuppressed findings, every
# suppression reasoned, wire/container constants in sync with ROADMAP.
# Writes target/lint-report.json (archived by CI).
cargo run --release --bin baf_lint
cargo bench --bench bench_codec -- --smoke --json-out target/bench-json
test -f target/bench-json/BENCH_codec.json
echo "tier-1 OK"
