//! Decode-path robustness harness: drives the fault generators in
//! `baf::codec::faultgen` against every registered codec and asserts the
//! no-panic contract of the codec module:
//!
//! * every 1-byte truncation of a valid container frame is rejected;
//! * every single-bit flip of a valid container frame is rejected (CRC)
//!   or decodes to the exact original tensor;
//! * targeted header corruption (with the CRC refreshed so validation is
//!   actually reached) never panics and never produces an inconsistent
//!   tensor;
//! * raw codec payloads (no CRC protection) decode to `Err` or a
//!   bounded, correctly-sized sample vector — never a panic;
//! * sustained random corruption (the E5 server's fault model) is
//!   survivable for thousands of rounds.
//!
//! Nothing here requires artifacts; the suite runs everywhere tier-1
//! runs.

#![allow(clippy::unwrap_used, clippy::expect_used)]

use baf::codec::faultgen::{
    all_bit_flips, all_truncations, header_mutations, stripe_table_mutations, Corruptor,
};
use baf::codec::{container, CodecKind, ImageMeta, ALL_CODECS};
use baf::quant::{quantize, QuantizedTensor};
use baf::tensor::Tensor;
use baf::util::SplitMix64;

fn sample_quant(c: usize, h: usize, w: usize, n: u8, seed: u64) -> QuantizedTensor {
    let mut r = SplitMix64::new(seed);
    let z = Tensor::from_vec(
        &[c, h, w],
        (0..c * h * w).map(|_| r.next_f32() * 4.0 - 2.0).collect(),
    );
    quantize(&z, n)
}

fn qp_for(codec: CodecKind) -> u8 {
    if codec == CodecKind::Mic {
        12
    } else {
        0
    }
}

/// Every prefix of a valid frame must be rejected: either it is too
/// short for the fixed header, or its last four bytes are not a valid
/// CRC of the rest.
#[test]
fn every_truncation_of_every_codec_frame_is_rejected() {
    for codec in ALL_CODECS {
        let q = sample_quant(3, 8, 8, 6, 0xBAF0 + codec as u64);
        let frame = container::pack(&q, codec, qp_for(codec));
        for fault in all_truncations(frame.len()) {
            let bad = fault.apply(&frame);
            assert!(
                container::parse(&bad).is_err(),
                "{}: truncation to {} of {} bytes accepted",
                codec.name(),
                bad.len(),
                frame.len()
            );
        }
    }
}

/// Every single-bit flip must be rejected (the CRC covers every byte,
/// including itself) — or, at minimum, decode to the exact original
/// tensor. Silent wrong data is the one forbidden outcome.
#[test]
fn every_bit_flip_of_every_codec_frame_is_detected_or_harmless() {
    for codec in ALL_CODECS {
        let q = sample_quant(3, 8, 8, 6, 0xF11B + codec as u64);
        let frame = container::pack(&q, codec, qp_for(codec));
        for fault in all_bit_flips(frame.len()) {
            let bad = fault.apply(&frame);
            match container::parse(&bad).and_then(|f| container::unpack(&f)) {
                Err(_) => {}
                Ok(back) => assert_eq!(
                    back.bins,
                    q.bins,
                    "{}: {fault:?} yielded wrong data without an error",
                    codec.name()
                ),
            }
        }
    }
}

/// Header corruption with a *refreshed* CRC reaches the field validation
/// logic the checksum normally shadows. The decoder may reject the frame
/// or decode it (a mutated header can still describe a consistent
/// geometry), but it must never panic and never return a tensor that
/// disagrees with its own claimed shape.
#[test]
fn header_mutations_never_panic_and_stay_consistent() {
    for codec in ALL_CODECS {
        let q = sample_quant(4, 8, 8, 6, 0x4EAD + codec as u64);
        let frame = container::pack(&q, codec, qp_for(codec));
        for bad in header_mutations(&frame) {
            match container::parse(&bad).and_then(|f| container::unpack(&f)) {
                Err(_) => {}
                Ok(back) => {
                    assert_eq!(
                        back.bins.len(),
                        back.c * back.h * back.w,
                        "{}: inconsistent decoded shape",
                        codec.name()
                    );
                }
            }
        }
    }
}

/// Striped (v2) frames inherit the full truncation contract: every
/// prefix is rejected.
#[test]
fn every_truncation_of_striped_frames_is_rejected() {
    for codec in ALL_CODECS {
        let q = sample_quant(4, 8, 8, 6, 0x57B0 + codec as u64);
        let frame = container::pack_v2(&q, codec, qp_for(codec), 3);
        for fault in all_truncations(frame.len()) {
            let bad = fault.apply(&frame);
            assert!(
                container::parse(&bad).is_err(),
                "{}: v2 truncation to {} of {} bytes accepted",
                codec.name(),
                bad.len(),
                frame.len()
            );
        }
    }
}

/// Striped (v2) frames inherit the bit-flip contract: detected (frame
/// CRC or per-stripe CRC) or decoded bit-exact.
#[test]
fn every_bit_flip_of_striped_frames_is_detected_or_harmless() {
    for codec in ALL_CODECS {
        let q = sample_quant(4, 8, 8, 6, 0x57B1 + codec as u64);
        let frame = container::pack_v2(&q, codec, qp_for(codec), 3);
        let reference = container::unpack(&container::parse(&frame).unwrap()).unwrap();
        for fault in all_bit_flips(frame.len()) {
            let bad = fault.apply(&frame);
            match container::parse(&bad).and_then(|f| container::unpack(&f)) {
                Err(_) => {}
                Ok(back) => assert_eq!(
                    back.bins,
                    reference.bins,
                    "{}: v2 {fault:?} yielded wrong data without an error",
                    codec.name()
                ),
            }
        }
    }
}

/// The targeted stripe-table fault generator (K field + every stripe
/// len/CRC byte, CRC refreshed so validation is reached): the decoder
/// may reject or decode bit-exact, never panic, never silent garbage —
/// and at least some mutations must actually be rejected (the table is
/// validated, not trusted).
#[test]
fn stripe_table_mutation_sweep_never_panics() {
    for codec in ALL_CODECS {
        let q = sample_quant(4, 8, 8, 6, 0x57B2 + codec as u64);
        let frame = container::pack_v2(&q, codec, qp_for(codec), 4);
        let reference = container::unpack(&container::parse(&frame).unwrap()).unwrap();
        let muts = stripe_table_mutations(&frame);
        assert!(!muts.is_empty(), "{}: generator found no targets", codec.name());
        let mut rejected = 0usize;
        for bad in muts {
            match container::parse(&bad).and_then(|f| container::unpack(&f)) {
                Err(_) => rejected += 1,
                Ok(back) => assert_eq!(
                    back.bins,
                    reference.bins,
                    "{}: stripe-table mutation decoded to wrong data",
                    codec.name()
                ),
            }
        }
        assert!(rejected > 0, "{}: no stripe-table mutation was rejected", codec.name());
    }
}

/// The E5 fault model against striped frames: thousands of random
/// corruption rounds must be survivable, same as v1.
#[test]
fn random_corruption_fuzz_on_striped_frames_never_panics() {
    let mut corruptor = Corruptor::new(0x57F2);
    for codec in ALL_CODECS {
        let q = sample_quant(3, 8, 8, 6, 0x57F3 + codec as u64);
        let frame = container::pack_v2(&q, codec, qp_for(codec), 3);
        let reference = container::unpack(&container::parse(&frame).unwrap()).unwrap();
        for round in 0..2_000 {
            let bad = corruptor.corrupt(&frame);
            match container::parse(&bad).and_then(|f| container::unpack(&f)) {
                Err(_) => {}
                Ok(back) => assert_eq!(
                    back.bins,
                    reference.bins,
                    "{} round {round}: corrupted v2 frame decoded to wrong data",
                    codec.name()
                ),
            }
        }
    }
}

/// Raw payloads have no checksum — corruption there may decode to
/// garbage (range coding carries no redundancy; integrity is the
/// container CRC's job). The contract is weaker but absolute: `Err` or a
/// vector of exactly the expected length. Never a panic, never an
/// oversized allocation.
#[test]
fn raw_payload_truncations_and_flips_never_panic() {
    let (w, h, n) = (16usize, 12usize, 6u8);
    let mut r = SplitMix64::new(0x4A33);
    let samples: Vec<u16> = (0..w * h).map(|_| (r.next_u64() % 64) as u16).collect();
    let meta = ImageMeta { width: w, height: h, n };
    for codec in ALL_CODECS {
        let qp = qp_for(codec);
        let enc = codec.encode_image(&samples, w, h, n, qp);
        for fault in all_truncations(enc.len()) {
            let bad = fault.apply(&enc);
            if let Ok(v) = codec.decode_image(&bad, &meta, qp) {
                assert_eq!(v.len(), w * h, "{}: wrong-size decode", codec.name());
            }
        }
        for fault in all_bit_flips(enc.len()) {
            let bad = fault.apply(&enc);
            if let Ok(v) = codec.decode_image(&bad, &meta, qp) {
                assert_eq!(v.len(), w * h, "{}: wrong-size decode", codec.name());
            }
        }
        // degenerate inputs
        assert!(codec.decode_image(&[], &meta, qp).is_err() || w * h == 0);
    }
}

/// Absurd headers must be rejected *before* any allocation happens: a
/// meta claiming ~2^32 samples errs with `LimitExceeded` instantly.
#[test]
fn oversized_geometry_is_rejected_without_allocating() {
    let huge = ImageMeta { width: 65_535, height: 65_535, n: 8 };
    for codec in ALL_CODECS {
        let err = codec.decode_image(&[0u8; 16], &huge, qp_for(codec)).unwrap_err();
        let msg = err.to_string();
        assert!(
            msg.contains("limit"),
            "{}: expected an allocation-limit error, got: {msg}",
            codec.name()
        );
    }
}

/// The E5 fault model end to end: thousands of random corruptions
/// (truncation bursts, multi-bit flips, pure garbage) against every
/// codec. Decoding must survive every round.
#[test]
fn random_corruption_fuzz_rounds_never_panic() {
    let mut corruptor = Corruptor::new(0xF422);
    for codec in ALL_CODECS {
        let q = sample_quant(3, 8, 8, 6, 0xF022 + codec as u64);
        let frame = container::pack(&q, codec, qp_for(codec));
        for round in 0..2_000 {
            let bad = corruptor.corrupt(&frame);
            match container::parse(&bad).and_then(|f| container::unpack(&f)) {
                Err(_) => {}
                Ok(back) => assert_eq!(
                    back.bins,
                    q.bins,
                    "{} round {round}: corrupted frame decoded to wrong data",
                    codec.name()
                ),
            }
        }
    }
}

/// Empty and tiny inputs are the most common real-world corruption;
/// parse must classify them as truncation, with the sizes in the error.
#[test]
fn empty_and_tiny_frames_are_truncation_errors() {
    for len in 0..container::HEADER_LEN + 4 {
        let err = container::parse(&vec![0u8; len]).unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("truncated"), "len={len}: {msg}");
    }
}

/// Regressions for the checked header walk in `container::parse`:
/// hostile field values (with the CRC refreshed so validation is
/// actually reached) produce typed errors, never slice panics.
#[test]
fn hostile_header_fields_are_typed_errors() {
    use baf::codec::Error;

    let q = sample_quant(2, 8, 8, 6, 0xC0DE);
    let mut frame = container::pack(&q, CodecKind::Tlc, 0);
    // the payload-length field claims ~4 GiB on a tiny frame
    frame[18..22].copy_from_slice(&u32::MAX.to_le_bytes());
    container::refresh_crc(&mut frame);
    match container::parse(&frame) {
        Err(Error::Truncated { .. } | Error::Corrupt(_)) => {}
        other => panic!("oversized payload_len must be a typed error, got {other:?}"),
    }

    // every header field after the magic forced to 0xFF at once
    let mut all_ff = container::pack(&q, CodecKind::Tlc, 0);
    for b in &mut all_ff[4..container::HEADER_LEN] {
        *b = 0xFF;
    }
    container::refresh_crc(&mut all_ff);
    assert!(container::parse(&all_ff).is_err(), "all-0xFF header accepted");
}

/// A stripe-table entry whose length points past the stripe data region
/// is a typed `Corrupt`, not an out-of-range slice.
#[test]
fn stripe_table_length_past_payload_is_corrupt() {
    use baf::codec::Error;

    let q = sample_quant(4, 8, 8, 6, 0xC0DF);
    let mut frame = container::pack_v2(&q, CodecKind::Tlc, 0, 3);
    // layout: header(22) + K(2) + side(4*C=16) -> first stripe len at 40
    let table = container::HEADER_LEN + 2 + 4 * 4;
    frame[table..table + 4].copy_from_slice(&u32::MAX.to_le_bytes());
    container::refresh_crc(&mut frame);
    match container::parse(&frame) {
        Err(Error::Corrupt(_) | Error::Truncated { .. }) => {}
        other => panic!("runaway stripe length must be a typed error, got {other:?}"),
    }
}
