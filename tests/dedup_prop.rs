//! Property test for [`baf::net::DedupWindow`], the bounded ring that
//! makes wire-v2 delivery exactly-once at the receiver.
//!
//! The generator mimics what the sender's bounded retransmission budget
//! actually puts on the wire: a monotone per-stream sequence with
//!
//! * **bounded reorder** — arrivals are shuffled within blocks no wider
//!   than the window, so a fresh frame never lags the stream head by a
//!   full window (exactly the guarantee a bounded retry budget gives);
//! * **gaps** — some sequence numbers never arrive at all (frames lost
//!   and terminally dropped);
//! * **duplicates** — already-delivered frames are re-presented at
//!   random (retransmits after lost ACKs), including ones far enough
//!   back to have left the ring (the below-window conservative case);
//! * **BUSY probes** — a fresh frame is looked up but *not* observed
//!   (admission refused it), then immediately re-presented: it must
//!   still read as fresh.
//!
//! Checked against a `HashSet` model on every arrival, across window
//! capacities from 1 to 64, ring wraparound (streams several times the
//! capacity), and sequence values up near `u64::MAX`:
//!
//! * a fresh in-window sequence number is **never** rejected;
//! * an already-observed sequence number is **never** fresh again;
//! * an in-window gap (never observed) stays fresh no matter how many
//!   ring slots were reused around it.

#![allow(clippy::unwrap_used, clippy::expect_used)]

use baf::net::DedupWindow;
use baf::util::SplitMix64;
use std::collections::HashSet;

/// One seeded trial: a shuffled-within-blocks stream of `n` sequence
/// numbers starting above `base`, driven through the window with
/// duplicates, gaps, and BUSY probes injected along the way.
fn run_trial(cap: usize, base: u64, n: u64, seed: u64) {
    // the window clamps capacity 0 to 1; mirror that in the model
    let cap_eff = cap.max(1);
    let mut rng = SplitMix64::new(seed);
    let mut stream: Vec<u64> = (1..=n).map(|k| base + k).collect();
    // bounded reorder: an element of block k is delivered after at most
    // block-1 larger values from its own block, and everything in
    // earlier blocks is smaller — so `hi - seq < cap` whenever a fresh
    // seq arrives, matching the sender's bounded retransmission budget
    for chunk in stream.chunks_mut(cap_eff) {
        rng.shuffle(chunk);
    }

    let mut w = DedupWindow::new(cap);
    assert_eq!(w.capacity(), cap_eff);
    let mut observed: HashSet<u64> = HashSet::new();
    let mut delivered: Vec<u64> = Vec::new();
    let mut hi = 0u64;
    let mut any = false;

    let ctx = |hi: u64| format!("cap {cap} base {base} seed {seed:#x} hi {hi}");

    for &seq in &stream {
        if rng.next_f64() < 0.1 {
            // gap: this frame is lost for good and never arrives
            continue;
        }
        if rng.next_f64() < 0.15 {
            // BUSY probe: admission refuses the frame, so it is looked
            // up but not observed; the immediate retransmit below must
            // still be fresh
            assert!(
                !w.contains(seq),
                "{}: BUSY-probed fresh seq {seq} misread as duplicate",
                ctx(hi)
            );
        }
        // fresh arrival: must never be rejected
        assert!(!w.contains(seq), "{}: fresh seq {seq} rejected", ctx(hi));
        w.observe(seq);
        assert!(observed.insert(seq), "generator bug: {seq} presented twice");
        if !any || seq > hi {
            hi = seq;
            any = true;
        }
        delivered.push(seq);

        // duplicate retransmit: anything already delivered — recent or
        // long since evicted from the ring — must never be fresh again
        if rng.next_f64() < 0.3 {
            let pick = delivered[(rng.next_u64() as usize) % delivered.len()];
            assert!(
                w.contains(pick),
                "{}: duplicate seq {pick} not recognized",
                ctx(hi)
            );
        }
        // an in-window seq that was never observed (a gap, or simply
        // not yet arrived) must stay fresh despite ring slot reuse
        if rng.next_f64() < 0.2 {
            let lo = hi.saturating_sub(cap_eff as u64 - 1).max(base + 1);
            let g = lo + rng.next_u64() % (hi - lo + 1);
            if !observed.contains(&g) {
                assert!(
                    !w.contains(g),
                    "{}: in-window gap seq {g} misread as duplicate",
                    ctx(hi)
                );
            }
        }
    }

    // final sweep: every observed seq is a duplicate forever after
    for &seq in &delivered {
        assert!(w.contains(seq), "{}: {seq} forgotten entirely", ctx(hi));
    }
}

#[test]
fn random_orders_with_duplicates_and_gaps_across_wraparound() {
    let mut master = SplitMix64::new(0xDED0_57A7);
    for &cap in &[1usize, 2, 3, 8, 16, 64] {
        for _ in 0..8 {
            let seed = master.next_u64();
            // streams several times the capacity, so every ring slot is
            // reused repeatedly (wraparound) within each trial
            run_trial(cap, 0, (cap as u64 * 6).max(64), seed);
        }
    }
}

#[test]
fn sequence_values_near_u64_max_do_not_confuse_the_ring() {
    let mut master = SplitMix64::new(0xB16_5EA5);
    for &cap in &[1usize, 3, 8, 32] {
        let seed = master.next_u64();
        run_trial(cap, u64::MAX - 4096, 4000, seed);
    }
}

#[test]
fn zero_capacity_is_clamped_and_still_correct() {
    run_trial(0, 0, 64, 0x0CA9);
}
