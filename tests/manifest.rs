//! Manifest parsing against a synthetic artifact directory (no PJRT).


#![allow(clippy::unwrap_used, clippy::expect_used)]

use baf::runtime::Manifest;

fn write_fixture(dir: &std::path::Path) {
    std::fs::create_dir_all(dir).unwrap();
    let manifest = r#"{
        "version": 1,
        "image_size": 64, "grid": 8, "cell": 8,
        "anchors": [[16, 16], [40, 40]],
        "num_classes": 4, "head_channels": 18,
        "p_channels": 64, "q_channels": 32,
        "z_shape": [16, 16, 64],
        "leaky_slope": 0.1,
        "artifacts": {
            "frontend_b1": {
                "file": "frontend_b1.hlo.txt",
                "inputs": [[1, 64, 64, 3]],
                "output": [1, 16, 16, 64],
                "stage": "frontend", "batch": 1
            },
            "baf_c16_n8_b1": {
                "file": "baf_c16_n8_b1.hlo.txt",
                "inputs": [[1, 16, 16, 16]],
                "output": [1, 16, 16, 64],
                "stage": "baf", "c": 16, "n": 8, "batch": 1,
                "sel": [3, 38, 31, 29, 26, 57, 39, 34, 35, 2, 50, 15, 63, 0, 52, 60]
            },
            "baf_c4_n8_b1": {
                "file": "baf_c4_n8_b1.hlo.txt",
                "inputs": [[1, 16, 16, 4]],
                "output": [1, 16, 16, 64],
                "stage": "baf", "c": 4, "n": 8, "batch": 1,
                "sel": [3, 38, 31, 29]
            }
        }
    }"#;
    std::fs::write(dir.join("manifest.json"), manifest).unwrap();
}

#[test]
fn parses_geometry_and_specs() {
    let dir = std::env::temp_dir().join("baf_manifest_fixture");
    write_fixture(&dir);
    let m = Manifest::load(&dir).unwrap();
    assert_eq!(m.image_size, 64);
    assert_eq!(m.anchors, vec![(16.0, 16.0), (40.0, 40.0)]);
    assert_eq!(m.z_shape, (16, 16, 64));
    let spec = m.spec("baf_c16_n8_b1").unwrap();
    assert_eq!(spec.c, Some(16));
    assert_eq!(spec.n, Some(8));
    assert_eq!(spec.inputs, vec![vec![1, 16, 16, 16]]);
    assert_eq!(spec.sel.as_ref().unwrap().len(), 16);
    assert!(m.spec("nonexistent").is_err());
}

#[test]
fn baf_variants_sorted() {
    let dir = std::env::temp_dir().join("baf_manifest_fixture2");
    write_fixture(&dir);
    let m = Manifest::load(&dir).unwrap();
    assert_eq!(m.baf_variants(), vec![(4, 8), (16, 8)]);
    assert_eq!(Manifest::baf_name(16, 8, 1), "baf_c16_n8_b1");
}

#[test]
fn missing_manifest_is_a_clear_error() {
    let dir = std::env::temp_dir().join("baf_manifest_missing");
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    let err = Manifest::load(&dir).unwrap_err();
    assert!(format!("{err:#}").contains("make artifacts"));
}
