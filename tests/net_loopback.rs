//! Loopback integration tests for the `baf::net` TCP transport: edge
//! and cloud threads talk over `127.0.0.1:0` with real sockets.
//!
//! * every codec × both container versions round-trips byte-identically
//!   (the wire must be transparent: what `container::pack` produced is
//!   what `container::parse` sees on the far side);
//! * a mid-run disconnect is survived via reconnect-with-backoff and
//!   every frame still arrives, with `net_reconnects` reflecting it;
//! * wire-rejected garbage shows up in `net_frames_rejected` while the
//!   stream keeps serving valid frames;
//! * a frame corrupted *inside* the container (wire CRC intact) passes
//!   the transport and surfaces as `net::Error::Codec` from
//!   `recv_parsed` — the layering the error taxonomy promises.
//!
//! Nothing here requires artifacts; the suite runs everywhere tier-1
//! runs.

#![allow(clippy::unwrap_used, clippy::expect_used)]

use baf::codec::{container, CodecKind, ALL_CODECS};
use baf::metrics::Registry;
use baf::net::{wire, Error, FrameReceiver, FrameSender, NetConfig};
use baf::quant::{quantize, QuantizedTensor};
use baf::tensor::Tensor;
use baf::util::SplitMix64;
use std::io::{Read, Write};
use std::time::Duration;

fn sample_quant(c: usize, h: usize, w: usize, n: u8, seed: u64) -> QuantizedTensor {
    let mut r = SplitMix64::new(seed);
    let z = Tensor::from_vec(
        &[c, h, w],
        (0..c * h * w).map(|_| r.next_f32() * 4.0 - 2.0).collect(),
    );
    quantize(&z, n)
}

fn qp_for(codec: CodecKind) -> u8 {
    if codec == CodecKind::Mic {
        12
    } else {
        0
    }
}

fn cfg() -> NetConfig {
    NetConfig {
        connect_timeout: Duration::from_secs(2),
        read_timeout: Duration::from_secs(2),
        write_timeout: Duration::from_secs(2),
        accept_timeout: Duration::from_secs(5),
        max_reconnects: 6,
        backoff_base: Duration::from_millis(5),
        backoff_max: Duration::from_millis(50),
        seed: 0x10CA1,
        breaker_threshold: 0,
        breaker_cooldown: Duration::from_millis(100),
        dedup_window: 64,
    }
}

/// One frame per codec per container version: 5 codecs x {v1, v2/K=4}.
fn corpus() -> Vec<(String, Vec<u8>)> {
    let mut frames = Vec::new();
    for codec in ALL_CODECS {
        let q = sample_quant(8, 8, 8, 6, 0x10CA1 + codec as u64);
        frames.push((
            format!("{}/v1", codec.name()),
            container::pack(&q, codec, qp_for(codec)),
        ));
        frames.push((
            format!("{}/v2k4", codec.name()),
            container::pack_v2(&q, codec, qp_for(codec), 4),
        ));
    }
    frames
}

#[test]
fn all_codecs_and_container_versions_roundtrip_byte_identically() {
    let mut rx = FrameReceiver::bind("127.0.0.1:0", cfg()).unwrap();
    let addr = rx.local_addr().unwrap().to_string();
    let frames = corpus();
    assert_eq!(frames.len(), 10, "five codecs x two container versions");

    let sent = frames.clone();
    let edge = std::thread::spawn(move || {
        let mut tx = FrameSender::connect(&addr, cfg()).unwrap();
        for (name, frame) in &sent {
            tx.send(frame).unwrap_or_else(|e| panic!("sending {name}: {e}"));
        }
        tx.stats()
    });

    for (name, frame) in &frames {
        // recv_parsed also validates the container end to end
        let (got, parsed) = rx
            .recv_parsed()
            .unwrap_or_else(|e| panic!("receiving {name}: {e}"));
        assert_eq!(&got.frame, frame, "{name}: wire must be transparent");
        container::unpack(&parsed).unwrap_or_else(|e| panic!("unpacking {name}: {e}"));
    }

    let tx_stats = edge.join().unwrap();
    assert_eq!(tx_stats.frames as usize, frames.len());
    assert_eq!(tx_stats.reconnects, 0, "clean run needs no reconnects");
    assert_eq!(rx.stats().frames as usize, frames.len());
    assert_eq!(rx.stats().bytes, tx_stats.bytes);
}

#[test]
fn mid_run_disconnect_is_survived_via_backoff_and_nothing_is_lost() {
    let mut rx = FrameReceiver::bind("127.0.0.1:0", cfg()).unwrap();
    let addr = rx.local_addr().unwrap().to_string();
    const N: usize = 10;
    let frames: Vec<Vec<u8>> = (0..N)
        .map(|i| {
            let q = sample_quant(4, 8, 8, 6, 0xD15C + i as u64);
            container::pack(&q, CodecKind::Tlc, 0)
        })
        .collect();

    let sent = frames.clone();
    let edge = std::thread::spawn(move || {
        let mut tx = FrameSender::connect(&addr, cfg()).unwrap();
        for frame in &sent {
            tx.send(frame).unwrap();
        }
        tx.stats()
    });

    let mut got = Vec::new();
    while got.len() < N {
        match rx.recv() {
            Ok(r) => {
                got.push(r.frame);
                if got.len() == 3 {
                    // sever the connection mid-run: the sender must
                    // reconnect (with backoff) and resume where it was
                    rx.disconnect();
                }
            }
            // transient: the severed connection winding down
            Err(Error::ConnClosed { .. }) | Err(Error::Timeout { .. }) => {}
            Err(e) => panic!("receiver failed: {e}"),
        }
    }
    assert_eq!(got, frames, "every frame arrives, in order, bit-exact");

    let tx_stats = edge.join().unwrap();
    assert_eq!(tx_stats.frames as usize, N, "all frames acked");
    assert!(
        tx_stats.reconnects >= 1,
        "the injected disconnect must show up in net_reconnects"
    );

    // the metrics registry view the coordinator exports
    let reg = Registry::default();
    tx_stats.export_sender_into(&reg);
    let m = reg.export();
    let counters = m.get("counters").unwrap();
    assert_eq!(counters.get("net_frames_out").unwrap().as_usize(), Some(N));
    assert!(counters.get("net_reconnects").unwrap().as_usize().unwrap() >= 1);
}

#[test]
fn wire_garbage_is_rejected_and_counted_while_valid_frames_keep_flowing() {
    let mut rx = FrameReceiver::bind("127.0.0.1:0", cfg()).unwrap();
    let addr = rx.local_addr().unwrap().to_string();
    let q = sample_quant(4, 8, 8, 6, 0xBAD);
    let frame = container::pack(&q, CodecKind::Tlc, 0);

    let expect = frame.clone();
    let edge = std::thread::spawn(move || {
        // first a raw client that speaks garbage...
        let mut bad = std::net::TcpStream::connect(&addr).unwrap();
        bad.write_all(b"not the baf wire protocol at all").unwrap();
        let mut verdict = [0u8; 1];
        bad.read_exact(&mut verdict).unwrap();
        assert_eq!(verdict[0], wire::NACK, "garbage must be NACKed");
        drop(bad);
        // ...then a well-behaved sender on a fresh connection
        let mut tx = FrameSender::connect(&addr, cfg()).unwrap();
        tx.send(&expect).unwrap();
    });

    let mut rejected = 0;
    let mut received = None;
    for _ in 0..16 {
        match rx.recv() {
            Ok(r) => {
                received = Some(r.frame);
                break;
            }
            Err(Error::Protocol(_)) | Err(Error::TooLarge { .. }) => rejected += 1,
            Err(Error::ConnClosed { .. }) | Err(Error::Timeout { .. }) => {}
            Err(e) => panic!("receiver failed: {e}"),
        }
    }
    edge.join().unwrap();
    assert_eq!(received.as_ref(), Some(&frame));
    assert_eq!(rejected, 1, "exactly the garbage message is rejected");

    let reg = Registry::default();
    rx.stats().export_receiver_into(&reg);
    let counters = reg.export();
    let counters = counters.get("counters").unwrap();
    assert_eq!(counters.get("net_frames_rejected").unwrap().as_usize(), Some(1));
    assert_eq!(counters.get("net_frames_in").unwrap().as_usize(), Some(1));
}

#[test]
fn container_corruption_passes_the_wire_and_fails_typed_at_parse() {
    let mut rx = FrameReceiver::bind("127.0.0.1:0", cfg()).unwrap();
    let addr = rx.local_addr().unwrap().to_string();
    let q = sample_quant(4, 8, 8, 6, 0xC0DE);
    let mut corrupt = container::pack(&q, CodecKind::Tlc, 0);
    // break the *container* CRC; the wire layer will wrap these bytes
    // with its own (valid) message CRC, so the transport accepts them
    let last = corrupt.len() - 1;
    corrupt[last] ^= 0xFF;

    let payload = corrupt.clone();
    let edge = std::thread::spawn(move || {
        let mut tx = FrameSender::connect(&addr, cfg()).unwrap();
        // the transport acks: wire-level integrity is its whole contract
        tx.send(&payload).unwrap();
    });

    let err = rx.recv_parsed().unwrap_err();
    assert!(
        matches!(err, Error::Codec(_)),
        "container corruption must surface as Error::Codec, got: {err}"
    );
    edge.join().unwrap();
    // the wire itself was fine: the message counts as received, and the
    // connection survives (framing was never in doubt)
    assert_eq!(rx.stats().frames, 1);
    assert_eq!(rx.stats().rejected, 0);
}
