//! End-to-end integration tests over the PJRT runtime (require artifacts;
//! skipped with a message otherwise).

#![allow(clippy::unwrap_used, clippy::expect_used)]

use baf::codec::{container, CodecKind};
use baf::config::{PipelineConfig, ServerConfig};
use baf::coordinator::{run_server, CloudOnly, Pipeline};
use baf::runtime::Engine;
use std::path::PathBuf;
use std::rc::Rc;

fn artifact_dir() -> Option<PathBuf> {
    let dir = baf::runtime::default_artifact_dir();
    if dir.join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("SKIP: no artifacts at {} (run `make artifacts`)", dir.display());
        None
    }
}

fn cfg(dir: &PathBuf, c: usize, n: u8) -> PipelineConfig {
    PipelineConfig { artifact_dir: dir.clone(), c, n, ..Default::default() }
}

/// Transmitting ALL channels at n=8 must recover cloud-only accuracy
/// almost exactly (quantization at 8 bits is near-lossless).
#[test]
fn full_channels_recover_cloud_only() {
    let Some(dir) = artifact_dir() else { return };
    let engine = Rc::new(Engine::new(&dir).unwrap());
    let samples = baf::data::eval_set(24);
    let base = CloudOnly::new(Rc::clone(&engine)).evaluate_set(&samples).unwrap();
    let pipe = Pipeline::new(Rc::clone(&engine), cfg(&dir, 64, 8)).unwrap();
    let (map, _) = pipe.evaluate_set(&samples).unwrap();
    assert!(
        (map.map_50 - base.map_50).abs() < 0.03,
        "C=P mAP {} vs cloud-only {}",
        map.map_50,
        base.map_50
    );
}

/// Fewer channels must not cost nothing: rate decreases with C, and the
/// pipeline stays functional down to C=4.
#[test]
fn rate_scales_with_c() {
    let Some(dir) = artifact_dir() else { return };
    let engine = Rc::new(Engine::new(&dir).unwrap());
    let samples = baf::data::eval_set(6);
    let mut prev_rate = f64::INFINITY;
    for &c in &[64usize, 16, 4] {
        let pipe = Pipeline::new(Rc::clone(&engine), cfg(&dir, c, 8)).unwrap();
        let (_, rate) = pipe.evaluate_set(&samples).unwrap();
        assert!(rate < prev_rate, "rate {rate} at C={c} not below {prev_rate}");
        prev_rate = rate;
    }
}

/// Rate decreases with n at fixed C (the FLIF property end to end).
#[test]
fn rate_scales_with_n() {
    let Some(dir) = artifact_dir() else { return };
    let engine = Rc::new(Engine::new(&dir).unwrap());
    let samples = baf::data::eval_set(6);
    let mut prev_rate = f64::INFINITY;
    for &n in &[8u8, 5, 2] {
        let pipe = Pipeline::new(Rc::clone(&engine), cfg(&dir, 16, n)).unwrap();
        let (_, rate) = pipe.evaluate_set(&samples).unwrap();
        assert!(rate < prev_rate, "rate {rate} at n={n} not below {prev_rate}");
        prev_rate = rate;
    }
}

/// The lossy codec path works end to end and costs fewer bits than
/// lossless at the same n.
#[test]
fn lossy_path_works_and_saves_bits() {
    let Some(dir) = artifact_dir() else { return };
    let engine = Rc::new(Engine::new(&dir).unwrap());
    let samples = baf::data::eval_set(6);
    let lossless = Pipeline::new(Rc::clone(&engine), cfg(&dir, 16, 6)).unwrap();
    let (_, rate_ll) = lossless.evaluate_set(&samples).unwrap();
    // an aggressive-enough QP must undercut the (efficient) lossless rate
    let mut c = cfg(&dir, 16, 6);
    c.codec = CodecKind::Mic;
    c.qp = 30;
    let lossy = Pipeline::new(Rc::clone(&engine), c).unwrap();
    let (map, rate_l) = lossy.evaluate_set(&samples).unwrap();
    assert!(rate_l < rate_ll, "lossy {rate_l} >= lossless {rate_ll}");
    assert!(map.map_50 > 0.1, "lossy path collapsed: mAP {}", map.map_50);
}

/// Consolidation (Eq. 6) must reduce the reconstruction error of the
/// transmitted channels relative to the ground-truth Z.
#[test]
fn consolidation_reduces_transmitted_channel_error() {
    let Some(dir) = artifact_dir() else { return };
    let engine = Rc::new(Engine::new(&dir).unwrap());
    let sample = baf::data::eval_set(1).remove(0);
    let on = Pipeline::new(Rc::clone(&engine), cfg(&dir, 16, 8)).unwrap();
    let mut c_off = cfg(&dir, 16, 8);
    c_off.consolidate = false;
    let off = Pipeline::new(Rc::clone(&engine), c_off).unwrap();

    // ground-truth Z from the edge
    let (_, et) = on.edge.process(&sample.image).unwrap();
    let sel = on.edge.sel.clone();
    let truth = baf::tensor::gather_channels_hwc_to_chw(&et.z, &sel);

    let frame_on = on.edge.process(&sample.image).unwrap().0;
    let (_, ct_on) = on.cloud.process(&frame_on).unwrap();
    let (_, ct_off) = off.cloud.process(&frame_on).unwrap();
    let err_on = baf::tensor::gather_channels_hwc_to_chw(&ct_on.z_tilde, &sel).mse(&truth);
    let err_off = baf::tensor::gather_channels_hwc_to_chw(&ct_off.z_tilde, &sel).mse(&truth);
    assert!(
        err_on < err_off,
        "Eq.6 should reduce transmitted-channel MSE: {err_on} vs {err_off}"
    );
}

/// Frames produced by the edge are self-describing: a cloud configured
/// identically decodes them; a mismatched C is rejected loudly.
#[test]
fn frame_geometry_checked() {
    let Some(dir) = artifact_dir() else { return };
    let engine = Rc::new(Engine::new(&dir).unwrap());
    let sample = baf::data::eval_set(1).remove(0);
    let p16 = Pipeline::new(Rc::clone(&engine), cfg(&dir, 16, 8)).unwrap();
    let p8 = Pipeline::new(Rc::clone(&engine), cfg(&dir, 8, 8)).unwrap();
    let (frame, _) = p16.edge.process(&sample.image).unwrap();
    assert!(p16.cloud.process(&frame).is_ok());
    assert!(p8.cloud.process(&frame).is_err(), "C mismatch must be rejected");
}

/// Wire compatibility across container versions: a classic (stripes=1)
/// edge emits v1 frames, a striped edge emits v2 frames, and each cloud
/// decodes BOTH — old receivers keep working and new receivers accept
/// old frames, with identical decoded tensors.
#[test]
fn v1_and_striped_frames_interoperate() {
    let Some(dir) = artifact_dir() else { return };
    let engine = Rc::new(Engine::new(&dir).unwrap());
    let sample = baf::data::eval_set(1).remove(0);
    let mut c4 = cfg(&dir, 16, 8);
    c4.stripes = 4;
    let p1 = Pipeline::new(Rc::clone(&engine), cfg(&dir, 16, 8)).unwrap();
    let p4 = Pipeline::new(Rc::clone(&engine), c4).unwrap();

    let (f1, _) = p1.edge.process(&sample.image).unwrap();
    let (f4, t4) = p4.edge.process(&sample.image).unwrap();
    assert!(t4.stripes > 1, "striped edge must actually stripe");
    assert_eq!(container::parse(&f1).unwrap().version, container::VERSION);
    let parsed4 = container::parse(&f4).unwrap();
    assert_eq!(parsed4.version, container::VERSION2);
    assert_eq!(parsed4.stripes.len(), t4.stripes);

    // cross-decode: striped cloud takes v1 frames, classic cloud takes v2
    let (_, ct_new_old) = p4.cloud.process(&f1).unwrap();
    let (_, ct_old_new) = p1.cloud.process(&f4).unwrap();
    // the entropy-coded content is identical, so reconstructions agree
    assert!(
        ct_new_old.z_tilde.mse(&ct_old_new.z_tilde) < 1e-12,
        "v1 and v2 frames of the same tensor must reconstruct identically"
    );
}

/// The multithreaded server completes all requests and reports sane
/// latency percentiles, with and without batching.
#[test]
fn server_smoke() {
    let Some(dir) = artifact_dir() else { return };
    for cap in [1usize, 8] {
        let pcfg = PipelineConfig { artifact_dir: dir.clone(), ..Default::default() };
        let scfg = ServerConfig {
            batch_cap: cap,
            batch_deadline_us: 1000,
            arrival_rate: 400.0,
            num_requests: 32,
            decode_workers: 2,
            queue_depth: 16,
            burst_factor: 1.0,
            corrupt_rate: 0.0,
            ..Default::default()
        };
        let report = run_server(&pcfg, &scfg).unwrap();
        assert_eq!(report.requests, 32);
        assert_eq!(report.dropped, 0);
        assert!(report.throughput_rps > 1.0);
        let e2e = report.metrics.get("latencies").unwrap().get("5_e2e").unwrap();
        assert_eq!(e2e.get("count").unwrap().as_usize(), Some(32));
        assert!(e2e.get("p95_us").unwrap().as_f64().unwrap() > 0.0);
    }
}

/// The server end to end with striped frames: stripes=2 edges feed the
/// stripe-parallel decode dispatcher; every request completes, the
/// stripe and scratch-reuse counters show the new machinery actually
/// engaged.
#[test]
fn server_striped_smoke() {
    let Some(dir) = artifact_dir() else { return };
    let pcfg = PipelineConfig { artifact_dir: dir, stripes: 2, ..Default::default() };
    let scfg = ServerConfig {
        batch_cap: 4,
        batch_deadline_us: 1000,
        arrival_rate: 400.0,
        num_requests: 32,
        decode_workers: 2,
        queue_depth: 16,
        burst_factor: 1.0,
        corrupt_rate: 0.0,
        ..Default::default()
    };
    let report = run_server(&pcfg, &scfg).unwrap();
    assert_eq!(report.requests, 32);
    assert_eq!(report.dropped, 0, "striped frames must all decode");
    let counters = report.metrics.get("counters").unwrap();
    let stripes = counters.get("stripes_decoded").unwrap().as_usize().unwrap();
    assert!(
        stripes >= 2 * 32,
        "32 frames at K=2 must log >= 64 stripes, got {stripes}"
    );
    let hits = counters.get("scratch_hits").unwrap().as_usize().unwrap();
    assert!(hits > 0, "steady-state decode must recycle scratch buffers");
}

/// With 10% of frames corrupted in flight the server must still complete
/// the run: corrupt frames are dropped and counted (never fatal), every
/// clean frame is served, and the drop count shows up in the metrics
/// table.
#[test]
fn server_survives_fault_injection() {
    let Some(dir) = artifact_dir() else { return };
    let pcfg = PipelineConfig { artifact_dir: dir, ..Default::default() };
    let scfg = ServerConfig {
        batch_cap: 4,
        batch_deadline_us: 1000,
        arrival_rate: 400.0,
        num_requests: 64,
        decode_workers: 2,
        queue_depth: 16,
        burst_factor: 1.0,
        corrupt_rate: 0.10,
        ..Default::default()
    };
    let report = run_server(&pcfg, &scfg).unwrap();
    assert_eq!(report.requests, 64, "every request must be accounted for");
    assert!(
        report.dropped > 0 && report.dropped < 64,
        "with 64 requests at 10% corruption, some (not all) frames must be \
         dropped; got {}",
        report.dropped
    );
    let e2e = report.metrics.get("latencies").unwrap().get("5_e2e").unwrap();
    assert_eq!(
        e2e.get("count").unwrap().as_usize(),
        Some(64 - report.dropped),
        "clean frames must all complete"
    );
    let counters = report.metrics.get("counters").unwrap();
    assert_eq!(
        counters.get("frames_dropped").unwrap().as_usize(),
        Some(report.dropped)
    );
    assert!(
        report.table.contains("frames_dropped"),
        "drop count must appear in the metrics table:\n{}",
        report.table
    );
}

/// Different selection policies change the transmitted set but the
/// beta-fill reconstruction path stays functional for all of them.
#[test]
fn selection_policies_functional() {
    let Some(dir) = artifact_dir() else { return };
    let ctx = baf::experiments::Context::open(&dir, 4).unwrap();
    for p in [
        baf::selection::Policy::Correlation,
        baf::selection::Policy::Variance,
        baf::selection::Policy::FirstC,
        baf::selection::Policy::Random(3),
    ] {
        let (map, bytes) = ctx.beta_fill(p, 16, 8).unwrap();
        assert!(bytes > 0.0);
        assert!((0.0..=1.0).contains(&map));
    }
}
