//! Cross-language golden integration tests.
//!
//! These require `make artifacts` to have run; they are skipped (with a
//! loud message) when the artifact directory is missing so that pure-Rust
//! CI can still run `cargo test`.


#![allow(clippy::unwrap_used, clippy::expect_used)]

use std::path::PathBuf;

fn artifact_dir() -> Option<PathBuf> {
    let dir = baf::runtime::default_artifact_dir();
    if dir.join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("SKIP: no artifacts at {} (run `make artifacts`)", dir.display());
        None
    }
}

#[test]
fn prng_matches_python() {
    let Some(dir) = artifact_dir() else { return };
    baf::golden::verify_prng(&dir.join("golden")).unwrap();
}

#[test]
fn dataset_matches_python_bit_exactly() {
    let Some(dir) = artifact_dir() else { return };
    baf::golden::verify_dataset(&dir.join("golden")).unwrap();
}

#[test]
fn quantizer_matches_jnp_oracle() {
    let Some(dir) = artifact_dir() else { return };
    baf::golden::verify_quant(&dir.join("golden")).unwrap();
}

#[test]
fn pjrt_pipeline_matches_jax() {
    let Some(dir) = artifact_dir() else { return };
    baf::golden::verify_pipeline(&dir).unwrap();
}
