//! Chaos soak for the TCP transport: a [`baf::net::FrameSender`] talks
//! to a [`baf::net::FrameReceiver`] through the deterministic
//! [`baf::net::chaos::ChaosProxy`] fault shim, under a seeded schedule
//! of latency, jitter, fragmentation, coalescing, corruption, resets,
//! and stalls. The suite asserts the exactly-once contract end to end:
//!
//! * **zero duplicate deliveries** — every id-stamped frame reaches the
//!   pipeline at most once, however many times it was retransmitted;
//! * **zero corrupt acceptances** — every delivered frame is
//!   byte-identical to what the sender encoded for that id;
//! * **exact conservation** — every sent frame ends in exactly one
//!   bucket: `delivered + dropped + shed == sent`, where `dropped`
//!   counts wire-rejected / terminally-failed frames that never arrived
//!   and `shed` counts circuit-breaker sheds. A frame that *was*
//!   delivered but whose verdict byte died on the way back (the
//!   ack-lost terminal) is counted on the delivered side, never twice;
//! * **no hangs** — the whole soak is wall-clock bounded.
//!
//! The schedule is replayable: the seed is printed at the start, and a
//! per-seed summary JSON lands in `target/chaos-soak/` (archived by
//! CI). Scale with `BAF_CHAOS_FRAMES` / reseed with `BAF_CHAOS_SEED`;
//! tier-1 runs a short fixed-seed smoke (`BAF_CHAOS_FRAMES=300`).
//!
//! A second scenario drives the server-side overload policy: a tiny
//! [`baf::coordinator::IngressQueue`] with a deliberately slow consumer
//! forces BUSY answers and deadline sheds, and the same conservation
//! law must hold: `consumed + shed + busy == sent`.

#![allow(clippy::unwrap_used, clippy::expect_used)]

use baf::coordinator::{IngressQueue, PopOutcome, PushOutcome};
use baf::json::Value;
use baf::net::chaos::{ChaosConfig, ChaosProxy};
use baf::net::{Error, FrameReceiver, FrameSender, NetConfig};
use baf::util::SplitMix64;
use std::collections::HashSet;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Parse a decimal or `0x`-prefixed env override.
fn env_u64(name: &str, default: u64) -> u64 {
    std::env::var(name)
        .ok()
        .and_then(|s| {
            let s = s.trim().replace('_', "");
            match s.strip_prefix("0x").or_else(|| s.strip_prefix("0X")) {
                Some(hex) => u64::from_str_radix(hex, 16).ok(),
                None => s.parse().ok(),
            }
        })
        .unwrap_or(default)
}

/// The id-stamped soak payload: 8 LE id bytes plus deterministic
/// filler, so the receiver can verify both identity and integrity.
fn payload_for(id: u64) -> Vec<u8> {
    let mut r = SplitMix64::new(id ^ 0x5A5A_F00D);
    let len = 24 + (id % 120) as usize;
    let mut p = Vec::with_capacity(len);
    p.extend_from_slice(&id.to_le_bytes());
    while p.len() < len {
        p.push(r.next_u64() as u8);
    }
    p
}

fn id_of(frame: &[u8]) -> u64 {
    let head: [u8; 8] = frame
        .get(..8)
        .and_then(|s| s.try_into().ok())
        .expect("delivered frame shorter than its id stamp");
    u64::from_le_bytes(head)
}

fn net_cfg() -> NetConfig {
    NetConfig {
        connect_timeout: Duration::from_millis(800),
        read_timeout: Duration::from_millis(300),
        write_timeout: Duration::from_millis(500),
        accept_timeout: Duration::from_millis(400),
        max_reconnects: 5,
        backoff_base: Duration::from_millis(2),
        backoff_max: Duration::from_millis(30),
        seed: 0xBAF_0E7,
        breaker_threshold: 4,
        breaker_cooldown: Duration::from_millis(50),
        dedup_window: 256,
    }
}

/// How one `send()` call ended, from the edge's point of view.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Outcome {
    Acked,
    Rejected,
    Busy,
    Shed,
    Failed,
}

#[test]
fn soak_exactly_once_and_conservation_under_seeded_chaos() {
    let frames = env_u64("BAF_CHAOS_FRAMES", 600);
    let seed = env_u64("BAF_CHAOS_SEED", 0xBAF_50AC);
    println!(
        "chaos soak: seed=0x{seed:X} frames={frames} \
         (replay: BAF_CHAOS_SEED=0x{seed:X} BAF_CHAOS_FRAMES={frames})"
    );
    let t0 = Instant::now();

    let mut rx = FrameReceiver::bind("127.0.0.1:0", net_cfg()).unwrap();
    let upstream = rx.local_addr().unwrap().to_string();
    let chaos = ChaosConfig {
        seed,
        jitter: Duration::from_millis(1),
        max_segment: 512,
        coalesce_prob: 0.15,
        corrupt_prob: 0.003,
        reset_prob: 0.003,
        stall_prob: 0.003,
        stall: Duration::from_millis(400),
        ..ChaosConfig::default()
    };
    let mut proxy = ChaosProxy::start(&upstream, chaos).unwrap();
    let addr = proxy.local_addr().to_string();

    // receiver: collect every delivered frame until the sender is done
    // and the stream has gone quiet
    let done = Arc::new(AtomicBool::new(false));
    let rx_done = Arc::clone(&done);
    let rx_thread = std::thread::spawn(move || {
        let mut delivered: Vec<Vec<u8>> = Vec::new();
        loop {
            match rx.recv() {
                Ok(r) => delivered.push(r.frame),
                Err(Error::Timeout { .. }) | Err(Error::ConnClosed { .. }) => {
                    if rx_done.load(Ordering::Relaxed) {
                        break;
                    }
                }
                // corrupt or torn messages: typed, dropped, keep serving
                Err(_) => {}
            }
        }
        (delivered, rx.stats())
    });

    // sender: one synchronous send per id, every outcome recorded
    let tx_thread = std::thread::spawn(move || {
        let mut tx = FrameSender::connect(&addr, net_cfg()).unwrap();
        let mut outcomes: Vec<Outcome> = Vec::with_capacity(frames as usize);
        for id in 0..frames {
            let o = match tx.send(&payload_for(id)) {
                Ok(()) => Outcome::Acked,
                Err(Error::Protocol(_)) => Outcome::Rejected,
                Err(Error::Busy) => Outcome::Busy,
                Err(Error::BreakerOpen) => Outcome::Shed,
                Err(_) => Outcome::Failed,
            };
            outcomes.push(o);
        }
        let stats = tx.stats();
        (outcomes, stats)
    });

    let (outcomes, tx_stats) = tx_thread.join().unwrap();
    done.store(true, Ordering::Relaxed);
    let (delivered, rx_stats) = rx_thread.join().unwrap();
    proxy.shutdown();
    let chaos_stats = proxy.stats();
    let elapsed = t0.elapsed();

    // no hangs: the whole soak is wall-clock bounded
    assert!(
        elapsed < Duration::from_secs(180),
        "soak took {elapsed:?}; the transport is hanging somewhere"
    );

    // zero corrupt acceptances + zero duplicate deliveries
    let mut delivered_ids: HashSet<u64> = HashSet::new();
    for frame in &delivered {
        let id = id_of(frame);
        assert!(id < frames, "seed 0x{seed:X}: delivered unknown id {id}");
        assert_eq!(
            frame,
            &payload_for(id),
            "seed 0x{seed:X}: frame {id} delivered with corrupt bytes"
        );
        assert!(
            delivered_ids.insert(id),
            "seed 0x{seed:X}: frame {id} delivered twice"
        );
    }
    assert_eq!(
        delivered.len(),
        rx_stats.frames as usize,
        "receiver's frames counter must equal actual deliveries"
    );

    // exact conservation: each sent id lands in exactly one bucket
    assert_eq!(outcomes.len() as u64, frames);
    let mut acked = 0u64;
    let mut dropped = 0u64;
    let mut shed = 0u64;
    let mut ack_lost = 0u64;
    for (id, o) in outcomes.iter().enumerate() {
        let was_delivered = delivered_ids.contains(&(id as u64));
        match o {
            Outcome::Acked => {
                assert!(
                    was_delivered,
                    "seed 0x{seed:X}: frame {id} was ACKed but never delivered"
                );
                acked += 1;
            }
            Outcome::Shed => {
                assert!(
                    !was_delivered,
                    "seed 0x{seed:X}: breaker-shed frame {id} was delivered"
                );
                shed += 1;
            }
            // Rejected/Failed (and a corrupted verdict byte read as
            // BUSY) may still have landed: the ack-lost terminal. Such
            // a frame counts as delivered, never as dropped too.
            Outcome::Rejected | Outcome::Busy | Outcome::Failed => {
                if was_delivered {
                    ack_lost += 1;
                } else {
                    dropped += 1;
                }
            }
        }
    }
    assert_eq!(
        delivered_ids.len() as u64 + dropped + shed,
        frames,
        "seed 0x{seed:X}: conservation violated \
         (delivered {} + dropped {dropped} + shed {shed} != sent {frames})",
        delivered_ids.len()
    );
    assert_eq!(delivered_ids.len() as u64, acked + ack_lost);
    // the schedule is faulty, not hostile: most traffic must get through
    assert!(
        delivered_ids.len() as u64 >= frames / 4,
        "seed 0x{seed:X}: only {}/{frames} delivered — schedule too hostile",
        delivered_ids.len()
    );

    println!(
        "chaos soak done in {elapsed:?}: delivered={} acked={acked} \
         ack_lost={ack_lost} dropped={dropped} shed={shed} \
         dup_suppressed={} reconnects={} chaos={chaos_stats:?}",
        delivered_ids.len(),
        rx_stats.duplicates,
        tx_stats.reconnects,
    );

    // per-seed summary JSON next to the lint/bench artifacts
    let dir = std::path::Path::new("target/chaos-soak");
    std::fs::create_dir_all(dir).unwrap();
    let mut faults = Value::obj();
    faults
        .set("connections", chaos_stats.connections)
        .set("resets", chaos_stats.resets)
        .set("corrupted", chaos_stats.corrupted)
        .set("stalls", chaos_stats.stalls)
        .set("coalesced", chaos_stats.coalesced)
        .set("bytes_up", chaos_stats.bytes_up)
        .set("bytes_down", chaos_stats.bytes_down);
    let mut v = Value::obj();
    v.set("seed", format!("0x{seed:X}"))
        .set("frames", frames)
        .set("delivered", delivered_ids.len())
        .set("acked", acked)
        .set("ack_lost", ack_lost)
        .set("dropped", dropped)
        .set("shed", shed)
        .set("duplicates_suppressed", rx_stats.duplicates)
        .set("wire_rejected", rx_stats.rejected)
        .set("reconnects", tx_stats.reconnects)
        .set("breaker_opens", tx_stats.breaker_opens)
        .set("elapsed_ms", elapsed.as_millis() as u64)
        .set("chaos", faults);
    let path = dir.join(format!("soak_0x{seed:X}.json"));
    baf::json::to_file(&path, &v).unwrap();
    println!("chaos soak summary: {}", path.display());
}

#[test]
fn overload_sheds_busy_and_conserves_at_the_ingress() {
    let sent = 100u64;
    let mut rx = FrameReceiver::bind("127.0.0.1:0", net_cfg()).unwrap();
    let upstream = rx.local_addr().unwrap().to_string();
    // transparent proxy: this scenario isolates the overload policy
    let mut proxy = ChaosProxy::start(&upstream, ChaosConfig::default()).unwrap();
    let addr = proxy.local_addr().to_string();

    // a tiny ingress queue with a deliberately slow consumer: the
    // backlog fills within a handful of frames, after which admission
    // answers BUSY and expired frames are shed drop-oldest
    let q = Arc::new(IngressQueue::<u64>::new(4));
    let cq = Arc::clone(&q);
    let consumer = std::thread::spawn(move || {
        let mut popped: Vec<u64> = Vec::new();
        loop {
            match cq.pop(Duration::from_millis(200)) {
                PopOutcome::Item(id) => {
                    popped.push(id);
                    std::thread::sleep(Duration::from_millis(8));
                }
                PopOutcome::TimedOut => {}
                PopOutcome::Closed => break,
            }
        }
        popped
    });

    let tx_thread = std::thread::spawn(move || {
        let mut tx = FrameSender::connect(&addr, net_cfg()).unwrap();
        let (mut acked, mut busy, mut other) = (0u64, 0u64, 0u64);
        for id in 0..sent {
            match tx.send(&payload_for(id)) {
                Ok(()) => acked += 1,
                Err(Error::Busy) => busy += 1,
                Err(_) => other += 1,
            }
        }
        (acked, busy, other)
    });

    let mut accepted = 0u64;
    let mut shed_by_queue = 0u64;
    let mut busy_answered = 0u64;
    let mut lost_after_ack = 0u64;
    loop {
        match rx.recv_admit(&mut |_| q.can_accept(Instant::now())) {
            Ok(r) => {
                let id = id_of(&r.frame);
                match q.push(id, Instant::now() + Duration::from_millis(10)) {
                    PushOutcome::Accepted { shed: Some(_) } => {
                        shed_by_queue += 1;
                        accepted += 1;
                    }
                    PushOutcome::Accepted { shed: None } => accepted += 1,
                    // single pusher, queue not closed: unreachable, but
                    // an ACKed-then-lost frame would break conservation
                    PushOutcome::Rejected(_) => lost_after_ack += 1,
                }
            }
            Err(Error::Busy) => busy_answered += 1,
            Err(Error::Timeout { .. }) | Err(Error::ConnClosed { .. }) => {
                if tx_thread.is_finished() {
                    break;
                }
            }
            Err(e) => panic!("overload scenario hit a transport fault: {e}"),
        }
    }
    let (acked, busy, other) = tx_thread.join().unwrap();
    q.close();
    let popped = consumer.join().unwrap();

    assert_eq!(lost_after_ack, 0, "an ACKed frame vanished before the queue");
    assert_eq!(other, 0, "transparent proxy: no transport failures expected");
    assert_eq!(acked + busy, sent, "edge-side conservation");
    assert_eq!(accepted, acked, "every ACK corresponds to an accepted frame");
    assert_eq!(busy_answered, busy, "both sides must agree on BUSY counts");
    assert_eq!(
        popped.len() as u64 + shed_by_queue + busy,
        sent,
        "ingress conservation: consumed + shed + busy == sent"
    );
    // the consumer is slow enough that overload genuinely happened
    assert!(
        shed_by_queue + busy > 0,
        "the overload scenario never overloaded (popped {})",
        popped.len()
    );
    // nothing consumed twice, nothing invented
    let unique: HashSet<u64> = popped.iter().copied().collect();
    assert_eq!(unique.len(), popped.len(), "an id was consumed twice");
    assert!(popped.iter().all(|id| *id < sent));
    assert_eq!(rx.stats().busy, busy_answered);
    proxy.shutdown();
}
