//! Property/fuzz tests for the JSON config parser (ROADMAP open item):
//! `json::parse` is fed prng-mutated valid configs plus targeted
//! corpora (deep nesting, huge numbers, truncations, surrogate
//! escapes). Every input must return `Ok` or a typed `ParseError` —
//! never panic, never overflow the stack, never hang.
//!
//! Two real bugs were found by this harness and fixed in `json::parse`:
//!
//! * unbounded recursion — `[[[[…` with ~100k brackets overflowed the
//!   parse stack; now bounded by `json::MAX_DEPTH` with a typed error;
//! * surrogate-pair underflow — `"\ud800\u0041"` computed `lo - 0xdc00`
//!   on a non-low-surrogate and panicked under `overflow-checks = true`
//!   (the test/dev profile); now rejected as a bad escape.
//!
//! The parsed values are additionally pushed through the
//! `PipelineConfig`/`ServerConfig` overlay (`apply`), since that is the
//! path untrusted config files actually take into the system.

#![allow(clippy::unwrap_used, clippy::expect_used)]

use baf::config::{PipelineConfig, ServerConfig};
use baf::json::{parse, MAX_DEPTH};
use baf::util::SplitMix64;

/// A realistic config the mutators start from (covers both sections and
/// every value type the overlay reads).
const SEED_CONFIG: &str = r#"{
  "c": 16, "n": 8, "codec": "tlc", "qp": 0,
  "policy": "corr", "consolidate": true, "stripes": 4,
  "server": {
    "batch_cap": 8, "batch_deadline_us": 2000, "arrival_rate": 200.0,
    "num_requests": 512, "decode_workers": 2, "queue_depth": 64,
    "burst_factor": 1.0, "corrupt_rate": 0.05,
    "listen": "127.0.0.1:7878", "connect": "10.0.0.2:7878"
  }
}"#;

/// Parse, and if it parses, run it through both config overlays — the
/// full untrusted path. Only the absence of panics is asserted.
fn exercise(input: &str) {
    if let Ok(v) = parse(input) {
        let _ = PipelineConfig::default().apply(&v);
        let _ = ServerConfig::default().apply(v.get("server").unwrap_or(&v));
    }
}

#[test]
fn deep_nesting_is_a_typed_error_not_a_stack_overflow() {
    for depth in [MAX_DEPTH + 1, 1_000, 100_000] {
        let arrays = format!("{}1{}", "[".repeat(depth), "]".repeat(depth));
        assert!(parse(&arrays).is_err(), "depth {depth} must be rejected");
        let objects = "{\"k\":".repeat(depth) + "1" + &"}".repeat(depth);
        assert!(parse(&objects).is_err(), "depth {depth} must be rejected");
    }
    // unclosed variants hit the limit before the missing-bracket error
    assert!(parse(&"[".repeat(100_000)).is_err());
    // and the limit is not off by much: real configs are untouched
    assert!(parse(SEED_CONFIG).is_ok());
}

#[test]
fn huge_and_degenerate_numbers_do_not_panic() {
    let long_int = "9".repeat(10_000);
    let long_frac = format!("0.{}1", "0".repeat(10_000));
    for s in [
        "1e308", "-1e308", "1e309", "-1e309", "1e99999", "-1e99999",
        "0.00000000000000000000000000000000000001",
        "123456789012345678901234567890123456789012345678901234567890",
        long_int.as_str(), long_frac.as_str(),
        "1e", "1e+", "1e-", "-", "-.", ".5", "00", "01", "1.", "--1",
    ] {
        exercise(s);
    }
    // overflow saturates to f64 infinity (std parse semantics) — the
    // point is that it is a value or an error, not a crash
    if let Ok(v) = parse("1e999") {
        assert!(v.as_f64().unwrap().is_infinite());
    }
}

#[test]
fn surrogate_escape_corpus_never_panics() {
    for s in [
        r#""\ud800""#,          // lone high surrogate
        r#""\udfff""#,          // lone low surrogate
        r#""\ud800\ud800""#,    // high + high
        "\"\\ud800\\u0041\"",   // high + non-surrogate (the underflow bug)
        "\"\\ud800\\udc00\"",   // a valid pair (U+10000)
        r#""\ud800"#,           // truncated mid-pair
        r#""\ud800\u"#,         // truncated second escape
        r#""\ud800\u00"#,       // truncated second escape digits
        r#""\uD83D\uDE00""#,    // uppercase hex valid pair
        r#""\u0000""#,          // NUL is fine in JSON
    ] {
        exercise(s);
    }
    assert_eq!(parse("\"\\ud83d\\ude00\"").unwrap().as_str(), Some("😀"));
}

#[test]
fn every_prefix_of_a_valid_config_is_handled() {
    for end in 0..SEED_CONFIG.len() {
        if SEED_CONFIG.is_char_boundary(end) {
            exercise(&SEED_CONFIG[..end]);
        }
    }
}

#[test]
fn prng_mutated_configs_never_panic() {
    let mut rng = SplitMix64::new(0xF422);
    let seed_bytes = SEED_CONFIG.as_bytes();
    for _ in 0..10_000 {
        let mut bytes = seed_bytes.to_vec();
        // 1..=8 byte-level mutations: overwrite, insert, delete
        let edits = rng.next_u64() % 8 + 1;
        for _ in 0..edits {
            if bytes.is_empty() {
                break;
            }
            let pos = (rng.next_u64() as usize) % bytes.len();
            match rng.next_u64() % 3 {
                0 => bytes[pos] = rng.next_u64() as u8,
                1 => bytes.insert(pos, rng.next_u64() as u8),
                _ => {
                    bytes.remove(pos);
                }
            }
        }
        // the parser takes &str: lossy-decode like a config loader would
        let text = String::from_utf8_lossy(&bytes);
        exercise(&text);
    }
}

#[test]
fn structural_garbage_corpus() {
    for s in [
        "", " ", "\u{feff}{}", "{", "}", "[", "]", "{]", "[}",
        "{\"a\"}", "{\"a\":}", "{:1}", "[,]", "[1,]", "[1 2]",
        "\"", "\\", "\"\\\"", "\"\\x\"", "tru", "truee", "nul", "nulll",
        "{\"a\":1}garbage", "[1][2]", "//comment", "{'a':1}",
        "\u{0}", "\"\u{0}\"",
    ] {
        exercise(s);
    }
}
