//! `.npy` reader fuzz harness — closes the first "remaining hardening"
//! item from ROADMAP (fuzz the npy reader the same way the codec frames
//! are fuzzed). Drives `tio::read` with:
//!
//! * every 1-byte-granular truncation of a valid file;
//! * every single-byte header overwrite (faultgen-style values), with
//!   the declared-length field included;
//! * hand-built hostile headers (reversed parens, absurd declared
//!   lengths, overflowing shape products) — each must produce a typed
//!   error, never a panic or an unbounded allocation;
//! * PRNG-generated garbage headers and whole-file corruption rounds
//!   (`faultgen::Corruptor`, the same fault model as the transport
//!   suite).
//!
//! A surviving `Ok` is only accepted when it decodes to a tensor whose
//! element count matches its shape and respects
//! `codec::MAX_DECODED_SAMPLES`.

#![allow(clippy::unwrap_used, clippy::expect_used)]

use baf::codec::faultgen::{all_truncations, Corruptor, Fault};
use baf::codec::MAX_DECODED_SAMPLES;
use baf::tensor::Tensor;
use baf::tio;
use baf::util::SplitMix64;
use std::path::PathBuf;

const NPY_MAGIC: &[u8; 6] = b"\x93NUMPY";

fn scratch_file(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join("baf_npy_fuzz");
    std::fs::create_dir_all(&dir).unwrap();
    dir.join(name)
}

/// A valid npy file's bytes, via the crate's own writer.
fn valid_npy(name: &str, shape: &[usize]) -> Vec<u8> {
    let count: usize = shape.iter().product();
    let t = Tensor::from_vec(
        shape,
        (0..count).map(|i| (i as f32) * 0.5 - 7.0).collect(),
    );
    let path = scratch_file(name);
    tio::write_f32(&path, &t).unwrap();
    std::fs::read(&path).unwrap()
}

/// Write `bytes` to a scratch file and run the reader; the call must
/// return (never panic), and any `Ok` must be internally consistent.
fn read_bytes(name: &str, bytes: &[u8]) -> anyhow::Result<tio::Npy> {
    let path = scratch_file(name);
    std::fs::write(&path, bytes).unwrap();
    let got = tio::read(&path);
    if let Ok(npy) = &got {
        let count: usize = npy.shape().iter().product();
        assert!(
            count <= MAX_DECODED_SAMPLES,
            "reader accepted an over-cap element count {count}"
        );
    }
    got
}

/// A hand-built v2.0 file: u32 declared header length, arbitrary header
/// text (mirrors the unit tests' `hostile_npy`, but with a payload).
fn npy_v2(declared_header_len: u32, header: &str, payload: &[u8]) -> Vec<u8> {
    let mut out = Vec::new();
    out.extend_from_slice(NPY_MAGIC);
    out.extend_from_slice(&[2, 0]);
    out.extend_from_slice(&declared_header_len.to_le_bytes());
    out.extend_from_slice(header.as_bytes());
    out.extend_from_slice(payload);
    out
}

#[test]
fn every_truncation_is_rejected() {
    let bytes = valid_npy("trunc.npy", &[4, 5, 3]);
    // sanity: the untruncated file round-trips
    assert!(read_bytes("trunc_case.npy", &bytes).is_ok());
    for fault in all_truncations(bytes.len()) {
        let bad = fault.apply(&bytes);
        assert!(
            read_bytes("trunc_case.npy", &bad).is_err(),
            "truncation to {} bytes must be rejected",
            bad.len()
        );
    }
}

#[test]
fn every_header_byte_overwrite_is_survivable() {
    let bytes = valid_npy("setbyte.npy", &[2, 6]);
    // the header region: magic(8) + u16 len(2) + header text; mutating
    // the length field and the magic is part of the point
    let header_end = bytes.len() - 2 * 6 * 4;
    for pos in 0..header_end {
        for value in [0x00, 0x01, 0x7f, 0xff] {
            let bad = Fault::SetByte { pos, value }.apply(&bytes);
            // must return, not panic; Ok is fine when the overwrite is
            // benign (e.g. rewriting a pad space)
            let _ = read_bytes("setbyte_case.npy", &bad);
        }
    }
}

#[test]
fn reversed_shape_parens_are_an_error_not_a_panic() {
    // regression: `find(')')` over the whole header used to produce
    // close < open and panic the slice in parse_shape
    let header = "{'descr': '<f4', 'fortran_order': False, 'shape': )(, }\n";
    let bad = npy_v2(header.len() as u32, header, &[0u8; 16]);
    assert!(read_bytes("parens.npy", &bad).is_err());
}

#[test]
fn hostile_declared_lengths_and_shapes_are_typed_errors() {
    // 1 GiB declared header on a tiny file: typed LimitExceeded before
    // any allocation
    let bad = npy_v2(1 << 30, "", &[]);
    let err = read_bytes("lim_header.npy", &bad).expect_err("must reject");
    assert!(matches!(
        err.downcast_ref::<baf::codec::Error>(),
        Some(baf::codec::Error::LimitExceeded { what: "npy header bytes", .. })
    ));

    // over-cap element count: typed LimitExceeded before the payload vec
    let header = "{'descr': '<f4', 'fortran_order': False, 'shape': (32768, 32768), }\n";
    let bad = npy_v2(header.len() as u32, header, &[0u8; 64]);
    let err = read_bytes("lim_count.npy", &bad).expect_err("must reject");
    assert!(matches!(
        err.downcast_ref::<baf::codec::Error>(),
        Some(baf::codec::Error::LimitExceeded { what: "npy element count", .. })
    ));

    // usize-overflowing shape product: checked_mul, not wraparound
    let header = "{'descr': '<f4', 'fortran_order': False, \
                  'shape': (18446744073709551615, 16), }\n";
    let bad = npy_v2(header.len() as u32, header, &[0u8; 64]);
    let err = read_bytes("lim_overflow.npy", &bad).expect_err("must reject");
    assert!(err.downcast_ref::<baf::codec::Error>().is_some());
}

#[test]
fn prng_garbage_headers_never_panic() {
    let mut rng = SplitMix64::new(0x6e70795f66757a7a);
    for round in 0..300 {
        let len = (rng.next_u64() % 96) as usize;
        let mut header = Vec::with_capacity(len);
        for _ in 0..len {
            header.push((rng.next_u64() & 0xff) as u8);
        }
        // half the rounds get a syntactically plausible prefix so the
        // parser gets past the early key lookups
        let text = if round % 2 == 0 {
            let tail = String::from_utf8_lossy(&header).into_owned();
            format!("{{'descr': '<f4', 'fortran_order': False, 'shape': {tail}")
        } else {
            String::from_utf8_lossy(&header).into_owned()
        };
        let bad = npy_v2(text.len() as u32, &text, &[0u8; 32]);
        let _ = read_bytes("garbage_case.npy", &bad);
    }
}

#[test]
fn sustained_random_corruption_is_survivable() {
    let bytes = valid_npy("corruptor.npy", &[3, 4, 4]);
    let mut c = Corruptor::new(0xbaf_0601);
    for _ in 0..500 {
        let bad = c.corrupt(&bytes);
        match read_bytes("corruptor_case.npy", &bad) {
            Ok(npy) => {
                // corruption that survives must still be self-consistent
                let count: usize = npy.shape().iter().product();
                if let tio::Npy::F32 { data, .. } = &npy {
                    assert_eq!(data.len(), count);
                }
            }
            Err(_) => {}
        }
    }
}
