//! Property-based tests of the codec stack (hand-rolled generator loop;
//! proptest is unavailable offline). Each property runs over hundreds of
//! randomized cases seeded deterministically — failures print the seed.


#![allow(clippy::unwrap_used, clippy::expect_used)]

use baf::codec::scratch::ScratchPool;
use baf::codec::{container, CodecKind, ImageMeta};
use baf::quant::{consolidate, dequantize, quantize};
use baf::runtime::pool::WorkerPool;
use baf::tensor::Tensor;
use baf::tile::{tile, untile};
use baf::util::SplitMix64;

fn random_tensor(r: &mut SplitMix64, c: usize, h: usize, w: usize) -> Tensor {
    let scale = r.next_f32() * 10.0 + 0.1;
    let offset = r.next_f32() * 20.0 - 10.0;
    Tensor::from_vec(
        &[c, h, w],
        (0..c * h * w).map(|_| r.next_f32() * scale + offset).collect(),
    )
}

/// PROPERTY: every lossless codec roundtrips every tensor exactly,
/// through the full container, for every supported bit depth 1..=16.
#[test]
fn prop_lossless_container_roundtrip() {
    let mut r = SplitMix64::new(0xC0DEC);
    for case in 0..150 {
        let c = [1usize, 3, 4, 8, 16][(r.next_u64() % 5) as usize];
        let h = [4usize, 8, 16][(r.next_u64() % 3) as usize];
        let w = [4usize, 8, 16][(r.next_u64() % 3) as usize];
        let n = (r.next_u64() % 16 + 1) as u8;
        let z = random_tensor(&mut r, c, h, w);
        let q = quantize(&z, n);
        for codec in [
            CodecKind::Tlc,
            CodecKind::PngLike,
            CodecKind::ZstdRaw,
            CodecKind::TlcIc,
        ] {
            let frame = container::pack(&q, codec, 0);
            let parsed = container::parse(&frame)
                .unwrap_or_else(|e| panic!("case {case} {codec:?}: {e}"));
            let back = container::unpack(&parsed)
                .unwrap_or_else(|e| panic!("case {case} {codec:?}: {e}"));
            assert_eq!(back.bins, q.bins, "case {case} {codec:?} n={n} c={c}");
            assert_eq!(back.ranges, q.ranges, "case {case} {codec:?} ranges");
            assert_eq!((back.c, back.h, back.w, back.n), (c, h, w, n));
        }
    }
}

/// PROPERTY: the striped v2 container roundtrips every tensor exactly
/// for every lossless codec and every stripe count — including K=1 and
/// K far beyond the number of stripeable units (which must clamp, not
/// fail).
#[test]
fn prop_striped_container_roundtrip() {
    let mut r = SplitMix64::new(0x5712ED);
    for case in 0..80 {
        let c = [1usize, 3, 4, 8, 16][(r.next_u64() % 5) as usize];
        let h = [4usize, 8, 16][(r.next_u64() % 3) as usize];
        let w = [4usize, 8, 16][(r.next_u64() % 3) as usize];
        let n = (r.next_u64() % 16 + 1) as u8;
        let k = [1usize, 2, 3, 7, 999][(r.next_u64() % 5) as usize];
        let z = random_tensor(&mut r, c, h, w);
        let q = quantize(&z, n);
        for codec in [
            CodecKind::Tlc,
            CodecKind::PngLike,
            CodecKind::ZstdRaw,
            CodecKind::TlcIc,
        ] {
            let frame = container::pack_v2(&q, codec, 0, k);
            let parsed = container::parse(&frame)
                .unwrap_or_else(|e| panic!("case {case} {codec:?} k={k}: {e}"));
            assert_eq!(parsed.version, container::VERSION2);
            assert!(
                !parsed.stripes.is_empty() && parsed.stripes.len() <= k.max(1),
                "case {case} {codec:?}: bad stripe count {}",
                parsed.stripes.len()
            );
            let back = container::unpack(&parsed)
                .unwrap_or_else(|e| panic!("case {case} {codec:?} k={k}: {e}"));
            assert_eq!(back.bins, q.bins, "case {case} {codec:?} n={n} k={k}");
            assert_eq!(back.ranges, q.ranges, "case {case} {codec:?} ranges");
            assert_eq!((back.c, back.h, back.w, back.n), (c, h, w, n));
        }
    }
}

/// PROPERTY: decoding a striped frame on a multi-thread pool with a
/// shared scratch pool agrees bit-for-bit with the serial decode.
#[test]
fn prop_striped_parallel_decode_agrees_with_serial() {
    let mut r = SplitMix64::new(0x9A4A11E1);
    let pool = WorkerPool::new(4);
    let scratch = ScratchPool::new();
    for case in 0..40 {
        let c = [2usize, 8, 16][(r.next_u64() % 3) as usize];
        let n = (r.next_u64() % 12 + 1) as u8;
        let k = (r.next_u64() % 6 + 1) as usize;
        let z = random_tensor(&mut r, c, 8, 8);
        let q = quantize(&z, n);
        for codec in [CodecKind::Tlc, CodecKind::TlcIc] {
            let frame = container::pack_v2_with(&q, codec, 0, k, &pool, &scratch);
            let parsed = container::parse(&frame)
                .unwrap_or_else(|e| panic!("case {case} {codec:?}: {e}"));
            let serial = container::unpack(&parsed)
                .unwrap_or_else(|e| panic!("case {case} {codec:?}: {e}"));
            let par = container::unpack_with(&parsed, &pool, &scratch)
                .unwrap_or_else(|e| panic!("case {case} {codec:?}: {e}"));
            assert_eq!(par.bins, serial.bins, "case {case} {codec:?} k={k}");
            scratch.put_u16(par.bins);
            scratch.put_u8(frame);
        }
    }
}

/// PROPERTY: a single-stripe v2 frame carries the exact v1 payload —
/// striping at K=1 is pure framing, zero entropy-coding change.
#[test]
fn prop_k1_v2_payload_matches_v1() {
    let mut r = SplitMix64::new(0x0F4A);
    for case in 0..40 {
        let c = [1usize, 4, 8][(r.next_u64() % 3) as usize];
        let n = (r.next_u64() % 16 + 1) as u8;
        let z = random_tensor(&mut r, c, 8, 8);
        let q = quantize(&z, n);
        for codec in [
            CodecKind::Tlc,
            CodecKind::PngLike,
            CodecKind::ZstdRaw,
            CodecKind::TlcIc,
        ] {
            let v1 = container::parse(&container::pack(&q, codec, 0))
                .unwrap_or_else(|e| panic!("case {case} {codec:?}: {e}"));
            let v2 = container::parse(&container::pack_v2(&q, codec, 0, 1))
                .unwrap_or_else(|e| panic!("case {case} {codec:?}: {e}"));
            assert_eq!(v2.payload, v1.payload, "case {case} {codec:?} n={n}");
        }
    }
}

/// PROPERTY: the lossy codec (the fifth `CodecKind`) also packs and
/// unpacks through the container for all bit depths — geometry and
/// side info are preserved even though sample values are approximated.
#[test]
fn prop_lossy_container_roundtrip_geometry() {
    let mut r = SplitMix64::new(0x10551);
    for case in 0..40 {
        let c = [1usize, 3, 8][(r.next_u64() % 3) as usize];
        let n = (r.next_u64() % 16 + 1) as u8;
        let qp = (r.next_u64() % 40) as u8;
        let z = random_tensor(&mut r, c, 8, 8);
        let q = quantize(&z, n);
        let frame = container::pack(&q, CodecKind::Mic, qp);
        let parsed = container::parse(&frame)
            .unwrap_or_else(|e| panic!("case {case} qp={qp}: {e}"));
        let back = container::unpack(&parsed)
            .unwrap_or_else(|e| panic!("case {case} qp={qp}: {e}"));
        assert_eq!((back.c, back.h, back.w, back.n), (c, 8, 8, n));
        assert_eq!(back.ranges, q.ranges, "case {case} ranges");
        let cap = (1u32 << n) - 1;
        assert!(
            back.bins.iter().all(|&b| u32::from(b) <= cap),
            "case {case}: lossy decode exceeded n={n} range"
        );
    }
}

/// PROPERTY: dequantization error is bounded by one quantizer step plus
/// the f16 side-info rounding: the transmitted min/max are rounded to
/// f16 (relative error up to 2^-11 of their magnitude), which both
/// shifts the grid and can clamp edge values — exactly the error model
/// the paper's Eq. 4/5 incurs with 16-bit side information.
#[test]
fn prop_quantization_error_bound() {
    let mut r = SplitMix64::new(0x0E44);
    for _ in 0..200 {
        let n = [2u8, 4, 6, 8, 12][(r.next_u64() % 5) as usize];
        let z = random_tensor(&mut r, 4, 8, 8);
        let q = quantize(&z, n);
        let zh = dequantize(&q);
        for ch in 0..4 {
            let rg = q.ranges[ch];
            let step = rg.span() / q.levels() as f32;
            let f16_err = (rg.min.abs() + rg.max.abs()) * 2f32.powi(-11);
            let tol = step * 1.001 + 2.0 * f16_err + 1e-5;
            for i in 0..64 {
                let a = z.data()[ch * 64 + i];
                let b = zh.data()[ch * 64 + i];
                assert!((a - b).abs() <= tol, "n={n} ch={ch}: |{a}-{b}| > {tol}");
            }
        }
    }
}

/// PROPERTY: consolidation output always lies within the decoded bin and
/// never moves a prediction that was already inside it.
#[test]
fn prop_consolidation_invariants() {
    let mut r = SplitMix64::new(0xEC6);
    for _ in 0..200 {
        let n = [2u8, 4, 8][(r.next_u64() % 3) as usize];
        let z = random_tensor(&mut r, 3, 8, 8);
        let q = quantize(&z, n);
        // predictions = truth + noise
        let mut zt = z.clone();
        let noise = r.next_f32();
        for v in zt.data_mut() {
            *v += (r.next_f32() - 0.5) * noise * 2.0;
        }
        let cons = consolidate(&zt, &q);
        let levels = q.levels() as f32;
        for ch in 0..3 {
            let rg = q.ranges[ch];
            let span = rg.span();
            if span <= 0.0 {
                continue;
            }
            let step = span / levels;
            for i in 0..64 {
                let bin = q.plane(ch)[i] as f32;
                let lo = rg.min + (bin - 0.5) * step;
                let hi = rg.min + (bin + 0.5) * step;
                let out = cons.data()[ch * 64 + i];
                let pred = zt.data()[ch * 64 + i];
                assert!(out >= lo - 1e-4 && out <= hi + 1e-4, "outside bin");
                if pred >= lo && pred <= hi {
                    assert_eq!(out, pred, "moved an in-bin prediction");
                }
            }
        }
    }
}

/// PROPERTY: tiling is a bijection between channel planes and the tiled
/// image for arbitrary (C, H, W).
#[test]
fn prop_tile_bijection() {
    let mut r = SplitMix64::new(0x711E);
    for _ in 0..100 {
        let c = (r.next_u64() % 31 + 1) as usize;
        let h = (r.next_u64() % 12 + 2) as usize;
        let w = (r.next_u64() % 12 + 2) as usize;
        let z = random_tensor(&mut r, c, h, w);
        let q = quantize(&z, 6);
        let img = tile(&q);
        assert_eq!(untile(&img), q.bins, "c={c} h={h} w={w}");
        assert!(img.cols * img.rows >= c);
    }
}

/// PROPERTY: the lossy codec's distortion decreases monotonically as QP
/// decreases (checked coarsely on random smooth fields).
#[test]
fn prop_lossy_distortion_monotone_in_qp() {
    let mut r = SplitMix64::new(0x1055);
    for _ in 0..20 {
        let w = 32;
        let h = 32;
        let fx = r.next_f32() * 8.0 + 1.0;
        let fy = r.next_f32() * 8.0 + 1.0;
        let samples: Vec<u16> = (0..w * h)
            .map(|i| {
                let x = (i % w) as f32 / w as f32;
                let y = (i / w) as f32 / h as f32;
                (((x * fx).sin() * (y * fy).cos() * 0.4 + 0.5) * 255.0) as u16
            })
            .collect();
        let meta = ImageMeta { width: w, height: h, n: 8 };
        let mut prev_mse = -1.0f64;
        for qp in [2u8, 14, 26, 38] {
            let enc = CodecKind::Mic.encode_image(&samples, w, h, 8, qp);
            let dec = CodecKind::Mic.decode_image(&enc, &meta, qp).unwrap();
            let mse: f64 = samples
                .iter()
                .zip(&dec)
                .map(|(&a, &b)| {
                    let d = a as f64 - b as f64;
                    d * d
                })
                .sum::<f64>()
                / samples.len() as f64;
            assert!(
                mse + 1e-9 >= prev_mse,
                "distortion decreased with higher QP: {mse} < {prev_mse}"
            );
            prev_mse = mse;
        }
    }
}

/// PROPERTY: a frame with the wrong magic or an unsupported version is
/// rejected even when its CRC is internally consistent (i.e. the check
/// is on the fields themselves, not a side effect of the checksum).
#[test]
fn prop_mismatched_magic_and_version_rejected() {
    let mut r = SplitMix64::new(0x3A61);
    let z = random_tensor(&mut r, 4, 8, 8);
    let q = quantize(&z, 6);
    for codec in [CodecKind::Tlc, CodecKind::PngLike, CodecKind::ZstdRaw] {
        let frame = container::pack(&q, codec, 0);
        for _ in 0..30 {
            // corrupt one of the 4 magic bytes, or the version byte
            let pos = (r.next_u64() % 5) as usize;
            let mut bad = frame.clone();
            bad[pos] = bad[pos].wrapping_add((r.next_u64() % 255 + 1) as u8);
            container::refresh_crc(&mut bad);
            assert!(
                container::parse(&bad).is_err(),
                "{codec:?}: altered byte {pos} accepted"
            );
        }
    }
}

/// PROPERTY: corrupting any single byte of a frame is detected (CRC) —
/// the decoder never silently returns wrong tensor data.
#[test]
fn prop_corruption_detected() {
    let mut r = SplitMix64::new(0xBADF);
    let z = random_tensor(&mut r, 8, 8, 8);
    let q = quantize(&z, 6);
    let frame = container::pack(&q, CodecKind::Tlc, 0);
    for _ in 0..100 {
        let pos = (r.next_u64() % frame.len() as u64) as usize;
        let bit = 1u8 << (r.next_u64() % 8);
        let mut bad = frame.clone();
        bad[pos] ^= bit;
        assert!(container::parse(&bad).is_err(), "flip at {pos} undetected");
    }
}
