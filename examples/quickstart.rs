//! Quickstart: the whole system in ~40 lines.
//!
//! Generates one ShapeWorld image, runs the split edge->cloud pipeline at
//! the paper's quarter-channels operating point (C=16 of P=64, n=8,
//! lossless TLC), and prints the detections next to the ground truth.
//!
//! Run: `cargo run --release --example quickstart`
//! (requires `make artifacts` to have produced ./artifacts)


#![allow(clippy::unwrap_used, clippy::expect_used)]

use baf::config::PipelineConfig;
use baf::coordinator::Pipeline;
use baf::data;

fn main() -> anyhow::Result<()> {
    baf::util::logging::init();

    // 1. open the pipeline (compiles the AOT artifacts on first use)
    let cfg = PipelineConfig::default(); // C=16, n=8, TLC, correlation
    let pipe = Pipeline::open(cfg)?;

    // 2. one image from the deterministic eval split
    // (index 1; warm the executables on index 0 so the printed stage
    // latencies reflect steady state, not first-call PJRT compilation)
    let mut set = data::eval_set(2);
    let warm = set.remove(0);
    let sample = set.remove(0);
    let _ = pipe.process(&warm.image)?;
    println!("ground truth:");
    for b in &sample.boxes {
        println!(
            "  {:>8}  [{:5.1}, {:5.1}, {:5.1}, {:5.1}]",
            data::CLASS_NAMES[b.class], b.x0, b.y0, b.x1, b.y1
        );
    }

    // 3. edge -> bitstream -> cloud -> detections
    let out = pipe.process(&sample.image)?;
    println!("\ncompressed tensor: {} bytes (vs {} raw f32 bytes for Z)",
        out.frame_bytes,
        16 * 16 * 64 * 4
    );
    println!("detections:");
    for b in out.boxes.iter().filter(|b| b.score > 0.2) {
        println!(
            "  {:>8}  [{:5.1}, {:5.1}, {:5.1}, {:5.1}]  score {:.2}",
            data::CLASS_NAMES[b.class], b.x0, b.y0, b.x1, b.y1, b.score
        );
    }
    println!("\nstage latencies:");
    for (name, us) in &out.stages {
        println!("  {name:<18} {us:>8.1} us");
    }
    Ok(())
}
