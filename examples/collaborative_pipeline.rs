//! End-to-end validation driver (the run recorded in EXPERIMENTS.md §E2E).
//!
//! Exercises every layer of the stack on a real workload:
//!   1. the deterministic ShapeWorld eval split (Rust generator, pinned
//!      bit-exactly to the Python training data generator);
//!   2. the AOT-compiled detector artifacts through PJRT (L2+L1);
//!   3. the full BaF compression pipeline (L3) at the paper's operating
//!      points, against the cloud-only baseline;
//! and reports mAP, rate, savings and latency — the paper's headline
//! experiment in one binary.
//!
//! Run: `cargo run --release --example collaborative_pipeline [-- images N]`


#![allow(clippy::unwrap_used, clippy::expect_used)]

use baf::codec::CodecKind;
use baf::config::PipelineConfig;
use baf::coordinator::{CloudOnly, Pipeline};
use baf::data;
use baf::runtime::Engine;
use std::rc::Rc;

fn main() -> anyhow::Result<()> {
    baf::util::logging::init();
    let images: usize = std::env::args()
        .skip_while(|a| a != "images")
        .nth(1)
        .and_then(|v| v.parse().ok())
        .unwrap_or(128);

    let dir = baf::runtime::default_artifact_dir();
    let engine = Rc::new(Engine::new(&dir)?);
    let samples = data::eval_set(images);
    println!("eval split: {} ShapeWorld images (seed {:#x})", images, data::EVAL_SEED);

    // ---- cloud-only baseline ----
    let co = CloudOnly::new(Rc::clone(&engine));
    let base = co.evaluate_set(&samples)?;
    let base_bytes: f64 = samples.iter().map(|s| co.image_bytes(&s.image) as f64).sum::<f64>()
        / samples.len() as f64;
    println!(
        "\ncloud-only:  mAP@0.5 = {:.4}   mAP@[.5:.95] = {:.4}   input = {:.0} B/img",
        base.map_50, base.map_50_95, base_bytes
    );

    // ---- BaF pipeline at three operating points ----
    println!("\n| config | mAP@0.5 | delta | rate B/img | savings vs input |");
    println!("|---|---|---|---|---|");
    for (c, n, codec, qp) in [
        (16usize, 8u8, CodecKind::Tlc, 0u8),   // paper's headline point
        (16, 6, CodecKind::Tlc, 0),            // deeper quantization
        (16, 6, CodecKind::Mic, 12),           // 6-bit + lossy (purple curve)
    ] {
        let cfg = PipelineConfig {
            artifact_dir: dir.clone(),
            c,
            n,
            codec,
            qp,
            ..Default::default()
        };
        let pipe = Pipeline::new(Rc::clone(&engine), cfg)?;
        let (map, bytes) = pipe.evaluate_set(&samples)?;
        println!(
            "| C={c} n={n} {}{} | {:.4} | {:+.4} | {:.0} | {:.1}% |",
            codec.name(),
            if codec == CodecKind::Mic { format!(" qp={qp}") } else { String::new() },
            map.map_50,
            map.map_50 - base.map_50,
            bytes,
            (1.0 - bytes / base_bytes) * 100.0
        );
    }

    // ---- single-request latency breakdown ----
    let pipe = Pipeline::new(
        Rc::clone(&engine),
        PipelineConfig { artifact_dir: dir, ..Default::default() },
    )?;
    let out = pipe.process(&samples[0].image)?;
    println!("\nsingle-request latency (C=16, n=8):");
    let total: f64 = out.stages.iter().map(|(_, us)| us).sum();
    for (name, us) in &out.stages {
        println!("  {name:<18} {us:>8.1} us  ({:>4.1}%)", us / total * 100.0);
    }
    println!("  {:<18} {total:>8.1} us", "TOTAL");
    Ok(())
}
