//! Codec workbench: compress one image's feature tensor with every codec
//! and bit depth; print the rate table and verify integrity end to end.
//! A standalone tool for exploring the §3.2 tiling + coding design space
//! without the detection pipeline.
//!
//! Run: `cargo run --release --example codec_tool`


#![allow(clippy::unwrap_used, clippy::expect_used)]

use baf::codec::{container, CodecKind};
use baf::quant::quantize;
use baf::runtime::Engine;
use baf::selection::{ChannelStats, Policy};
use baf::tensor::gather_channels_hwc_to_chw;
use baf::tile;

fn main() -> anyhow::Result<()> {
    baf::util::logging::init();
    let dir = baf::runtime::default_artifact_dir();
    let engine = Engine::new(&dir)?;
    let stats = ChannelStats::load(&dir)?;
    let m = engine.manifest().clone();

    let sample = baf::data::eval_set(1).remove(0);
    let img = sample.image.clone().reshape(&[1, m.image_size, m.image_size, 3]);
    let z = engine
        .run("frontend_b1", &[&img])?
        .reshape(&[m.z_shape.0, m.z_shape.1, m.z_shape.2]);

    println!("split tensor Z: {}x{}x{} (raw f32 = {} bytes)",
        m.z_shape.0, m.z_shape.1, m.z_shape.2, z.len() * 4);

    for c in [16usize, 64] {
        let sel = stats.select(Policy::Correlation, c);
        let planes = gather_channels_hwc_to_chw(&z, &sel);
        println!("\nC = {c} channels:");
        println!("| n | tile | raw bits | tlc | png-like | zstd | mic qp=12 |");
        println!("|---|---|---|---|---|---|---|");
        for n in [2u8, 4, 6, 8] {
            let q = quantize(&planes, n);
            let img = tile::tile(&q);
            let mut row = format!(
                "| {n} | {}x{} | {} |",
                img.width,
                img.height,
                img.samples.len() * n as usize / 8
            );
            for codec in [CodecKind::Tlc, CodecKind::PngLike, CodecKind::ZstdRaw] {
                let frame = container::pack(&q, codec, 0);
                // verify roundtrip through the container
                let back = container::unpack(&container::parse(&frame)?)?;
                assert_eq!(back.bins, q.bins, "{} corrupted data", codec.name());
                row.push_str(&format!(" {} |", frame.len()));
            }
            let lossy = container::pack(&q, CodecKind::Mic, 12);
            row.push_str(&format!(" {} |", lossy.len()));
            println!("{row}");
        }
    }
    println!("\n(all lossless paths verified bit-exact through the container)");
    Ok(())
}
