//! Serving scenario: the pipelined edge->cloud server under Poisson load,
//! with and without dynamic batching — the deployment the paper's
//! collaborative-intelligence setting implies (many devices, one cloud).
//!
//! Run: `cargo run --release --example edge_cloud_serving`


#![allow(clippy::unwrap_used, clippy::expect_used)]

use baf::config::{PipelineConfig, ServerConfig};
use baf::coordinator::run_server;

fn main() -> anyhow::Result<()> {
    baf::util::logging::init();
    let pcfg = PipelineConfig::default();

    for (label, cap) in [("no batching (cap 1)", 1usize), ("dynamic batching (cap 8)", 8)] {
        let scfg = ServerConfig {
            batch_cap: cap,
            batch_deadline_us: 2000,
            arrival_rate: 250.0,
            num_requests: 192,
            decode_workers: 2,
            queue_depth: 64,
            burst_factor: 1.0,
            corrupt_rate: 0.0,
            ..Default::default()
        };
        println!("=== {label}: {} requests @ {}/s ===", scfg.num_requests, scfg.arrival_rate);
        let report = run_server(&pcfg, &scfg)?;
        println!(
            "throughput {:.1} req/s, mean batch {:.2}\n{}",
            report.throughput_rps, report.mean_batch_size, report.table
        );
    }
    Ok(())
}
